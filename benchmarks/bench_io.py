"""Shard-store I/O bandwidth: the measured counterpart of the perf model's
T_read/T_write terms (Eq. 8/16).

Times the slice-per-rank store (repro/io) on this machine's filesystem:
chunked write, full scatter-read, a single-rank region read, and the
checkpoint save/restore built on the same core. Rows report GB/s so the
numbers slot directly against `MachineSpec.bw_load`/`bw_store` — on the
paper's GPFS these are the 50/28.5 GB/s constants; on a laptop SSD expect
single-digit GB/s (page-cache-warm reads higher).

    python benchmarks/run.py --suite io [--fast]
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np


def _time(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(iters: int = 3, fast: bool = False):
    from repro.io import shard_store
    from repro.checkpoint import load_checkpoint, save_checkpoint

    n = 64 if fast else 192
    chunks = (4, 1, 1) if fast else (8, 1, 1)
    arr = np.random.default_rng(0).standard_normal(
        (n, n, n)).astype(np.float32)
    gb = arr.nbytes / 1e9
    root = tempfile.mkdtemp(prefix="repro-bench-io-")
    rows = []
    try:
        store = f"{root}/arr"

        t = _time(lambda: shard_store.save_array(store, arr, chunks=chunks),
                  iters)
        rows.append((f"io/shard_write/{n}^3", t * 1e6, f"{gb / t:.2f}GB/s"))

        t = _time(lambda: shard_store.load_array(store), iters)
        rows.append((f"io/shard_read/{n}^3", t * 1e6, f"{gb / t:.2f}GB/s"))

        rank_rows = n // chunks[0]
        region = (slice(0, rank_rows), slice(0, n), slice(0, n))
        t = _time(lambda: shard_store.read_region(store, region), iters)
        rows.append((f"io/rank_read/{rank_rows}x{n}x{n}", t * 1e6,
                     f"{gb / chunks[0] / t:.2f}GB/s"))

        tree = {"vol": arr}
        t = _time(lambda: save_checkpoint(f"{root}/ckpt", 1, tree), iters)
        rows.append((f"io/ckpt_save/{n}^3", t * 1e6, f"{gb / t:.2f}GB/s"))

        t = _time(lambda: load_checkpoint(f"{root}/ckpt", 1, tree), iters)
        rows.append((f"io/ckpt_restore/{n}^3", t * 1e6, f"{gb / t:.2f}GB/s"))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
