"""Paper Fig. 6 + Fig. 7: end-to-end reconstruction GUPS.

Measured end-to-end (filter + back-project) on CPU at reduced scale, plus
the performance-model projection of the paper's three output sizes
(2048^3, 4096^3, 8192^3 from 2048^2 x 4096 input).

The measured rows are driven by the plan/engine layer: `plan_spec` (the
driver's ``--plan`` flag) selects any point of the schedule x reduce x
precision x impl cross-product with one string — including the stream-codec
tokens, e.g. ``"precision=fp8_e4m3,reduce=scatter_bf16"`` — and each
measured row reports the wire GB its two collectives move (AllGather of the
encoded projection stream vs the volume Reduce), so codec choices show up
as communication volume next to the wall clock.
"""
from __future__ import annotations

from benchmarks.bench_backprojection import _time

from repro.core.distributed import IFDKGrid
from repro.core.fdk import gups
from repro.core.geometry import default_geometry, paper_geometry
from repro.core.perf_model import ABCI, gups_end_to_end, predict
from repro.core.phantom import forward_project
from repro.core.plan import plan_from_spec
from repro.planner.cost import (
    allgather_wire_bytes, point_from_plan, reduce_wire_bytes,
)


def _wire_note(plan) -> str:
    """ag/rd wire GB of a built plan (0 on a 1x1 grid — nothing moves)."""
    g = plan.geometry
    pt = point_from_plan(plan)
    return (f"ag={allgather_wire_bytes(g, pt) / 1e9:.3f}GB "
            f"rd={reduce_wire_bytes(g, pt) / 1e9:.3f}GB")


def run(iters: int = 2, fast: bool = False, plan_spec: str | None = None):
    rows = []
    # measured (reduced-scale, CPU), one plan per impl — or the caller's spec
    cases = [(16, 32)] if fast else [(32, 64), (48, 96)]
    impls = ("factorized",) if fast else ("reference", "factorized")
    specs = [plan_spec] if plan_spec else [f"impl={i}" for i in impls]
    for n, npj in cases:
        g = default_geometry(n, n_proj=npj)
        proj = forward_project(g)
        for spec in specs:
            plan = plan_from_spec(g, spec)
            fn = plan.build()
            dt = _time(lambda: fn(proj), iters)
            d = plan.describe()
            tag = f"{d['schedule']}/{d['impl']}/{d['precision']}"
            rows.append((
                f"fig6/measured/{n}^3x{npj}/{tag}", dt * 1e6,
                f"{gups(g, dt):.3f}GUPS {_wire_note(plan)}",
            ))
    # projected (paper scale, paper constants) — wire GB per stage from the
    # same cost helpers the planner ranks with
    from repro.planner.cost import PlanPoint
    for n_out, r, c in [(2048, 4, 4), (4096, 32, 8), (8192, 256, 8)]:
        g = paper_geometry(n_out)
        grid = IFDKGrid(r=r, c=c)
        b = predict(g, grid, ABCI)
        pt = PlanPoint(grid=grid)
        rows.append((
            f"fig6/projected/{n_out}^3/{r * c}gpus", b.t_runtime * 1e6,
            f"{gups_end_to_end(g, b):.0f}GUPS "
            f"ag={allgather_wire_bytes(g, pt) / 1e9:.0f}GB "
            f"rd={reduce_wire_bytes(g, pt) / 1e9:.0f}GB",
        ))
    return rows
