"""Paper Fig. 6 + Fig. 7: end-to-end reconstruction GUPS.

Measured end-to-end (filter + back-project) on CPU at reduced scale, plus
the performance-model projection of the paper's three output sizes
(2048^3, 4096^3, 8192^3 from 2048^2 x 4096 input).
"""
from __future__ import annotations

from repro.core.distributed import IFDKGrid
from repro.core.fdk import timed_reconstruct
from repro.core.geometry import CBCTGeometry, default_geometry
from repro.core.perf_model import ABCI, gups_end_to_end, predict
from repro.core.phantom import forward_project


def run(iters: int = 2):
    rows = []
    # measured (reduced-scale, CPU)
    for n, npj in [(32, 64), (48, 96)]:
        g = default_geometry(n, n_proj=npj)
        proj = forward_project(g)
        for impl in ("reference", "factorized"):
            _, dt, rate = timed_reconstruct(g, proj, impl=impl, iters=iters)
            rows.append((
                f"fig6/measured/{n}^3x{npj}/{impl}", dt * 1e6,
                f"{rate:.3f}GUPS",
            ))
    # projected (paper scale, paper constants)
    for n_out, r, c in [(2048, 4, 4), (4096, 32, 8), (8192, 256, 8)]:
        g = CBCTGeometry(
            n_proj=4096, n_u=2048, n_v=2048, d_u=0.002, d_v=0.002,
            d=4.0, dsd=8.0, n_x=n_out, n_y=n_out, n_z=n_out,
            d_x=0.001, d_y=0.001, d_z=0.001,
        )
        b = predict(g, IFDKGrid(r=r, c=c), ABCI)
        rows.append((
            f"fig6/projected/{n_out}^3/{r * c}gpus", b.t_runtime * 1e6,
            f"{gups_end_to_end(g, b):.0f}GUPS",
        ))
    return rows
