"""Reconstruction-as-a-service throughput: scans/hour at a fixed fleet.

One scan reconstructed fast is the paper's claim; a serving deployment
cares how many *scans per hour* the same fleet sustains. This suite prices
the service path (repro/service: plan cache + geometry-bucketed batched
engine + prefetch/write-behind) against the loop it replaces:

  serial_cold    the naive service: every request pays planner search
                 (`plan_from_spec(g, "auto")`), an engine build + compile
                 (caches cleared), then a single-scan reconstruction. This
                 is what admission costs without the plan/engine caches.
  serial_warm    the steady-state serial loop: one warm single-scan engine,
                 scans reconstructed one dispatch at a time. Isolates the
                 batching win from the caching win. (On the CPU backend a
                 single scan already saturates the cores, so expect the
                 batched dispatch to run at ~0.8x warm-serial per scan —
                 batching pays on accelerators with spare occupancy; the
                 caching win is what this host can demonstrate.)
  service        ReconstructionService.submit x B + drain() on warm caches:
                 one planner search per family ever, one vmapped dispatch
                 per bucket of B scans.
  serve_loop     the hardened mode (ISSUE 9): serve() background drain
                 loop, submit with a per-scan time-to-volume SLO
                 (deadline_s), callers ticket.wait() — measures the
                 continuously-serving path end to end and reports SLO
                 attainment (service.slo.met/missed off the
                 service.time_to_volume_seconds histogram clock).

Acceptance (ISSUE 7): a bucket of >= 4 same-geometry scans must serve
>= 2x the scans/hour of the serial single-scan loop. Each service row's
`derived` carries scans_per_hour plus the speedups against both baselines
and an OK/MISS verdict. serial_warm and service are sampled interleaved
(min-of-iters, bench_streaming idiom) so host drift cannot pick the
winner; serial_cold is compile-dominated and sampled separately. The
serve_loop/slo row (ISSUE 9 acceptance) reports attainment against a
deadline of 4x the measured warm per-scan time — generous enough that a
healthy loop attains ~100%, tight enough that a stalled loop shows up in
the nightly BENCH_serving.json trajectory. `main()` (or ``run.py
--json``) persists rows as BENCH_serving.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

# `python benchmarks/bench_serving.py` puts benchmarks/ (not the repo
# root) on sys.path; make the documented direct invocation work.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_streaming import _interleaved_best, flatten_rows, \
    write_json
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import clear_engine_cache, plan_from_spec
from repro.service import ReconstructionService, ScanFamily

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")


def _scans_per_hour(per_scan_s: float) -> float:
    return 3600.0 / per_scan_s


def _time_serial_cold(g, scans, iters: int) -> float:
    """Per-scan seconds for the no-cache loop: planner search + engine
    build + compile + reconstruct, per request."""
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        for s in scans:
            clear_engine_cache()
            plan = plan_from_spec(g, "auto")
            jax.block_until_ready(plan.build()(s))
        best = min(best, (time.perf_counter() - t0) / len(scans))
    return best


def _time_serve_loop(g, scans, iters: int, deadline_s: float,
                     policy: str = "deadline"):
    """Per-scan seconds + SLO attainment for the background-loop mode:
    submit with a deadline, ticket.wait(), shutdown between rounds is NOT
    paid (one loop serves every round — that is the mode's point)."""
    svc = ReconstructionService(max_batch=len(scans), policy=policy)
    # Steady-state measurement: racing submits can split a round into any
    # power-of-two bucket size, so pre-compile them all — otherwise one
    # cold batched-engine compile lands in a measured round and the SLO
    # row reports compile time, not serving behavior.
    fam = ScanFamily.make(g, None, {})
    plan = svc.plan_cache.resolve(fam)
    b = 1
    while b <= len(scans):
        warm = jnp.zeros((b, g.n_proj, g.n_v, g.n_u), jnp.float32)
        jax.block_until_ready(plan.build_batched(b)(warm))
        b *= 2
    svc.serve()
    best = float("inf")
    try:
        for _ in range(iters):
            t0 = time.perf_counter()
            tickets = [svc.submit(projections=s, geometry=g,
                                  deadline_s=deadline_s) for s in scans]
            for t in tickets:
                if not t.wait(timeout=300.0):
                    raise RuntimeError(
                        f"serve loop missed the 300 s bench timeout for "
                        f"{t.scan_id} (state {t.state.value})")
            jax.block_until_ready(tickets[-1].volume)
            best = min(best,
                       (time.perf_counter() - t0) / len(scans))
    finally:
        svc.shutdown()
        st = svc.stats()
        svc.close()
    return best, st


def run(iters: int = 5, fast: bool = False, policy: str = "deadline"):
    """Yield one LIST of rows per case (a case group) so the driver
    (run.py --json) can snapshot the stage tracer around each case and
    record per-case t_stage deltas (bench_streaming.run's convention)."""
    cases = [(32, 64, 4)] if fast else [(32, 64, 4), (48, 96, 8)]
    for n, npj, bucket in cases:
        rows = []
        g = default_geometry(n, n_proj=npj)
        base = jnp.asarray(forward_project(g))
        # distinct same-geometry scans (one family, different data)
        scans = [base * (1.0 + 0.1 * k) for k in range(bucket)]
        label = f"serving/{n}^3x{npj}/B{bucket}"

        # steady-state serial baseline: one warm single-scan engine
        clear_engine_cache()
        serial_engine = plan_from_spec(g, "auto").build()

        svc = ReconstructionService(max_batch=bucket)

        def service_round():
            tickets = [svc.submit(projections=s, geometry=g) for s in scans]
            svc.drain()
            jax.block_until_ready(tickets[-1].volume)

        def serial_round():
            for s in scans:
                jax.block_until_ready(serial_engine(s))

        t_warm, t_svc = _interleaved_best([serial_round, service_round],
                                          iters)
        t_warm /= bucket
        t_svc /= bucket
        t_cold = _time_serial_cold(g, scans, max(2, iters // 2))

        st = svc.stats()
        assert st["plan_cache"]["searches"] == 1, st["plan_cache"]
        svc.close()

        sph_cold = _scans_per_hour(t_cold)
        sph_warm = _scans_per_hour(t_warm)
        sph_svc = _scans_per_hour(t_svc)
        speedup = sph_svc / sph_cold
        rows.append((f"{label}/serial_cold", t_cold * 1e6,
                     f"scans_per_hour={sph_cold:.0f} searches_per_scan=1"))
        rows.append((f"{label}/serial_warm", t_warm * 1e6,
                     f"scans_per_hour={sph_warm:.0f}"))
        rows.append((
            f"{label}/service", t_svc * 1e6,
            f"scans_per_hour={sph_svc:.0f} "
            f"speedup_vs_cold={speedup:.2f}x "
            f"speedup_vs_warm={sph_svc / sph_warm:.2f}x "
            f"plan_searches={st['plan_cache']['searches']} "
            f"{'OK' if speedup >= 2.0 else 'MISS'}",
        ))
        # Request latency straight off the service's metrics registry
        # (obs/metrics histograms behind stats()["latency"]).
        qw = st["latency"]["queue_wait"]
        ttv = st["latency"]["time_to_volume"]
        rows.append((
            f"{label}/latency", (ttv["mean"] or 0.0) * 1e6,
            f"time_to_volume_mean_us={(ttv['mean'] or 0.0) * 1e6:.0f} "
            f"queue_wait_mean_us={(qw['mean'] or 0.0) * 1e6:.0f} "
            f"n={ttv['count']}",
        ))

        # -- background-loop mode (ISSUE 9): serve() + deadline SLOs ------
        deadline_s = 4.0 * t_warm * bucket     # 4x one warm round per scan
        t_loop, st_loop = _time_serve_loop(g, scans,
                                           max(2, iters // 2),
                                           deadline_s, policy=policy)
        sph_loop = _scans_per_hour(t_loop)
        slo = st_loop["slo"]
        ttv_loop = st_loop["latency"]["time_to_volume"]
        attain = slo["attainment"]
        rows.append((
            f"{label}/serve_loop", t_loop * 1e6,
            f"scans_per_hour={sph_loop:.0f} policy={policy} "
            f"loop_passes={st_loop['loop']['passes']} "
            f"speedup_vs_warm={sph_loop / sph_warm:.2f}x",
        ))
        rows.append((
            f"{label}/slo", (ttv_loop["mean"] or 0.0) * 1e6,
            f"attainment={attain if attain is None else round(attain, 4)} "
            f"met={slo['met']} missed={slo['missed']} "
            f"deadline_us={deadline_s * 1e6:.0f} "
            f"ttv_mean_us={(ttv_loop['mean'] or 0.0) * 1e6:.0f} "
            f"ttv_max_us={(ttv_loop['max'] or 0.0) * 1e6:.0f} "
            f"{'OK' if (attain or 0.0) >= 0.99 else 'MISS'}",
        ))
        yield rows


def main(argv=None) -> None:
    import argparse

    from repro.service import SCHEDULING_POLICIES

    ap = argparse.ArgumentParser(
        description="reconstruction-as-a-service throughput bench")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--policy", default="deadline",
                    choices=SCHEDULING_POLICIES,
                    help="bucket scheduling policy for the serve-loop "
                         "mode (default: deadline)")
    ap.add_argument("--json", nargs="?", const=JSON_PATH, default=None,
                    metavar="PATH",
                    help=f"persist rows as JSON (default {JSON_PATH})")
    args = ap.parse_args(argv)
    rows = flatten_rows(run(iters=args.iters, fast=args.fast,
                            policy=args.policy))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        write_json(args.json, rows)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
