"""Ranked plan-search table: the planner's answer for a deployment triple.

Prints the top-k candidate plans for a (geometry, device count, HBM budget)
with the full plan-aware Eq. 17-19 breakdown per row — the table the paper
builds by hand in §4.2/Table 5, produced by `repro.planner.search_grids`.

    PYTHONPATH=src python benchmarks/plan_search.py                # paper 4K, 256 ranks
    PYTHONPATH=src python benchmarks/plan_search.py --n 2048 --devices 64 \
        --hbm-gib 16 --system abci --top-k 12 --all
    PYTHONPATH=src python benchmarks/plan_search.py --local --measure
        # buildable single-device plans, top-3 timed for real

Also runnable as a `benchmarks/run.py` suite (``--suite plan_search``).
"""
from __future__ import annotations

import argparse

from repro.core.geometry import default_geometry, paper_geometry
from repro.core.perf_model import ABCI, TPU_V5E
from repro.planner import search_grids, search_plans
from repro.planner.cost import allgather_wire_bytes, reduce_wire_bytes
from repro.planner.measure import refine

_SYSTEMS = {"abci": ABCI, "tpu": TPU_V5E}


def _fmt_row(i, p, g):
    b = p.breakdown
    pt = p.point
    sched = pt.schedule
    if sched != "fused":
        sched += f"/{pt.n_steps}"
    if pt.y_chunks:
        sched += f"x{pt.y_chunks}"
    stat = "ok" if p.feasible else f"INFEASIBLE ({p.reason})"
    cols = [
        f"{i:>2}", f"{pt.grid.r}x{pt.grid.c}", f"{sched:<14}",
        f"{pt.reduce:<12}", f"{pt.precision:<8}", f"{pt.impl:<10}",
        f"{b.t_read:7.2f}", f"{b.t_flt:7.2f}", f"{b.t_allgather:7.2f}",
        f"{b.t_bp:7.2f}", f"{b.t_compute:7.2f}", f"{b.t_write:7.2f}",
        f"{b.t_post:7.2f}", f"{b.t_runtime:8.2f}",
        f"{p.predicted_gups(g):9.1f}",
        f"{p.footprint.total / 2**30:6.2f}",
        # Wire GB the two collectives actually move under this plan's
        # stream codec / reduce mode (the communication-volume story the
        # codec layer exists for): fp8 quarters ag_GB, scatter_bf16 halves
        # rd_GB — visible next to the time columns so ranking flips under
        # --pfs/--rank-io throttles are explainable.
        f"{allgather_wire_bytes(g, pt) / 1e9:8.1f}",
        f"{reduce_wire_bytes(g, pt) / 1e9:8.1f}",
    ]
    if p.measured is not None:
        cols.append(f"meas={p.measured:.3f}s")
    cols.append(stat)
    return "  ".join(cols)


_HEADER = ("  #  RxC    schedule        reduce        prec      impl      "
           "   t_read   t_flt    t_ag     t_bp   t_cmp   t_wr     t_post"
           "     t_run      GUPS    GiB     ag_GB    rd_GB  status")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="perf-model-driven ReconstructionPlan search")
    ap.add_argument("--n", type=int, default=4096, help="volume edge N_x=N_y=N_z")
    ap.add_argument("--n-proj", type=int, default=4096)
    ap.add_argument("--detector", type=int, default=2048,
                    help="detector edge N_u=N_v")
    ap.add_argument("--devices", type=int, default=256,
                    help="deployment size to plan for (rank count)")
    ap.add_argument("--system", choices=sorted(_SYSTEMS), default="abci")
    ap.add_argument("--hbm-gib", type=float, default=16.0,
                    help="per-device HBM budget")
    ap.add_argument("--pfs-read-gbs", type=float, default=None,
                    help="override the system's aggregate PFS read "
                         "bandwidth (GB/s) — the T_read term; throttle to "
                         "see load-bound rankings")
    ap.add_argument("--pfs-write-gbs", type=float, default=None,
                    help="override the aggregate PFS write bandwidth "
                         "(GB/s) — the T_write term")
    ap.add_argument("--rank-io-gbs", type=float, default=None,
                    help="per-rank PFS link bandwidth (GB/s): caps "
                         "T_read/T_write at n_ranks x this, so "
                         "few-writer plans (psum) price worse than the "
                         "slice-per-rank store (scatter)")
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--precision", action="append", default=None,
                    metavar="TOK",
                    help="restrict the precision axis (repeatable): fp32, "
                         "bf16, fp16, fp8_e4m3")
    ap.add_argument("--reduce", action="append", default=None,
                    metavar="TOK",
                    help="restrict the reduce axis (repeatable): psum, "
                         "scatter, scatter_bf16")
    ap.add_argument("--all", action="store_true",
                    help="include infeasible candidates in the table")
    ap.add_argument("--local", action="store_true",
                    help="search buildable single-device plans (small "
                         "default geometry, mesh-less 1x1 grid) instead of "
                         "a paper-scale projection")
    ap.add_argument("--measure", action="store_true",
                    help="with --local: time the top-3 built engines and "
                         "re-rank by wall clock")
    args = ap.parse_args(argv)
    if args.measure and not args.local:
        ap.error("--measure times built engines and needs --local "
                 "(grid-only projections have nothing to build)")

    system = _SYSTEMS[args.system]
    for flag, value in [("--pfs-read-gbs", args.pfs_read_gbs),
                        ("--pfs-write-gbs", args.pfs_write_gbs),
                        ("--rank-io-gbs", args.rank_io_gbs)]:
        if value is not None and value <= 0:
            ap.error(f"{flag} must be positive (got {value})")
    system = system.with_pfs(
        read=None if args.pfs_read_gbs is None else args.pfs_read_gbs * 1e9,
        write=(None if args.pfs_write_gbs is None
               else args.pfs_write_gbs * 1e9),
        rank_io=None if args.rank_io_gbs is None else args.rank_io_gbs * 1e9)
    hbm = int(args.hbm_gib * 2**30)
    axes = {}
    if args.precision:
        axes["precisions"] = tuple(args.precision)
    if args.reduce:
        axes["reduces"] = tuple(args.reduce)
    if args.local:
        g = default_geometry(32, n_proj=64)
        proposals = search_plans(
            g, None, system=system, hbm_bytes=hbm, top_k=args.top_k,
            include_infeasible=args.all, **axes)
        if args.measure:
            proposals = refine(g, proposals)
    else:
        g = paper_geometry(args.n, args.n_proj, args.detector)
        proposals = search_grids(
            g, args.devices, system=system, hbm_bytes=hbm,
            top_k=args.top_k, include_infeasible=args.all, **axes)

    print(f"plan search: {g.n_u}x{g.n_v} x {g.n_proj} proj -> {g.n_x}^3, "
          f"{args.devices if not args.local else 'local'} ranks, "
          f"{args.hbm_gib} GiB HBM, system={system.name} "
          f"(times in seconds)")
    print(_HEADER)
    for i, p in enumerate(proposals):
        print(_fmt_row(i, p, g))


def run(iters: int = 1, fast: bool = False):
    """benchmarks/run.py suite: top-5 modeled plans as CSV rows."""
    if fast:
        g = default_geometry(32, n_proj=64)
        devices = 4
    else:
        g = paper_geometry()
        devices = 256
    rows = []
    proposals = search_grids(g, devices, system=ABCI, top_k=5)
    for i, p in enumerate(proposals):
        grid = p.point.grid
        rows.append((
            f"plan_search/top{i}/{grid.r}x{grid.c}",
            p.predicted * 1e6,
            f"{p.predicted_gups(g):.1f}GUPS "
            + p.spec().replace(",", ";"),
        ))
    return rows


if __name__ == "__main__":
    main()
