"""Ranked plan-search table: the planner's answer for a deployment triple.

Prints the top-k candidate plans for a (geometry, device count, HBM budget)
with the full plan-aware Eq. 17-19 breakdown per row — the table the paper
builds by hand in §4.2/Table 5, produced by `repro.planner.search_grids`.

    PYTHONPATH=src python benchmarks/plan_search.py                # paper 4K, 256 ranks
    PYTHONPATH=src python benchmarks/plan_search.py --n 2048 --devices 64 \
        --hbm-gib 16 --system abci --top-k 12 --all
    PYTHONPATH=src python benchmarks/plan_search.py --local --measure
        # buildable single-device plans, top-3 timed for real
    PYTHONPATH=src python benchmarks/plan_search.py --local --calibrated \
        --measure --save-overlay overlay.json
        # seed a calibration from traced runs, re-rank with the fitted
        # overlay, report stock-vs-calibrated attribution + model error

Also runnable as a `benchmarks/run.py` suite (``--suite plan_search``) —
the suite additionally emits ranking-quality rows (was the stock / the
calibrated top-1 the measured-best plan?) into BENCH_plan_search.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from repro.core.geometry import default_geometry, paper_geometry
from repro.core.perf_model import ABCI, TPU_V5E
from repro.planner import admitted_impls, search_grids, search_plans
from repro.planner.cost import allgather_wire_bytes, reduce_wire_bytes
from repro.planner.measure import measure_proposal, refine

_SYSTEMS = {"abci": ABCI, "tpu": TPU_V5E}


def seed_calibration(g, proposals, system=ABCI, iters: int = 3,
                     top_k: int = 3):
    """Fit a MachineCalibration from traced runs of the leading buildable
    proposals, recorded into a HERMETIC per-invocation store (never the
    user's REPRO_CALIB_CACHE file — the report must reflect these runs).

    Returns (calibration, store, last_tracer) where last_tracer holds the
    final traced run of the top proposal (attribution report input).
    Incremental-schedule proposals are skipped: `build_traced` hands those
    back as sessions, and their per-delta stage timings flow into the
    default store during real streaming use instead."""
    from repro.filecache import JsonFileCache
    from repro.obs.trace import Tracer, set_tracer
    from repro.planner.calibrate import CalibrationStore, set_default_store

    store = CalibrationStore(cache=JsonFileCache(
        "REPRO_CALIB_CACHE", "calibration_store.json",
        path=os.path.join(tempfile.mkdtemp(prefix="repro-cal-"),
                          "store.json")))
    prev_store = set_default_store(store)
    last_tracer = None
    try:
        proj = np.asarray(np.zeros(g.proj_shape(), np.float32))
        seeded = 0
        for p in proposals:
            if seeded >= top_k:
                break
            if p.plan is None or p.point.schedule == "incremental":
                continue
            seeded += 1
            fdk = p.plan.build_traced()
            for _ in range(max(1, iters)):
                prev = set_tracer(Tracer(enabled=True))
                try:
                    jax.block_until_ready(fdk(proj))
                    if seeded == 1:
                        from repro.obs.trace import get_tracer
                        last_tracer = get_tracer()
                finally:
                    set_tracer(prev)
    finally:
        set_default_store(prev_store)
    return store.fit(system=system), store, last_tracer


def _fmt_row(i, p, g):
    b = p.breakdown
    pt = p.point
    sched = pt.schedule
    if sched != "fused":
        sched += f"/{pt.n_steps}"
    if pt.y_chunks:
        sched += f"x{pt.y_chunks}"
    stat = "ok" if p.feasible else f"INFEASIBLE ({p.reason})"
    cols = [
        f"{i:>2}", f"{pt.grid.r}x{pt.grid.c}", f"{sched:<14}",
        f"{pt.reduce:<12}", f"{pt.precision:<8}", f"{pt.impl:<10}",
        f"{b.t_read:7.2f}", f"{b.t_flt:7.2f}", f"{b.t_allgather:7.2f}",
        f"{b.t_bp:7.2f}", f"{b.t_compute:7.2f}", f"{b.t_write:7.2f}",
        f"{b.t_post:7.2f}", f"{b.t_runtime:8.2f}",
        f"{p.predicted_gups(g):9.1f}",
        f"{p.footprint.total / 2**30:6.2f}",
        # Wire GB the two collectives actually move under this plan's
        # stream codec / reduce mode (the communication-volume story the
        # codec layer exists for): fp8 quarters ag_GB, scatter_bf16 halves
        # rd_GB — visible next to the time columns so ranking flips under
        # --pfs/--rank-io throttles are explainable.
        f"{allgather_wire_bytes(g, pt) / 1e9:8.1f}",
        f"{reduce_wire_bytes(g, pt) / 1e9:8.1f}",
    ]
    if p.measured is not None:
        cols.append(f"meas={p.measured:.3f}s")
    cols.append(stat)
    return "  ".join(cols)


_HEADER = ("  #  RxC    schedule        reduce        prec      impl      "
           "   t_read   t_flt    t_ag     t_bp   t_cmp   t_wr     t_post"
           "     t_run      GUPS    GiB     ag_GB    rd_GB  status")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="perf-model-driven ReconstructionPlan search")
    ap.add_argument("--n", type=int, default=4096, help="volume edge N_x=N_y=N_z")
    ap.add_argument("--n-proj", type=int, default=4096)
    ap.add_argument("--detector", type=int, default=2048,
                    help="detector edge N_u=N_v")
    ap.add_argument("--devices", type=int, default=256,
                    help="deployment size to plan for (rank count)")
    ap.add_argument("--system", choices=sorted(_SYSTEMS), default="abci")
    ap.add_argument("--hbm-gib", type=float, default=16.0,
                    help="per-device HBM budget")
    ap.add_argument("--pfs-read-gbs", type=float, default=None,
                    help="override the system's aggregate PFS read "
                         "bandwidth (GB/s) — the T_read term; throttle to "
                         "see load-bound rankings")
    ap.add_argument("--pfs-write-gbs", type=float, default=None,
                    help="override the aggregate PFS write bandwidth "
                         "(GB/s) — the T_write term")
    ap.add_argument("--rank-io-gbs", type=float, default=None,
                    help="per-rank PFS link bandwidth (GB/s): caps "
                         "T_read/T_write at n_ranks x this, so "
                         "few-writer plans (psum) price worse than the "
                         "slice-per-rank store (scatter)")
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--precision", action="append", default=None,
                    metavar="TOK",
                    help="restrict the precision axis (repeatable): fp32, "
                         "bf16, fp16, fp8_e4m3")
    ap.add_argument("--reduce", action="append", default=None,
                    metavar="TOK",
                    help="restrict the reduce axis (repeatable): psum, "
                         "scatter, scatter_bf16")
    ap.add_argument("--all", action="store_true",
                    help="include infeasible candidates in the table")
    ap.add_argument("--local", action="store_true",
                    help="search buildable single-device plans (small "
                         "default geometry, mesh-less 1x1 grid) instead of "
                         "a paper-scale projection")
    ap.add_argument("--measure", action="store_true",
                    help="with --local: time the top-3 built engines and "
                         "re-rank by wall clock")
    ap.add_argument("--calibrated", action="store_true",
                    help="with --local: fit a calibration overlay from "
                         "traced runs of the leading stock proposals, "
                         "re-rank with it, and print the stock-vs-"
                         "calibrated attribution report + aggregate model "
                         "error (planner/calibrate.py)")
    ap.add_argument("--cal-iters", type=int, default=4,
                    help="traced runs per seeded proposal for --calibrated "
                         "(default 4: enough to reject compile warmup)")
    ap.add_argument("--save-overlay", default=None, metavar="PATH",
                    help="with --calibrated: write the fitted "
                         "MachineCalibration as JSON (nightly CI artifact)")
    args = ap.parse_args(argv)
    if args.measure and not args.local:
        ap.error("--measure times built engines and needs --local "
                 "(grid-only projections have nothing to build)")
    if args.calibrated and not args.local:
        ap.error("--calibrated fits from traced runs of built engines and "
                 "needs --local")
    if args.save_overlay and not args.calibrated:
        ap.error("--save-overlay needs --calibrated (nothing fitted "
                 "otherwise)")

    system = _SYSTEMS[args.system]
    for flag, value in [("--pfs-read-gbs", args.pfs_read_gbs),
                        ("--pfs-write-gbs", args.pfs_write_gbs),
                        ("--rank-io-gbs", args.rank_io_gbs)]:
        if value is not None and value <= 0:
            ap.error(f"{flag} must be positive (got {value})")
    system = system.with_pfs(
        read=None if args.pfs_read_gbs is None else args.pfs_read_gbs * 1e9,
        write=(None if args.pfs_write_gbs is None
               else args.pfs_write_gbs * 1e9),
        rank_io=None if args.rank_io_gbs is None else args.rank_io_gbs * 1e9)
    hbm = int(args.hbm_gib * 2**30)
    axes = {}
    if args.precision:
        axes["precisions"] = tuple(args.precision)
    if args.reduce:
        axes["reduces"] = tuple(args.reduce)
    if args.local:
        g = default_geometry(32, n_proj=64)
        proposals = search_plans(
            g, None, system=system, hbm_bytes=hbm, top_k=args.top_k,
            include_infeasible=args.all, **axes)
        if args.measure:
            proposals = refine(g, proposals)
    else:
        g = paper_geometry(args.n, args.n_proj, args.detector)
        proposals = search_grids(
            g, args.devices, system=system, hbm_bytes=hbm,
            top_k=args.top_k, include_infeasible=args.all, **axes)

    print(f"plan search: {g.n_u}x{g.n_v} x {g.n_proj} proj -> {g.n_x}^3, "
          f"{args.devices if not args.local else 'local'} ranks, "
          f"{args.hbm_gib} GiB HBM, system={system.name} "
          f"(times in seconds)")
    print(_HEADER)
    for i, p in enumerate(proposals):
        print(_fmt_row(i, p, g))

    if args.calibrated:
        from repro.obs.attribution import (aggregate_error, compare,
                                           render_report)
        cal, store, tracer = seed_calibration(
            g, proposals, system=system, iters=args.cal_iters)
        if cal.is_empty:
            print(f"calibration: fit is empty after {store.n_samples()} "
                  "samples — stock ranking stands", file=sys.stderr)
            sys.exit(1)
        print(f"\ncalibration: {cal.summary()}")
        recal = search_plans(
            g, None, system=system, hbm_bytes=hbm, top_k=args.top_k,
            include_infeasible=args.all, calibration=cal, **axes)
        if args.measure:
            recal = refine(g, recal)
        print("\ncalibrated ranking (fitted overlay applied):")
        print(_HEADER)
        for i, p in enumerate(recal):
            print(_fmt_row(i, p, g))
        if tracer is not None:
            top = next(p for p in proposals
                       if p.plan is not None
                       and p.point.schedule != "incremental")
            rows_stock = compare(top.plan, tracer, system=system)
            rows_cal = compare(top.plan, tracer, system=system,
                               calibration=cal)
            e_s, e_c = aggregate_error(rows_stock), aggregate_error(rows_cal)
            fmt = lambda e: "-" if e is None else f"{e:.4f}"
            print(f"\nattribution of the traced {top.spec()} run "
                  f"(stock model):")
            print(render_report(rows_stock))
            print("\nsame trace, calibrated model:")
            print(render_report(rows_cal))
            print(f"\naggregate model error: stock={fmt(e_s)} "
                  f"calibrated={fmt(e_c)}")
        if args.save_overlay:
            with open(args.save_overlay, "w") as f:
                json.dump(cal.to_dict(), f, indent=1)
                f.write("\n")
            print(f"# overlay saved: {args.save_overlay}")


def run(iters: int = 1, fast: bool = False):
    """benchmarks/run.py suite: top-5 modeled plans, then the
    ranking-quality rows the calibration loop is judged by — was the stock
    top-1 / the calibrated top-1 actually the measured-best plan? Yields
    one case group per part (per-case t_stage in BENCH_plan_search.json)."""
    if fast:
        g = default_geometry(32, n_proj=64)
        devices = 4
    else:
        g = paper_geometry()
        devices = 256
    rows = []
    proposals = search_grids(g, devices, system=ABCI, top_k=5)
    for i, p in enumerate(proposals):
        grid = p.point.grid
        rows.append((
            f"plan_search/top{i}/{grid.r}x{grid.c}",
            p.predicted * 1e6,
            f"{p.predicted_gups(g):.1f}GUPS "
            + p.spec().replace(",", ";"),
        ))
    yield rows

    # -- ranking quality: predicted->measured loop on a buildable problem --
    gl = default_geometry(16, n_proj=8) if fast \
        else default_geometry(32, n_proj=64)
    # Same impl admission as auto_plan: the seeded runs only cover the
    # stock top plans, so an impl with no fitted evidence must not win
    # the calibrated ranking on its (unfalsified) stock factor.
    stock = search_plans(gl, None, system=ABCI, top_k=4,
                         impls=admitted_impls(None))
    cal, _, _ = seed_calibration(gl, stock, iters=max(3, iters + 2))
    calibrated = search_plans(gl, None, system=ABCI, top_k=4,
                              impls=admitted_impls(cal),
                              calibration=cal) if not cal.is_empty else stock
    cands = {}
    for p in stock + calibrated:
        cands.setdefault(p.spec(), p)
    meas = {spec: measure_proposal(gl, p, iters=max(2, iters))
            for spec, p in cands.items()}
    best = min(meas, key=meas.get)
    s_spec, c_spec = stock[0].spec(), calibrated[0].spec()
    rows = [
        (f"plan_search/ranking/stock_top1", meas[s_spec] * 1e6,
         f"top1_hit={s_spec == best} spec={s_spec.replace(',', ';')}"),
        (f"plan_search/ranking/calibrated_top1", meas[c_spec] * 1e6,
         f"top1_hit={c_spec == best} fitted={not cal.is_empty} "
         f"speedup_vs_stock={meas[s_spec] / meas[c_spec]:.2f}x "
         f"spec={c_spec.replace(',', ';')} "
         f"{'OK' if meas[c_spec] <= meas[s_spec] else 'MISS'}"),
        (f"plan_search/ranking/measured_best", meas[best] * 1e6,
         f"n_candidates={len(cands)} spec={best.replace(',', ';')}"),
    ]
    yield rows


if __name__ == "__main__":
    main()
