"""Traced-reconstruction smoke: produce and VALIDATE a Perfetto trace.

The CI fast tier runs this on a 16^3 auto-planned reconstruction (source ->
traced engine -> sink) and uploads the trace JSON as a workflow artifact —
every PR ships a loadable stage-level trace of the pipeline it built, and
the run fails if the trace is malformed or any engine stage went dark:

    python benchmarks/export_trace.py --out trace_ci.json
    python benchmarks/export_trace.py --out t.json --n 32 --plan \
        "schedule=pipelined,n_steps=2"

Validation (exit nonzero on any miss):
  * the file parses as Chrome/Perfetto ``trace_event`` JSON;
  * every complete event carries the required keys (ph/ts/dur/name/pid/tid);
  * >= 1 span per engine stage of obs.attribution.STAGE_FIELDS;
  * `attribution.compare` yields a row for every PerfBreakdown stage and
    every nonzero-predicted stage was measured.

Prints the predicted-vs-measured attribution report to stdout.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

REQUIRED_KEYS = {"ph", "ts", "dur", "name", "pid", "tid"}


def run_traced(n: int, n_proj: int, spec: str, out_path: str) -> dict:
    """One traced source->engine->sink reconstruction; saves and returns
    the exported trace object."""
    import numpy as np
    from repro import obs
    from repro.core.geometry import default_geometry
    from repro.core.phantom import forward_project
    from repro.core.plan import plan_from_spec
    from repro.io import ProjectionSource, VolumeSink
    from repro.obs.trace import Tracer, set_tracer

    g = default_geometry(n, n_proj=n_proj)
    proj = np.asarray(forward_project(g))
    tmp = tempfile.mkdtemp(prefix="repro-trace-smoke-")
    src = ProjectionSource.write(os.path.join(tmp, "proj"), proj,
                                 chunks=(1, 1, 1))
    sink = VolumeSink(os.path.join(tmp, "vol"))
    plan = plan_from_spec(g, spec)
    prev = set_tracer(Tracer(enabled=True))
    try:
        fdk = plan.build_traced(source=src, sink=sink)
        fdk()
        tracer = obs.get_tracer()
        tracer.save(out_path)
        report = obs.attribution.render_report(
            obs.attribution.compare(plan, tracer))
    finally:
        set_tracer(prev)
    print(f"plan: {plan.describe()}")
    print(report)
    return json.load(open(out_path))


def validate(trace: dict) -> list:
    """Schema + coverage checks; returns a list of failure strings."""
    from repro.obs.attribution import STAGE_FIELDS
    failures = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for ev in events:
        if ev.get("ph") != "X":
            continue
        missing = REQUIRED_KEYS - set(ev)
        if missing:
            failures.append(f"event {ev.get('name')!r} missing {missing}")
    for stage in STAGE_FIELDS:
        n = sum(1 for e in events
                if e.get("ph") == "X" and e.get("name") == stage)
        if n < 1:
            failures.append(f"no span for engine stage {stage!r}")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="traced-reconstruction smoke + trace validation")
    ap.add_argument("--out", default="trace_ci.json",
                    help="trace JSON output path (default trace_ci.json)")
    ap.add_argument("--n", type=int, default=16,
                    help="cubic volume size (default 16)")
    ap.add_argument("--n-proj", type=int, default=8,
                    help="projection count (default 8)")
    ap.add_argument("--plan", default="auto", metavar="SPEC",
                    help="plan spec (default 'auto': planner search)")
    args = ap.parse_args(argv)

    trace = run_traced(args.n, args.n_proj, args.plan, args.out)
    failures = validate(trace)
    n_spans = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
    if failures:
        for f in failures:
            print(f"TRACE INVALID: {f}", file=sys.stderr)
        sys.exit(1)
    print(f"trace OK: {args.out} ({n_spans} spans, all engine stages "
          "covered)")


if __name__ == "__main__":
    main()
