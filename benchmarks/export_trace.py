"""Traced-reconstruction smoke: produce, VALIDATE, and drift-check a trace.

The CI fast tier runs this on a 16^3 auto-planned reconstruction (source ->
traced engine -> sink) and uploads the trace JSON as a workflow artifact —
every PR ships a loadable stage-level trace of the pipeline it built, and
the run fails if the trace is malformed or any engine stage went dark:

    python benchmarks/export_trace.py --out trace_ci.json
    python benchmarks/export_trace.py --out t.json --n 32 --plan \
        "schedule=pipelined,n_steps=2"
    python benchmarks/export_trace.py --iters 4 \
        --check-drift benchmarks/drift_baseline.json

Validation (exit nonzero on any miss):
  * the file parses as Chrome/Perfetto ``trace_event`` JSON;
  * every complete event carries the required keys (ph/ts/dur/name/pid/tid);
  * >= 1 span per engine stage of obs.attribution.STAGE_FIELDS;
  * `attribution.compare` yields a row for every PerfBreakdown stage and
    every nonzero-predicted stage was measured.

``--iters N`` repeats the traced run N times; every run deposits its
per-stage timings into the process-default CalibrationStore
(planner/calibrate.py), so the samples survive compile-warmup outlier
rejection (the first run's spans include jit compilation).

``--check-drift [BASELINE]`` is the drift alarm (ISSUE: close the
predicted->measured loop): fit the calibration overlay from the runs just
recorded (a hermetic per-invocation store — never the user's cache), price
the SAME trace with the stock model and with the fitted overlay, and
compare the time-weighted aggregate model errors
(obs.attribution.aggregate_error) against the committed baseline:

  * the fit must produce a non-empty overlay (enough samples per stage);
  * calibrated aggregate error must be <= baseline["calibrated_max"];
  * when the stock error exceeds baseline["stock_floor_for_drop"], the
    calibrated error must be strictly below the stock error — the whole
    point of the loop is that fitting HELPS.

Prints the stock and calibrated predicted-vs-measured attribution reports
to stdout; exits nonzero on any validation or drift failure.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

REQUIRED_KEYS = {"ph", "ts", "dur", "name", "pid", "tid"}

DRIFT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "drift_baseline.json")


def setup_problem(n: int, n_proj: int, spec: str):
    """(plan, source, sink) for the smoke geometry — resolved ONCE so every
    --iters repetition runs the identical plan (an accumulating calibration
    store must not flip the auto-planner's pick mid-loop)."""
    import numpy as np
    from repro.core.geometry import default_geometry
    from repro.core.phantom import forward_project
    from repro.core.plan import plan_from_spec
    from repro.io import ProjectionSource, VolumeSink

    g = default_geometry(n, n_proj=n_proj)
    proj = np.asarray(forward_project(g))
    tmp = tempfile.mkdtemp(prefix="repro-trace-smoke-")
    src = ProjectionSource.write(os.path.join(tmp, "proj"), proj,
                                 chunks=(1, 1, 1))
    sink = VolumeSink(os.path.join(tmp, "vol"))
    return plan_from_spec(g, spec), src, sink


def run_traced(plan, src, sink, out_path: str, quiet: bool = False) -> dict:
    """One traced source->engine->sink reconstruction on a FRESH tracer;
    saves and returns the exported trace object. Each call deposits its
    stage timings into the default CalibrationStore (build_traced's
    record hook fires when the tracer is enabled)."""
    from repro import obs
    from repro.obs.trace import Tracer, set_tracer

    prev = set_tracer(Tracer(enabled=True))
    try:
        fdk = plan.build_traced(source=src, sink=sink)
        fdk()
        tracer = obs.get_tracer()
        tracer.save(out_path)
        if not quiet:
            print(f"plan: {plan.describe()}")
            print(obs.attribution.render_report(
                obs.attribution.compare(plan, tracer)))
    finally:
        set_tracer(prev)
    return json.load(open(out_path))


def validate(trace: dict) -> list:
    """Schema + coverage checks; returns a list of failure strings."""
    from repro.obs.attribution import STAGE_FIELDS
    failures = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for ev in events:
        if ev.get("ph") != "X":
            continue
        missing = REQUIRED_KEYS - set(ev)
        if missing:
            failures.append(f"event {ev.get('name')!r} missing {missing}")
    for stage in STAGE_FIELDS:
        n = sum(1 for e in events
                if e.get("ph") == "X" and e.get("name") == stage)
        if n < 1:
            failures.append(f"no span for engine stage {stage!r}")
    return failures


def check_drift(plan, trace, store, baseline_path: str) -> list:
    """The drift alarm: stock vs calibrated aggregate model error on the
    same trace, gated by the committed baseline. Returns failure strings
    (empty = healthy); prints both attribution tables."""
    from repro.obs.attribution import aggregate_error, compare, render_report

    with open(baseline_path) as f:
        baseline = json.load(f)
    cal = store.fit()
    if cal.is_empty:
        return [f"calibration fit is empty after "
                f"{store.n_samples()} recorded samples — not enough "
                f"per-stage evidence to close the loop (raise --iters?)"]

    rows_stock = compare(plan, trace)
    rows_cal = compare(plan, trace, calibration=cal)
    e_stock = aggregate_error(rows_stock)
    e_cal = aggregate_error(rows_cal)
    print(f"\ncalibration: {cal.summary()}")
    print("\n-- stock model --")
    print(render_report(rows_stock))
    print("\n-- calibrated model --")
    print(render_report(rows_cal))
    fmt = lambda e: "-" if e is None else f"{e:.4f}"
    print(f"\naggregate model error: stock={fmt(e_stock)} "
          f"calibrated={fmt(e_cal)} "
          f"(baseline calibrated_max={baseline['calibrated_max']})")

    failures = []
    if e_cal is None:
        failures.append("calibrated attribution has no measurable rows")
        return failures
    if e_cal > baseline["calibrated_max"]:
        failures.append(
            f"calibrated aggregate model error {e_cal:.4f} exceeds "
            f"baseline calibrated_max={baseline['calibrated_max']} — the "
            f"fitted overlay no longer explains this host's measurements")
    floor = baseline.get("stock_floor_for_drop", 0.0)
    if e_stock is not None and e_stock > floor and e_cal >= e_stock:
        failures.append(
            f"calibration did not improve on the stock model "
            f"(stock={e_stock:.4f}, calibrated={e_cal:.4f}) although stock "
            f"error is above the {floor} floor — the fit is not closing "
            f"the predicted->measured loop")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="traced-reconstruction smoke + trace validation")
    ap.add_argument("--out", default="trace_ci.json",
                    help="trace JSON output path (default trace_ci.json)")
    ap.add_argument("--n", type=int, default=16,
                    help="cubic volume size (default 16)")
    ap.add_argument("--n-proj", type=int, default=8,
                    help="projection count (default 8)")
    ap.add_argument("--plan", default="auto", metavar="SPEC",
                    help="plan spec (default 'auto': planner search)")
    ap.add_argument("--iters", type=int, default=1,
                    help="traced-run repetitions feeding the calibration "
                         "store (default 1; >=4 recommended with "
                         "--check-drift so compile warmup is rejected as "
                         "an outlier)")
    ap.add_argument("--check-drift", nargs="?", const=DRIFT_BASELINE,
                    default=None, metavar="BASELINE",
                    help="fit a calibration from the recorded runs and "
                         "fail if its aggregate model error regresses "
                         f"past the committed baseline (default "
                         f"{DRIFT_BASELINE})")
    args = ap.parse_args(argv)

    store = None
    if args.check_drift is not None:
        # Hermetic per-invocation store: the drift verdict must come from
        # THIS run's samples, not whatever the user's cache accumulated.
        from repro.filecache import JsonFileCache
        from repro.planner.calibrate import CalibrationStore, \
            set_default_store
        store_path = os.path.join(
            tempfile.mkdtemp(prefix="repro-drift-"), "store.json")
        store = CalibrationStore(cache=JsonFileCache(
            "REPRO_CALIB_CACHE", "calibration_store.json", path=store_path))
        set_default_store(store)

    plan, src, sink = setup_problem(args.n, args.n_proj, args.plan)
    trace = None
    for i in range(max(1, args.iters)):
        last = i == max(1, args.iters) - 1
        trace = run_traced(plan, src, sink, args.out, quiet=not last)

    failures = validate(trace)
    if not failures and args.check_drift is not None:
        failures = check_drift(plan, trace, store, args.check_drift)
    n_spans = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
    if failures:
        for f in failures:
            print(f"TRACE INVALID: {f}", file=sys.stderr)
        sys.exit(1)
    drift = "" if args.check_drift is None else ", drift check passed"
    print(f"trace OK: {args.out} ({n_spans} spans, all engine stages "
          f"covered{drift})")


if __name__ == "__main__":
    main()
