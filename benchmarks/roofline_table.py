"""Render the EXPERIMENTS.md roofline table from artifacts/dryrun.jsonl."""
from __future__ import annotations

import json
import os
from collections import OrderedDict

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun.jsonl")


def load(path: str = ART):
    recs = OrderedDict()
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # newest wins
    return recs


def fmt_row(r) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — "
                f"| SKIP | — |")
    ur = r.get("useful_ratio")
    rf = r.get("roofline_fraction")
    return ("| {arch} | {shape} | {mesh} | {tc:.4f} | {tm:.4f} | {tl:.4f} "
            "| {dom} | {ur} | {rf} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        tc=r["t_compute_s"], tm=r["t_memory_s"], tl=r["t_collective_s"],
        dom=r["dominant"],
        ur=f"{ur:.2f}" if ur else "—",
        rf=f"{rf:.2f}" if rf is not None else "—",
    )


def render(path: str = ART) -> str:
    recs = load(path)
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | useful 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs.values():
        lines.append(fmt_row(r))
    return "\n".join(lines)


def run(iters: int = 0, fast: bool = False):
    # Reads pre-computed dry-run artifacts — no compute; `fast` is a no-op
    # accepted for driver uniformity.
    recs = load()
    rows = []
    for (arch, shape, mesh), r in recs.items():
        if r["status"] != "ok":
            continue
        rows.append((
            f"roofline/{arch}/{shape}/{mesh}",
            r["t_compute_s"] * 1e6,
            f"dom={r['dominant']},frac={r.get('roofline_fraction'):.2f}"
            if r.get("roofline_fraction") is not None else
            f"dom={r['dominant']}",
        ))
    return rows


if __name__ == "__main__":
    print(render())
