"""Paper Table 5 + Fig. 5: strong/weak scaling via the performance model.

Reproduces the paper's projected-peak rows with THEIR system constants
(ABCI: V100, GPFS, EDR IB) and reports the relative error of our Eq. 8-19
implementation against the T_compute values printed in Table 5 — this is the
validation of the reproduction's performance model. Also projects the same
problems onto the TPU v5e target constants.
"""
from __future__ import annotations

from repro.core.distributed import IFDKGrid
from repro.core.geometry import CBCTGeometry, paper_geometry
from repro.core.perf_model import ABCI, TPU_V5E, gups_end_to_end, predict

# Paper Table 5: (volume, N_gpus) -> measured T_compute seconds
TABLE5 = {
    (4096, 32): 70.2,
    (4096, 64): 35.6,
    (4096, 128): 18.9,
    (4096, 256): 10.2,
    (8192, 256): 101.3,
    (8192, 512): 53.1,
    (8192, 1024): 29.7,
    (8192, 2048): 17.2,
}


def _problem(n_out: int) -> CBCTGeometry:
    return paper_geometry(n_out)


def run(iters: int = 0, fast: bool = False):
    # Pure performance-model arithmetic — already instant, so `fast` only
    # trims the row count (one Table-5 point instead of the full sweep).
    rows = []
    table5 = dict(list(TABLE5.items())[:1]) if fast else TABLE5
    for (n_out, n_gpus), measured in table5.items():
        g = _problem(n_out)
        r = 32 if n_out == 4096 else 256
        grid = IFDKGrid(r=r, c=n_gpus // r)
        b = predict(g, grid, ABCI)
        rel = abs(b.t_compute - measured) / measured
        rows.append((
            f"table5/{n_out}^3/{n_gpus}gpus/model_T_compute",
            b.t_compute * 1e6,
            f"paper={measured}s,rel_err={rel:.2f},delta={b.delta:.2f}",
        ))
    # Fig. 5 end-to-end runtime projections on paper hardware and TPU target
    for n_out, n_dev in [(4096, 256), (8192, 2048)]:
        g = _problem(n_out)
        r = 32 if n_out == 4096 else 256
        grid = IFDKGrid(r=r, c=n_dev // r)
        for sysc in (ABCI, TPU_V5E):
            b = predict(g, grid, sysc)
            rows.append((
                f"fig5/{n_out}^3/{n_dev}dev/{sysc.name}/T_runtime",
                b.t_runtime * 1e6,
                f"gups={gups_end_to_end(g, b):.0f}",
            ))
    return rows
