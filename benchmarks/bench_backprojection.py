"""Paper Table 4: back-projection kernel throughput (GUPS) across problem
sizes and implementations.

On this CPU container the absolute GUPS are CPU numbers; the *relative*
comparison reproduces the paper's claim: the factorized Alg. 4 ("L1-Tran")
beats the reference Alg. 2 ("RTK-32") via the 1/6 coordinate-cost reduction
and the transposed layout. Host-device copies are excluded, as in the paper.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.backprojection import (
    backproject_factorized, backproject_reference,
)
from repro.core.fdk import gups
from repro.core.geometry import CBCTGeometry
from repro.kernels.backproject.ops import backproject_pallas

# (n_u=n_v, n_proj, n_out) — scaled-down analogues of Table 4 rows; alpha is
# the paper's input/output ratio.
CASES = [
    (64, 128, 16),    # alpha = 128
    (64, 128, 32),    # alpha = 16
    (64, 128, 64),    # alpha = 2
    (128, 128, 32),   # alpha = 64
    (128, 128, 64),   # alpha = 8
]

IMPLS = {
    "reference(Alg2/RTK-32)": backproject_reference,
    "factorized(Alg4/L1-Tran)": backproject_factorized,
    "pallas(interpret)": backproject_pallas,
}


def _case_geometry(n_det: int, n_proj: int, n_out: int) -> CBCTGeometry:
    return CBCTGeometry(
        n_proj=n_proj, n_u=n_det, n_v=n_det,
        d_u=4.8 / n_det, d_v=4.8 / n_det, d=4.0, dsd=8.0,
        n_x=n_out, n_y=n_out, n_z=n_out,
        d_x=2.0 / n_out, d_y=2.0 / n_out, d_z=2.0 / n_out,
    )


def run(iters: int = 2):
    import numpy as np
    from repro.core.geometry import projection_matrices
    rows = []
    rng = np.random.default_rng(0)
    for n_det, n_proj, n_out in CASES:
        g = _case_geometry(n_det, n_proj, n_out)
        pm = jnp.asarray(projection_matrices(g))
        q = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        alpha = (n_det * n_det * n_proj) / (n_out ** 3)
        for name, fn in IMPLS.items():
            if name.startswith("pallas") and n_out > 32:
                continue  # interpret mode is python-speed; keep it small
            out = fn(pm, q, g.n_x, g.n_y, g.n_z)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn(pm, q, g.n_x, g.n_y, g.n_z))
            dt = (time.perf_counter() - t0) / iters
            rows.append((
                f"table4/{n_det}^2x{n_proj}->{n_out}^3/a={alpha:.0f}/{name}",
                dt * 1e6, f"{gups(g, dt):.3f}GUPS",
            ))
    return rows
