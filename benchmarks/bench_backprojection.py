"""Paper Table 4: back-projection kernel throughput (GUPS) across problem
sizes and implementations — plus the storage-precision / autotuner report.

On this CPU container the absolute GUPS are CPU numbers; the *relative*
comparison reproduces the paper's claim: the factorized Alg. 4 ("L1-Tran")
beats the reference Alg. 2 ("RTK-32") via the 1/6 coordinate-cost reduction
and the transposed layout. Host-device copies are excluded, as in the paper.

CLI (python benchmarks/bench_backprojection.py):
  --dtype {fp32,bf16,fp16,fp8_e4m3}
                             stream codec of the projection stream; the
                             report compares it against fp32 and shows the
                             VMEM-tuned vs naive-default block shapes.
  --budget BYTES             VMEM budget handed to the autotuner.
  --iters N                  timing iterations per measurement.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.backprojection import (
    backproject_factorized, backproject_reference,
)
from repro.core.fdk import gups
from repro.core.geometry import CBCTGeometry
from repro.core.precision import Precision
from repro.kernels.backproject import tune
from repro.kernels.backproject.ops import backproject_pallas

# (n_u=n_v, n_proj, n_out) — scaled-down analogues of Table 4 rows; alpha is
# the paper's input/output ratio.
CASES = [
    (64, 128, 16),    # alpha = 128
    (64, 128, 32),    # alpha = 16
    (64, 128, 64),    # alpha = 2
    (128, 128, 32),   # alpha = 64
    (128, 128, 64),   # alpha = 8
]

IMPLS = {
    "reference(Alg2/RTK-32)": backproject_reference,
    "factorized(Alg4/L1-Tran)": backproject_factorized,
    "pallas(interpret)": backproject_pallas,
}


def _case_geometry(n_det: int, n_proj: int, n_out: int) -> CBCTGeometry:
    return CBCTGeometry(
        n_proj=n_proj, n_u=n_det, n_v=n_det,
        d_u=4.8 / n_det, d_v=4.8 / n_det, d=4.0, dsd=8.0,
        n_x=n_out, n_y=n_out, n_z=n_out,
        d_x=2.0 / n_out, d_y=2.0 / n_out, d_z=2.0 / n_out,
    )


def _naive_block(n: int, target: int = 8) -> int:
    """The pre-autotuner default: largest divisor of n that is <= target."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _time(fn, iters):
    jax.block_until_ready(fn())  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def run(iters: int = 2, fast: bool = False):
    import numpy as np
    from repro.core.geometry import projection_matrices
    rows = []
    rng = np.random.default_rng(0)
    cases = CASES[:1] if fast else CASES  # smoke: one tiny case
    for n_det, n_proj, n_out in cases:
        g = _case_geometry(n_det, n_proj, n_out)
        pm = jnp.asarray(projection_matrices(g))
        q = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        alpha = (n_det * n_det * n_proj) / (n_out ** 3)
        for name, fn in IMPLS.items():
            if name.startswith("pallas") and n_out > 32:
                continue  # interpret mode is python-speed; keep it small
            dt = _time(lambda: fn(pm, q, g.n_x, g.n_y, g.n_z), iters)
            rows.append((
                f"table4/{n_det}^2x{n_proj}->{n_out}^3/a={alpha:.0f}/{name}",
                dt * 1e6, f"{gups(g, dt):.3f}GUPS",
            ))
    return rows


def run_precision(dtype_name: str = "fp16", iters: int = 2,
                  budget: int | None = None):
    """Tuned-vs-default blocks and fp32-vs-low-precision GUPS for the Pallas
    kernel (the tentpole report: storage dtype halves the qt VMEM term, the
    autotuner turns that into bigger batches under the same budget)."""
    import numpy as np
    from repro.core.geometry import projection_matrices
    prec = Precision(dtype_name)
    budget = tune.DEFAULT_VMEM_BUDGET if budget is None else budget
    rows = []
    rng = np.random.default_rng(0)
    for n_det, n_proj, n_out in CASES:
        if n_out > 32:
            continue  # interpret mode is python-speed; keep it small
        g = _case_geometry(n_det, n_proj, n_out)
        pm = jnp.asarray(projection_matrices(g))
        q32 = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        # the stream codec's wire format (scaled codecs carry a sidecar)
        q_lp, sc_lp = prec.codec.encode(q32)
        case = f"precision/{n_det}^2x{n_proj}->{n_out}^3"

        variants = [("fp32", q32, None)]
        if prec.storage != "fp32":
            variants.append((prec.storage, q_lp, sc_lp))
        for tag, q, sc in variants:
            cfg = tune.autotune(g.n_x, g.n_y, g.n_z, g.n_proj, g.n_u, g.n_v,
                                qt_dtype=q.dtype, budget=budget, measure=True)
            assert cfg.vmem <= budget, (cfg, budget)
            dt = _time(
                lambda: backproject_pallas(
                    pm, q, g.n_x, g.n_y, g.n_z,
                    bi=cfg.bi, bj=cfg.bj, bs=cfg.bs, scales=sc,
                ),
                iters,
            )
            rows.append((
                f"{case}/{tag}/tuned(bi={cfg.bi},bj={cfg.bj},bs={cfg.bs},"
                f"vmem={cfg.vmem}B<=budget={budget}B)",
                dt * 1e6, f"{gups(g, dt):.3f}GUPS",
            ))

        nb = (_naive_block(g.n_x), _naive_block(g.n_y),
              _naive_block(g.n_proj))
        dt = _time(
            lambda: backproject_pallas(pm, q_lp, g.n_x, g.n_y, g.n_z,
                                       bi=nb[0], bj=nb[1], bs=nb[2],
                                       scales=sc_lp),
            iters,
        )
        rows.append((
            f"{case}/{prec.storage}/default(bi={nb[0]},bj={nb[1]},bs={nb[2]})",
            dt * 1e6, f"{gups(g, dt):.3f}GUPS",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dtype", default="fp16",
                    choices=["fp32", "bf16", "fp16", "fp8_e4m3"],
                    help="storage dtype of the projection stream")
    ap.add_argument("--budget", type=int, default=None,
                    help="VMEM budget in bytes (default REPRO_BP_VMEM_BUDGET)")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--table4", action="store_true",
                    help="also run the full Table-4 impl sweep")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows = run_precision(args.dtype, args.iters, args.budget)
    if args.table4:
        rows += run(args.iters)
    for row, us, derived in rows:
        print(f"{row},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
