# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_backprojection, bench_end_to_end, bench_filtering,
        bench_scaling_model, roofline_table,
    )
    suites = [
        ("table4", bench_backprojection.run),     # BP kernel GUPS sweep
        ("filtering", bench_filtering.run),       # TH_flt micro-benchmark
        ("table5_fig5", bench_scaling_model.run),  # scaling model vs paper
        ("fig6", bench_end_to_end.run),           # end-to-end GUPS
        ("roofline", roofline_table.run),         # dry-run roofline terms
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row, us, derived in fn():
                print(f"{row},{us:.1f},{derived}")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},nan,FAILED")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
