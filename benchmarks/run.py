# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   python benchmarks/run.py                 # full sweep, all suites
#   python benchmarks/run.py --suite fig6    # one suite (repeatable flag)
#   python benchmarks/run.py --fast          # tiny-geometry smoke of every
#                                            # suite (CI tier)
#   python benchmarks/run.py --plan "schedule=pipelined,n_steps=2" \
#       --suite fig6                         # plan spec drives the
#                                            # end-to-end harness
#
# A failing suite prints a single ``<name>,nan,FAILED`` row (its partial
# rows are suppressed — no half-tables masquerading as results), the
# traceback goes to stderr, and the exit status is nonzero.
from __future__ import annotations

import argparse
import os
import sys
import traceback

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; the suites import each other as `benchmarks.*`, so make the
# documented invocation work from anywhere.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main(argv=None) -> None:
    from benchmarks import (
        bench_backprojection, bench_end_to_end, bench_filtering, bench_io,
        bench_scaling_model, bench_serving, bench_streaming, plan_search,
        roofline_table,
    )
    suites = [
        ("table4", bench_backprojection.run),     # BP kernel GUPS sweep
        ("filtering", bench_filtering.run),       # TH_flt micro-benchmark
        ("table5_fig5", bench_scaling_model.run),  # scaling model vs paper
        ("fig6", bench_end_to_end.run),           # end-to-end GUPS
        ("streaming", bench_streaming.run),       # time-from-last-delta
        ("serving", bench_serving.run),           # scans/hour at fixed fleet
        ("roofline", roofline_table.run),         # dry-run roofline terms
        ("plan_search", plan_search.run),         # auto-planner ranked table
        ("io", bench_io.run),                     # shard-store read/write GB/s
    ]
    names = [n for n, _ in suites]
    ap = argparse.ArgumentParser(description="iFDK benchmark driver")
    ap.add_argument("--suite", action="append", choices=names,
                    help="run only this suite (repeatable; default: all)")
    ap.add_argument("--fast", action="store_true",
                    help="tiny-geometry smoke mode for every suite")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations (default: per-suite)")
    ap.add_argument("--plan", default=None, metavar="SPEC",
                    help="ReconstructionPlan spec for the end-to-end suite, "
                         "e.g. 'schedule=pipelined,n_steps=2,precision=bf16'"
                         " — or 'auto' to let the planner pick "
                         "(repro/planner)")
    ap.add_argument("--policy", default=None,
                    choices=["fifo", "largest_bucket", "deadline"],
                    help="bucket scheduling policy for the serving suite's "
                         "serve-loop mode (repro/service; default: "
                         "deadline)")
    ap.add_argument("--json", action="store_true",
                    help="additionally persist each suite's rows as "
                         "BENCH_<suite>.json at the repo root (the "
                         "PR-over-PR perf trajectory files); rows carry a "
                         "t_stage breakdown from the stage tracer")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record every engine/stage span of the run and "
                         "save a Chrome/Perfetto trace_event file "
                         "(load at ui.perfetto.dev)")
    args = ap.parse_args(argv)

    # --json wants per-suite t_stage breakdowns and --trace wants the
    # span stream — both come from the same tracer. Fenced engine spans
    # make each dispatch synchronous, which the suites do anyway (they
    # block_until_ready inside their timing loops).
    from repro.obs import trace as obs_trace
    tracing = bool(args.trace) or args.json
    if tracing:
        obs_trace.enable()

    def _stage_snapshot():
        if not tracing:
            return {}
        tr = obs_trace.get_tracer()
        totals = dict(tr.stage_totals("stage."))
        totals.update(tr.stage_totals("engine."))
        totals.update(tr.stage_totals("session."))
        return totals

    selected = [s for s in suites if not args.suite or s[0] in args.suite]
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in selected:
        kwargs = {"fast": args.fast}
        if args.iters is not None:
            kwargs["iters"] = args.iters
        if name == "fig6" and args.plan:
            kwargs["plan_spec"] = args.plan
        if name == "serving" and args.policy:
            kwargs["policy"] = args.policy
        def _delta(before, after):
            return {
                k: round(v - before.get(k, 0.0), 6)
                for k, v in sorted(after.items())
                if v - before.get(k, 0.0) > 0.0
            }

        # Iterate the suite LAZILY, snapshotting the tracer around each
        # yielded item: a suite that yields case groups (lists of rows —
        # bench_streaming/bench_serving) gets a per-case t_stage delta on
        # each case's rows instead of the whole run's cumulative totals
        # repeated on every row. The cumulative stays at suite level (one
        # trailing ``suite_total`` record).
        before = _stage_snapshot()
        items = []                       # [(rows_of_item, per_item_delta)]
        grouped = False                  # suite yielded case groups (lists)
        try:
            it = fn(**kwargs)
            prev = _stage_snapshot()
            for item in it:
                now = _stage_snapshot()
                if isinstance(item, list):
                    grouped = True
                    group = item
                else:
                    group = [item]
                items.append((group, _delta(prev, now)))
                prev = now
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},nan,FAILED")
            continue
        rows = [row for g, _ in items for row in g]
        for row, us, derived in rows:
            print(f"{row},{us:.1f},{derived}")
        if args.json:
            t_stage = _delta(before, _stage_snapshot())
            path = os.path.join(root, f"BENCH_{name}.json")
            if grouped:
                row_stages = [d for g, d in items for _ in g]
                bench_streaming.write_json(path, rows, t_stage=t_stage,
                                           row_stages=row_stages)
            else:
                bench_streaming.write_json(path, rows, t_stage=t_stage)
    if args.trace:
        obs_trace.get_tracer().save(args.trace)
        print(f"# trace: {args.trace} "
              f"({len(obs_trace.get_tracer().events())} spans)",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
