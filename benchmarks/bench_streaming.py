"""Streaming instant-CT: time-from-last-projection-to-volume.

The paper's headline is reconstruction *inside* the acquisition window: the
volume is ready moments after the last projection lands, because everything
before it was folded while the scanner was still writing. This suite
measures exactly that figure of merit for the incremental schedule
(core/plan.py `build_incremental`):

  t_last_delta   the fold of the last (already staged) delta with the
                 reduce epilogue + FDK scale fused into the same dispatch —
                 `update(staged, finalize=True)`. Filtering is
                 per-projection independent, so a streaming rank stages
                 (filters + encodes + gathers) the final burst's frames
                 while that burst is still landing; the back-projection
                 fold + epilogue is the only work that cannot overlap
                 acquisition (ISSUE: "time-from-last-projection approaches
                 one subset's back-projection").
  batch_e2e      the equivalent batch plan's end-to-end call (all
                 projections up front), fused and pipelined flavors.

The streaming claim holds when t_last_delta < batch_e2e / n_steps: the
session's tail latency beats even a perfectly proportional slice of the
batch pipeline. All three timings are sampled INTERLEAVED (round-robin,
min-of-iters) so host load drift cannot favor one side. Each measured
row's `derived` field carries the comparison; `main()` (or
``run.py --json``) persists the rows as BENCH_streaming.json — the
perf-trajectory file tracked across PRs (ROADMAP).
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

import jax
import numpy as np

# `python benchmarks/bench_streaming.py` puts benchmarks/ (not the repo
# root) on sys.path; make the documented direct invocation work.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan
from repro.planner.cost import point_from_plan, time_from_last_delta

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_streaming.json")


def _interleaved_best(fns, iters: int) -> list:
    """min-of-iters for each fn, sampled round-robin. The streaming
    criterion compares numbers whose true gap is a few percent; sequential
    mean-of-N timing lets host load drift decide the verdict, so the
    candidates alternate within each round and the minimum (the
    least-disturbed sample) represents each."""
    for fn in fns:                       # warm-up / compile
        fn()
    best = [math.inf] * len(fns)
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _make_last_delta_fn(plan, proj, n_steps: int):
    """Closure timing one last-delta tail: the fold of the final STAGED
    delta with the epilogue fused in — `update(staged, finalize=True)`
    without the host bookkeeping.

    The first n_steps-1 deltas are folded into a live session up front and
    the last delta is staged (filter + encode + gather) outside the timed
    region — that work rode along with acquisition. The jitted fold is
    pure (state in, state out), so the timing loop replays the identical
    fold without mutating the session."""
    g = plan.geometry
    n_d = g.n_proj // n_steps
    sess = plan.build_incremental()
    for k in range(n_steps - 1):
        sess.update(proj[k * n_d:(k + 1) * n_d], (k * n_d, (k + 1) * n_d))
    jax.block_until_ready(sess._acc)
    staged = sess.stage(proj[-n_d:], (g.n_proj - n_d, g.n_proj))
    jax.block_until_ready(staged.q_col)
    fold_fn = sess._get_fold_fn(n_d, with_volume=True)

    def last_to_volume():
        _, volume = fold_fn(sess._acc, staged.pm_col, staged.q_col,
                            staged.sc_col)
        jax.block_until_ready(volume)

    return last_to_volume


def run(iters: int = 7, fast: bool = False):
    """Yield one LIST of rows per case (a case group): the driver
    (run.py --json) snapshots the stage tracer around each yielded group,
    so every case gets its own t_stage delta instead of the whole suite's
    cumulative totals. Flatten for the flat-row view (see main())."""
    # Small volumes are dispatch-overhead-bound: the one launch t_last pays
    # but the batch plan amortizes across its whole scan costs ~100us+,
    # which swamps the streaming margin below ~32^3. The fast case starts
    # where the fold does real work.
    cases = [(32, 64, 4)] if fast else [(32, 64, 4), (48, 96, 4)]
    for n, npj, n_steps in cases:
        rows = []
        g = default_geometry(n, n_proj=npj)
        proj = np.asarray(forward_project(g))
        label = f"streaming/{n}^3x{npj}"

        fused = ReconstructionPlan(geometry=g)
        pipelined = ReconstructionPlan(geometry=g, schedule="pipelined",
                                       n_steps=n_steps)
        fused_fn, pipe_fn = fused.build(), pipelined.build()

        incr = ReconstructionPlan(geometry=g, schedule="incremental",
                                  n_steps=n_steps)
        t_fused, t_pipe, t_last = _interleaved_best([
            lambda: jax.block_until_ready(fused_fn(proj)),
            lambda: jax.block_until_ready(pipe_fn(proj)),
            _make_last_delta_fn(incr, proj, n_steps),
        ], iters)

        # the streaming criterion, against the equivalent (same
        # micro-batching) pipelined batch plan
        budget = t_pipe / n_steps
        modeled = time_from_last_delta(g, point_from_plan(incr))
        rows.append((f"{label}/batch_fused_e2e", t_fused * 1e6, ""))
        rows.append((f"{label}/batch_pipelined_e2e", t_pipe * 1e6,
                     f"n_steps={n_steps}"))
        rows.append((
            f"{label}/t_last_delta", t_last * 1e6,
            f"n_steps={n_steps} budget={budget * 1e6:.1f}us "
            f"speedup_vs_fused={t_fused / t_last:.2f}x "
            f"model_abci={modeled * 1e6:.1f}us "
            f"{'OK' if t_last < budget else 'MISS'}",
        ))
        yield rows


def flatten_rows(groups):
    """Flat (name, us, derived) rows from a run() that may yield case
    groups (lists) and/or bare row tuples."""
    return [row for item in groups
            for row in (item if isinstance(item, list) else [item])]


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="streaming instant-CT bench")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iters", type=int, default=7)
    ap.add_argument("--json", nargs="?", const=JSON_PATH, default=None,
                    metavar="PATH",
                    help=f"persist rows as JSON (default {JSON_PATH})")
    args = ap.parse_args(argv)
    rows = flatten_rows(run(iters=args.iters, fast=args.fast))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        write_json(args.json, rows)
        print(f"# wrote {args.json}")


def write_json(path: str, rows, t_stage=None, row_stages=None) -> None:
    """Persist benchmark rows as the PR-over-PR trajectory file.

    `row_stages` (optional list parallel to `rows`, of dicts span name ->
    seconds) attaches each row's OWN per-case stage delta — the driver
    (run.py --json) snapshots `Tracer.stage_totals` around each case group
    so a row's t_stage is what that case actually spent, not the whole
    run's cumulative totals. `t_stage` is the suite-level cumulative
    breakdown: with `row_stages` present it is appended as one trailing
    ``suite_total`` record; without (the legacy call shape) it is attached
    to every row unchanged."""
    payload = []
    for i, (name, us, derived) in enumerate(rows):
        rec = {"name": name, "us_per_call": us, "derived": derived}
        if row_stages is not None:
            if i < len(row_stages) and row_stages[i]:
                rec["t_stage"] = row_stages[i]
        elif t_stage:
            rec["t_stage"] = t_stage
        payload.append(rec)
    if row_stages is not None and t_stage:
        payload.append({"name": "suite_total", "t_stage": t_stage})
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


if __name__ == "__main__":
    main()
