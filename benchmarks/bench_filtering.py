"""Filtering-stage throughput (paper §4.2.1 TH_flt micro-benchmark)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.filtering import make_filter
from repro.core.geometry import default_geometry


def run(iters: int = 3, fast: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    cases = [(64, 8)] if fast else [(64, 32), (128, 32), (256, 16)]
    for n, batch in cases:
        g = default_geometry(n, n_proj=batch)
        filt = make_filter(g)
        proj = jnp.asarray(
            rng.normal(size=(batch, g.n_v, g.n_u)), jnp.float32
        )
        jax.block_until_ready(filt(proj))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(filt(proj))
        dt = (time.perf_counter() - t0) / iters
        rows.append((
            f"filtering/{g.n_u}x{g.n_v}x{batch}", dt * 1e6,
            f"{batch / dt:.0f}proj_per_s",
        ))
    return rows
