"""Trace-calibrated auto-planner (planner/calibrate.py): the
predicted->measured loop — robust fitting, store persistence, calibrated
ranking recovery, and the drift-alarm metric."""
import json
import os

import jax
import numpy as np
import pytest

from repro.core.geometry import default_geometry
from repro.core.perf_model import ABCI
from repro.core.plan import ReconstructionPlan, plan_from_spec
from repro.filecache import JsonFileCache
from repro.obs.attribution import AttributionRow, aggregate_error
from repro.planner.calibrate import (
    MIN_SAMPLES, CalibrationStore, MachineCalibration, default_calibration,
    resolve_calibration, robust_scale, set_default_store)
from repro.planner.cost import (IMPL_GUPS_FACTOR, STEP_OVERHEAD_S,
                                PlanPoint, point_from_plan, predict_point)
from repro.planner.search import auto_plan, search_plans


def _store(tmp_path=None):
    """A CalibrationStore: file-backed on tmp_path, else in-memory
    (conftest sets REPRO_CALIB_CACHE=off, so the default cache is
    path-less)."""
    if tmp_path is None:
        return CalibrationStore()
    return CalibrationStore(cache=JsonFileCache(
        "REPRO_CALIB_CACHE", "calibration_store.json",
        path=os.path.join(str(tmp_path), "store.json")))


def _record_bp(store, impl, ratio, n=5, p=1e-3, **overrides):
    kw = dict(system=ABCI.name, stage="stage.backproject", impl=impl,
              schedule="fused", reduce="psum", precision="bf16", bucket=15)
    kw.update(overrides)
    for i in range(n):
        store.record(predicted_s=p * (1 + 0.01 * i),
                     measured_s=ratio * p * (1 + 0.01 * i), **kw)


class TestRobustScale:
    def test_recovers_ratio(self):
        pts = [(p, 2.0 * p) for p in (1e-3, 2e-3, 3e-3, 4e-3)]
        scale, used, rejected = robust_scale(pts)
        assert scale == pytest.approx(2.0, rel=1e-6)
        assert used == 4 and rejected == 0

    def test_rejects_outlier(self):
        # six consistent 2x samples + one 500x compile-warmup spike: the
        # MAD gate on log-ratios drops the spike, the fit stays ~2x.
        pts = [(p, 2.0 * p) for p in (1e-3, 1.1e-3, 2e-3, 3e-3,
                                      4e-3, 5e-3)]
        pts.append((1e-3, 0.5))  # 500x
        scale, used, rejected = robust_scale(pts)
        assert rejected == 1 and used == 6
        assert scale == pytest.approx(2.0, rel=1e-3)

    def test_under_sample_gate(self):
        scale, used, _ = robust_scale([(1e-3, 2e-3), (2e-3, 4e-3)])
        assert scale is None and used == 0

    def test_zero_sides_dropped(self):
        scale, _, _ = robust_scale([(0.0, 1.0), (1.0, 0.0), (-1.0, 1.0)])
        assert scale is None

    def test_time_weighting(self):
        # one 2 s sample at 2.2x outvotes three 1 ms samples at 2x
        # (weights are the measured seconds; the ratios sit within the MAD
        # floor so nothing is rejected).
        pts = [(1e-3, 2e-3)] * 3 + [(2.0 / 2.2, 2.0)]
        scale, used, rejected = robust_scale(pts)
        assert rejected == 0 and used == 4
        assert scale == pytest.approx(2.2, rel=0.01)


class TestMachineCalibration:
    def test_empty_is_noop(self):
        cal = MachineCalibration(base=ABCI.name)
        assert cal.is_empty
        assert cal.apply(ABCI) is ABCI
        assert cal.bp_scale("factorized") is None
        assert cal.step_overhead() == STEP_OVERHEAD_S
        g = default_geometry(16, n_proj=8)
        pt = PlanPoint(grid=ReconstructionPlan(geometry=g).grid,
                       schedule="fused", n_steps=1, y_chunks=None,
                       reduce="psum", precision="fp32", impl="factorized")
        assert predict_point(g, pt, calibration=cal).t_runtime == \
            pytest.approx(predict_point(g, pt).t_runtime, rel=1e-12)

    def test_to_dict_round_trip(self):
        cal = MachineCalibration(
            base=ABCI.name, stage_scales={"t_flt": 0.5},
            bp_scales={"kernel": 3.0}, step_overhead_s=1e-4,
            n_samples=12, n_rejected=1)
        back = MachineCalibration.from_dict(
            json.loads(json.dumps(cal.to_dict())))
        assert back == cal

    def test_admits_impl_needs_fitted_win(self):
        # kernel factor 1.25 / scale 100 = 0.0125 < reference's stock
        # 0.125: measured evidence says the kernel LOST — stays excluded.
        slow = MachineCalibration(base=ABCI.name,
                                  bp_scales={"kernel": 100.0})
        assert not slow.admits_impl("kernel")
        fast = MachineCalibration(base=ABCI.name,
                                  bp_scales={"kernel": 0.5})
        assert fast.admits_impl("kernel")      # 1.25/0.5 = 2.5 > 0.125
        assert not MachineCalibration(base=ABCI.name).admits_impl("kernel")

    def test_resolve_calibration(self):
        cal = MachineCalibration(base=ABCI.name, bp_scales={"kernel": 1.0})
        assert resolve_calibration(None, ABCI) == (None, ABCI)
        assert resolve_calibration(cal, ABCI) == (cal, ABCI)
        other = ABCI.with_overlay(flt_scale=2.0)
        assert resolve_calibration(other, ABCI) == (None, other)
        with pytest.raises(ValueError, match="calibration"):
            resolve_calibration(42, ABCI)


class TestStore:
    def test_fit_bp_scale_applied_to_prediction(self):
        store = _store()
        _record_bp(store, "factorized", ratio=3.0)
        cal = store.fit()
        assert cal.bp_scales["factorized"] == pytest.approx(3.0, rel=1e-3)
        g = default_geometry(16, n_proj=8)
        pt = PlanPoint(grid=ReconstructionPlan(geometry=g).grid,
                       schedule="fused", n_steps=1, y_chunks=None,
                       reduce="psum", precision="bf16", impl="factorized")
        bd0, bd = predict_point(g, pt), predict_point(g, pt,
                                                      calibration=cal)
        # the scale multiplies the update-rate part only (Eq. 12's
        # t_bp - t_h2d); the H2D traffic term is untouched.
        assert bd.t_bp == pytest.approx(
            bd0.t_h2d + 3.0 * (bd0.t_bp - bd0.t_h2d), rel=1e-3)

    def test_under_sampled_key_falls_back(self):
        store = _store()
        _record_bp(store, "factorized", ratio=3.0, n=MIN_SAMPLES - 1)
        cal = store.fit()
        assert "factorized" not in cal.bp_scales

    def test_round_trip_across_instances(self, tmp_path):
        # two store objects on the same file = two processes sharing
        # REPRO_CALIB_CACHE: one records, the other fits.
        writer = _store(tmp_path)
        assert writer.persistent
        _record_bp(writer, "factorized", ratio=2.0)
        reader = _store(tmp_path)
        assert reader.n_samples(ABCI.name) == 5
        cal = reader.fit()
        assert cal.bp_scales["factorized"] == pytest.approx(2.0, rel=1e-3)
        reader.clear()
        assert _store(tmp_path).n_samples() == 0

    def test_record_traced_run_projects_to_fused(self):
        # build_traced always executes the fused stage decomposition, so a
        # pipelined plan's samples must be keyed (and priced) as fused.
        store = _store()
        g = default_geometry(16, n_proj=8)
        plan = ReconstructionPlan(geometry=g, schedule="pipelined",
                                  n_steps=2)
        store.record_traced_run(plan, {"stage.filter": 0.01,
                                       "stage.backproject": 0.02})
        keys = list(store.samples())
        assert keys and all(k[4] == "fused" for k in keys)
        # the backproject sample's predicted basis is the fused point's
        # update-rate term (t_bp - t_h2d), not the stepped t_bp.
        import dataclasses
        fused = dataclasses.replace(point_from_plan(plan),
                                    schedule="fused", n_steps=1,
                                    y_chunks=None)
        bd = predict_point(g, fused)
        bp_key = [k for k in keys if k[2] == "stage.backproject"]
        assert len(bp_key) == 1
        sample = store.samples()[bp_key[0]][0]
        assert sample["p"] == pytest.approx(bd.t_bp - bd.t_h2d, rel=1e-9)

    def test_step_overhead_fit_from_engine_pairs(self):
        store = _store()
        g = default_geometry(16, n_proj=8)
        grid = ReconstructionPlan(geometry=g).grid
        fused = PlanPoint(grid=grid, schedule="fused", n_steps=1,
                          y_chunks=None, reduce="psum", precision="bf16",
                          impl="factorized")
        base = 0.010
        for _ in range(MIN_SAMPLES):
            store.record_engine(g, fused, base)
        stepped = PlanPoint(grid=grid, schedule="pipelined", n_steps=4,
                            y_chunks=None, reduce="psum", precision="bf16",
                            impl="factorized")
        for _ in range(MIN_SAMPLES):
            store.record_engine(g, stepped, base + 4 * 5e-4)
        cal = store.fit()
        # (stepped - fused) / k = 5e-4 per step
        assert cal.step_overhead_s == pytest.approx(5e-4, rel=1e-6)
        assert cal.step_overhead() == pytest.approx(5e-4)


class TestRankingRecovery:
    """ISSUE acceptance: seed the store with timings that contradict the
    stock constants (the kernel impl is actually ~1000x slower than its
    analytic factor claims); stock-auto mis-ranks, calibrated-auto
    recovers the true ordering STRICTLY."""

    def _mis_calibrated(self):
        store = _store()
        # truth on this "host": kernel 1000x slower than modeled,
        # factorized exactly as modeled
        _record_bp(store, "kernel", ratio=1000.0)
        _record_bp(store, "factorized", ratio=1.0)
        return store.fit()

    def test_stock_misranks_calibrated_recovers(self):
        g = default_geometry(16, n_proj=8)
        cal = self._mis_calibrated()
        grid = ReconstructionPlan(geometry=g).grid
        mk = lambda impl: PlanPoint(
            grid=grid, schedule="fused", n_steps=1, y_chunks=None,
            reduce="psum", precision="bf16", impl=impl)
        stock_k = predict_point(g, mk("kernel")).t_runtime
        stock_f = predict_point(g, mk("factorized")).t_runtime
        assert stock_k < stock_f          # the analytic prior mis-ranks
        cal_k = predict_point(g, mk("kernel"), calibration=cal).t_runtime
        cal_f = predict_point(g, mk("factorized"),
                              calibration=cal).t_runtime
        assert cal_f < cal_k              # strict recovery

    def test_search_plans_ranking_flips(self):
        # Back-projection-dominated geometry (model-only — nothing is
        # built): at 2048^3 the impls' t_bp differ by far more than the
        # ranking's ~1% predicted buckets, so stock genuinely prefers
        # the kernel rather than winning a sub-noise tie-break.
        g = default_geometry(2048, n_proj=8)
        cal = self._mis_calibrated()
        # include_infeasible: a 2048^3 volume overflows the single-device
        # memory model, but the predicted ORDER is what's under test.
        kw = dict(impls=("factorized", "kernel"), precisions=("bf16",),
                  schedules=("fused",), top_k=4, include_infeasible=True)
        stock = search_plans(g, None, **kw)
        assert stock[0].point.impl == "kernel"
        calibrated = search_plans(g, None, calibration=cal, **kw)
        assert calibrated[0].point.impl == "factorized"


class TestKernelGuardRetirement:
    """auto_plan's CPU-only kernel exclusion is now evidence-based: fitted
    kernel factor beats reference's -> kernel enters the ranked space."""

    @pytest.fixture(autouse=True)
    def _cpu_only(self):
        if jax.default_backend() == "tpu":
            pytest.skip("the guard under test only exists off-TPU")

    def test_stock_auto_excludes_kernel(self):
        g = default_geometry(16, n_proj=8)
        plan = auto_plan(g, calibration=None)
        assert plan.impl == "factorized"

    def test_fitted_kernel_win_admits_and_ranks_it(self):
        g = default_geometry(16, n_proj=8)
        store = _store()
        # measured: kernel back-projection exactly as modeled, factorized
        # pathologically slow on this "host" — the fitted kernel factor
        # (1.25) beats reference's stock 0.125, so the kernel competes,
        # and with factorized's t_bp blown past the dominant filter term
        # it must WIN the auto search outright.
        _record_bp(store, "kernel", ratio=1.0)
        _record_bp(store, "factorized", ratio=1e7)
        cal = store.fit()
        assert cal.admits_impl("kernel")
        plan = auto_plan(g, calibration=cal)
        assert plan.impl == "kernel"
        # without the fitted evidence the guard still excludes the kernel
        assert auto_plan(g, calibration=None).impl == "factorized"

    def test_fitted_kernel_loss_keeps_it_out(self):
        g = default_geometry(16, n_proj=8)
        store = _store()
        _record_bp(store, "kernel", ratio=1000.0)
        cal = store.fit()
        assert not cal.admits_impl("kernel")
        assert auto_plan(g, calibration=cal).impl == "factorized"


class TestDefaultStoreHooks:
    def test_default_calibration_none_when_disabled(self):
        # conftest sets REPRO_CALIB_CACHE=off and no explicit store is
        # installed here: "auto" must resolve to stock constants.
        prev = set_default_store(None)
        try:
            assert default_calibration() is None
            cal, system = resolve_calibration("auto", ABCI)
            assert cal is None and system is ABCI
        finally:
            set_default_store(prev)

    def test_explicit_store_records_and_resolves(self):
        store = _store()
        prev = set_default_store(store)
        try:
            _record_bp(store, "factorized", ratio=2.0)
            cal = default_calibration()
            assert cal is not None
            assert cal.bp_scales["factorized"] == pytest.approx(2.0,
                                                                rel=1e-3)
            got, _ = resolve_calibration("auto", ABCI)
            assert got == cal
        finally:
            set_default_store(prev)

    def test_measure_deposits_into_store(self):
        from repro.planner.measure import clear_cache, measure_proposal
        g = default_geometry(16, n_proj=8)
        proposals = search_plans(g, None, impls=("factorized",),
                                 precisions=("fp32",),
                                 schedules=("fused",), top_k=1)
        store = _store()
        prev = set_default_store(store)
        clear_cache()   # a memo hit would skip the deposit
        try:
            seconds = measure_proposal(g, proposals[0], iters=1)
            assert seconds > 0
            engine_keys = [k for k in store.samples() if k[2] == "engine"]
            assert len(engine_keys) == 1
            sample = store.samples()[engine_keys[0]][0]
            assert sample["m"] == pytest.approx(seconds)
            assert sample["k"] == 1 and sample["sz"] > 0
        finally:
            set_default_store(prev)
            clear_cache()


class TestTracedIncrementalSession:
    def test_streaming_session_feeds_store_and_matches_fused(self):
        from repro.core.phantom import forward_project
        g = default_geometry(16, n_proj=8)
        proj = np.asarray(forward_project(g))
        plan = ReconstructionPlan(geometry=g, schedule="incremental",
                                  n_steps=2)
        oracle = np.asarray(
            ReconstructionPlan(geometry=g).build()(proj))

        store = _store()
        prev = set_default_store(store)
        try:
            sess = plan.build_traced()
            n_d = g.n_proj // 2
            sess.update(proj[:n_d], (0, n_d))
            volume = sess.update(proj[n_d:], (n_d, g.n_proj),
                                 finalize=True)
            np.testing.assert_allclose(np.asarray(volume), oracle,
                                       rtol=1e-4, atol=1e-5)
            seconds = sess.stage_seconds()
            for stage in ("stage.filter", "stage.allgather",
                          "stage.backproject", "stage.reduce"):
                assert seconds.get(stage, 0.0) > 0.0, stage
            keys = list(store.samples())
            assert keys, "finalized session must deposit samples"
            assert all(k[4] == "incremental" for k in keys)
            stages = {k[2] for k in keys}
            assert "stage.backproject" in stages
        finally:
            set_default_store(prev)

    def test_records_once(self):
        from repro.core.phantom import forward_project
        g = default_geometry(16, n_proj=8)
        proj = np.asarray(forward_project(g))
        plan = ReconstructionPlan(geometry=g, schedule="incremental",
                                  n_steps=2)
        store = _store()
        prev = set_default_store(store)
        try:
            sess = plan.build_traced()
            sess.update(proj[: g.n_proj // 2], (0, g.n_proj // 2))
            sess.update(proj[g.n_proj // 2:], (g.n_proj // 2, g.n_proj))
            sess.finalize()
            n = store.n_samples()
            assert n > 0
            sess.finalize()   # pure; must not double-record
            assert store.n_samples() == n
        finally:
            set_default_store(prev)


class TestAggregateError:
    def _row(self, predicted, measured, n=1):
        return AttributionRow(stage="stage.backproject", field="t_bp",
                              predicted_s=predicted, measured_s=measured,
                              n_spans=n)

    def test_time_weighted(self):
        rows = [self._row(1.0, 2.0),        # |err| = 1.0, weight 2.0
                self._row(1.0, 1.0)]        # |err| = 0.0, weight 1.0
        assert aggregate_error(rows) == pytest.approx(2.0 / 3.0)

    def test_skips_unattributable(self):
        rows = [self._row(0.0, 1.0),        # predicted 0: error None
                self._row(1.0, 0.5, n=0)]   # never measured
        assert aggregate_error(rows) is None
        assert aggregate_error([]) is None

    def test_perfect_model_is_zero(self):
        assert aggregate_error([self._row(0.5, 0.5)]) == 0.0


class TestBenchRowStages:
    def test_write_json_per_row_stages(self, tmp_path):
        from benchmarks.bench_streaming import flatten_rows, write_json
        rows = [("a", 1.0, ""), ("b", 2.0, ""), ("c", 3.0, "")]
        stages = [{"stage.filter": 0.1}, {}, {"stage.filter": 0.2}]
        path = str(tmp_path / "bench.json")
        write_json(path, rows, t_stage={"stage.filter": 0.3},
                   row_stages=stages)
        recs = json.load(open(path))
        assert [r["name"] for r in recs] == ["a", "b", "c", "suite_total"]
        assert recs[0]["t_stage"] == {"stage.filter": 0.1}
        assert "t_stage" not in recs[1]
        assert recs[2]["t_stage"] == {"stage.filter": 0.2}
        assert recs[3] == {"name": "suite_total",
                           "t_stage": {"stage.filter": 0.3}}
        # legacy call shape: cumulative attached to every row, no trailer
        write_json(path, rows, t_stage={"stage.filter": 0.3})
        recs = json.load(open(path))
        assert len(recs) == 3
        assert all(r["t_stage"] == {"stage.filter": 0.3} for r in recs)
        assert flatten_rows([rows[:2], rows[2]]) == rows


@pytest.mark.slow
class TestCalibratedAutoMeasured:
    """ISSUE acceptance: on a bench geometry, the calibrated-auto pick's
    measured runtime is <= stock-auto's (the loop can only help)."""

    def test_calibrated_pick_not_slower(self):
        import sys
        sys.path.insert(0, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        from benchmarks.plan_search import seed_calibration
        from repro.planner.measure import measure_proposal

        g = default_geometry(32, n_proj=64)
        stock = search_plans(g, None, top_k=4)
        cal, store, _ = seed_calibration(g, stock, iters=4)
        assert not cal.is_empty, store.n_samples()
        calibrated = search_plans(g, None, top_k=4, calibration=cal)
        t_stock = measure_proposal(g, stock[0], iters=2)
        t_cal = measure_proposal(g, calibrated[0], iters=2)
        # timing noise guard: identical picks are trivially equal; distinct
        # picks must not be measurably worse (10% slack on a ~30 ms call)
        assert t_cal <= t_stock * 1.10
