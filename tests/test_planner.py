"""Auto-planner subsystem: plan-aware cost model, feasibility pruning,
ranked search, measured refinement, the `plan_from_spec(g, "auto")` wiring,
spec-error ergonomics, and the legacy-entry-point deprecation warnings."""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core.distributed import IFDKGrid, grid_candidates, input_sharding
from repro.core.fdk import reconstruct
from repro.core.geometry import default_geometry, paper_geometry
from repro.core.perf_model import ABCI
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan, plan_from_spec
from repro.core.precision import Precision
from repro.parallel.mesh import make_mesh, single_device_mesh
from repro.planner import (
    PlanPoint, auto_plan, check_feasible, enumerate_points, plan_footprint,
    point_from_plan, predict_plan, predict_point, search_grids, search_plans,
)
from repro.planner import measure as plan_measure
from repro.planner.cost import STEP_OVERHEAD_S

paper_problem = paper_geometry


GRID_256 = IFDKGrid(r=32, c=8)


# ---------------------------------------------------------------------------
# cost.py: plan-aware Eq. 8-19
# ---------------------------------------------------------------------------

class TestCost:
    def test_fused_serializes_stages(self):
        g = paper_problem()
        b = predict_point(g, PlanPoint(grid=GRID_256, schedule="fused"))
        assert not b.overlap
        assert b.t_compute == pytest.approx(
            b.t_load + b.t_flt + b.t_allgather + b.t_bp)

    def test_pipelined_overlaps_per_eq17(self):
        g = paper_problem()
        b = predict_point(g, PlanPoint(grid=GRID_256, schedule="pipelined",
                                       n_steps=4))
        assert b.overlap
        assert b.t_compute == pytest.approx(
            max(b.t_load, b.t_flt, b.t_allgather, b.t_bp))

    def test_pipelined_single_step_has_no_overlap(self):
        """n_steps=1 degenerates to fused semantics — the model must not
        award it Eq. 17's max."""
        g = paper_problem()
        b = predict_point(g, PlanPoint(grid=GRID_256, schedule="pipelined",
                                       n_steps=1))
        assert not b.overlap

    def test_storage_dtype_scales_comm(self):
        g = paper_problem()
        f32 = predict_point(g, PlanPoint(grid=GRID_256, precision="fp32"))
        b16 = predict_point(g, PlanPoint(grid=GRID_256, precision="bf16"))
        assert b16.t_allgather == pytest.approx(f32.t_allgather / 2)
        assert b16.t_load == pytest.approx(f32.t_load / 2)

    def test_chunked_restreams_projections(self):
        """More y-chunks -> more Q^T re-reads -> larger T_bp; the pipelined
        schedule at the same n_steps is the lower envelope."""
        g = paper_problem()
        pipe = predict_point(g, PlanPoint(grid=GRID_256,
                                          schedule="pipelined", n_steps=4))
        prev = pipe.t_bp
        for yc in (2, 8, 32):
            b = predict_point(g, PlanPoint(grid=GRID_256, schedule="chunked",
                                           n_steps=4, y_chunks=yc))
            assert b.t_bp > prev
            prev = b.t_bp

    def test_step_overhead_penalizes_deep_pipelines(self):
        g = paper_problem()
        t2 = predict_point(g, PlanPoint(grid=GRID_256, schedule="pipelined",
                                        n_steps=2)).t_bp
        t8 = predict_point(g, PlanPoint(grid=GRID_256, schedule="pipelined",
                                        n_steps=8)).t_bp
        assert t8 == pytest.approx(t2 + 6 * STEP_OVERHEAD_S)

    def test_psum_doubles_scatter_reduce_traffic(self):
        g = paper_problem()
        ps = predict_point(g, PlanPoint(grid=GRID_256, reduce="psum"))
        sc = predict_point(g, PlanPoint(grid=GRID_256, reduce="scatter"))
        assert ps.t_reduce == pytest.approx(2 * sc.t_reduce)
        c1 = predict_point(g, PlanPoint(grid=IFDKGrid(r=32, c=1)))
        assert c1.t_reduce == 0.0

    def test_impl_factors_order_t_bp(self):
        g = paper_problem()
        ts = {impl: predict_point(
                  g, PlanPoint(grid=GRID_256, impl=impl)).t_bp
              for impl in ("reference", "factorized", "kernel")}
        assert ts["reference"] > ts["factorized"] > ts["kernel"]
        with pytest.raises(ValueError, match="unknown impl"):
            predict_point(g, PlanPoint(grid=GRID_256, impl="cuda"))

    def test_predict_plan_matches_point(self):
        g = default_geometry(16, n_proj=8)
        plan = ReconstructionPlan(geometry=g, schedule="pipelined",
                                  n_steps=2, precision="bf16")
        assert predict_plan(plan) == predict_point(g, point_from_plan(plan))


class TestIOCost:
    """T_read/T_write in the plan-aware model: the slice-per-rank store's
    writer count and the PFS throttling the with-I/O ranking responds to."""

    def test_io_writers_counts_the_stores_concurrency(self):
        from repro.planner.cost import io_writers
        assert io_writers(PlanPoint(grid=GRID_256, reduce="psum")) == 32
        assert io_writers(PlanPoint(grid=GRID_256, reduce="scatter")) == 256
        assert io_writers(PlanPoint(grid=GRID_256, reduce="scatter",
                                    data_size=4)) == 128

    def test_scatter_store_outwrites_psum_under_rank_io_cap(self):
        """With per-rank PFS links the bottleneck, the parallel store
        (scatter: R x C writers) beats the replicated slab's R writers —
        the paper's reason for the slice-per-rank layout."""
        g = paper_problem()
        sys = ABCI.with_pfs(rank_io=50e6)
        ps = predict_point(g, PlanPoint(grid=GRID_256, reduce="psum"), sys)
        sc = predict_point(g, PlanPoint(grid=GRID_256, reduce="scatter"),
                           sys)
        assert sc.t_write < ps.t_write
        # uncapped (the paper's aggregate assumption): no difference
        ps0 = predict_point(g, PlanPoint(grid=GRID_256, reduce="psum"))
        sc0 = predict_point(g, PlanPoint(grid=GRID_256, reduce="scatter"))
        assert ps0.t_write == pytest.approx(sc0.t_write)

    def test_pfs_throttle_changes_auto_ranking(self):
        """The acceptance regression: throttling PFS read bandwidth flips
        the ranked search's winner — the planner ranks WITH I/O."""
        g = paper_problem()
        fast = search_grids(g, 256, system=ABCI, top_k=1)[0]
        slow = search_grids(g, 256,
                            system=ABCI.with_pfs(read=ABCI.bw_load / 200),
                            top_k=1)[0]
        assert (fast.point.grid, fast.spec()) != (slow.point.grid,
                                                  slow.spec())
        # under the throttle the winner is read-bound: Eq. 17's max is the
        # load term, so the ranking literally hinges on T_read
        assert slow.breakdown.t_compute == pytest.approx(
            slow.breakdown.t_read)
        assert slow.breakdown.t_read > fast.breakdown.t_read

    def test_rank_io_throttle_flips_reduce_mode_preference(self):
        """psum wins the tie-break when writes are free; capping per-rank
        links makes the parallel store's extra writers decisive."""
        g = paper_problem()
        points = [PlanPoint(grid=GRID_256, schedule="pipelined", n_steps=4,
                            precision="bf16", reduce=r)
                  for r in ("psum", "scatter")]
        sys = ABCI.with_pfs(rank_io=50e6)
        t = {p.reduce: predict_point(g, p, sys).t_runtime for p in points}
        assert t["scatter"] < t["psum"]


# ---------------------------------------------------------------------------
# feasibility.py: per-device memory model
# ---------------------------------------------------------------------------

class TestFeasibility:
    def test_chunked_scatter_divides_slab(self):
        g = paper_problem()
        fused = plan_footprint(g, PlanPoint(grid=GRID_256, schedule="fused"))
        chunk = plan_footprint(g, PlanPoint(grid=GRID_256,
                                            schedule="chunked", n_steps=8,
                                            y_chunks=16, reduce="scatter"))
        assert chunk.slab < fused.slab
        assert chunk.gathered < fused.gathered
        assert chunk.total < fused.total

    def test_scatter_divisor_is_data_axis_not_full_column(self):
        """The engine scatters the chunked accumulator over the DATA axis
        only (pod finishes replicated): on a multi-pod mesh the footprint
        must divide by data_size, not by all C columns."""
        g = paper_problem()
        single_pod = PlanPoint(grid=GRID_256, schedule="chunked", n_steps=8,
                               y_chunks=16, reduce="scatter")
        multi_pod = dataclasses.replace(single_pod, data_size=4)
        assert plan_footprint(g, multi_pod).slab > \
            plan_footprint(g, single_pod).slab
        mesh = single_device_mesh()
        plan = ReconstructionPlan(geometry=default_geometry(16, n_proj=8),
                                  mesh=mesh)
        assert point_from_plan(plan).data_size == 1

    def test_infeasible_reason_names_budget(self):
        g = paper_problem()
        point = PlanPoint(grid=IFDKGrid(r=1, c=1))
        ok, reason = check_feasible(g, point, hbm_bytes=16 * 2**30)
        assert not ok and "exceeds the HBM budget" in reason

    def test_kernel_vmem_floor(self):
        """A VMEM budget below the kernel's minimal working set prunes
        impl='kernel' with a kernel-specific reason; the XLA impls are
        untouched by it."""
        g = default_geometry(16, n_proj=8)
        point = PlanPoint(grid=IFDKGrid(r=1, c=1), impl="kernel")
        ok, _ = check_feasible(g, point)
        assert ok
        ok, reason = check_feasible(g, point, vmem_budget=1024)
        assert not ok and "fits VMEM" in reason
        ok, _ = check_feasible(
            g, PlanPoint(grid=IFDKGrid(r=1, c=1)), vmem_budget=1024)
        assert ok

    def test_kernel_needs_even_nz(self):
        g = dataclasses.replace(default_geometry(16, n_proj=8), n_z=15)
        ok, reason = check_feasible(
            g, PlanPoint(grid=IFDKGrid(r=1, c=1), impl="kernel"))
        assert not ok and "even N_z" in reason


# ---------------------------------------------------------------------------
# search.py: enumeration + ranking
# ---------------------------------------------------------------------------

class TestSearch:
    def test_grid_candidates_divisibility(self):
        g = paper_problem()
        grids = grid_candidates(g, 256)
        assert IFDKGrid(r=32, c=8) in grids
        for gr in grids:
            assert gr.n_ranks == 256 and g.n_x % gr.r == 0
        # 6 devices: only R in {1, 2} divide both 6 and n_x
        g6 = default_geometry(64, n_proj=96)
        assert [gr.r for gr in grid_candidates(g6, 6)] == [1, 2]
        # ranks must also tile the projections (validate()'s Eq. 5 rule)
        assert grid_candidates(default_geometry(64, n_proj=128), 6) == []

    def test_enumerate_points_respects_structure(self):
        g = default_geometry(16, n_proj=8)
        pts = list(enumerate_points(g, IFDKGrid(r=1, c=1)))
        assert all(p.n_steps == 1 for p in pts if p.schedule == "fused")
        assert all(p.reduce == "psum" for p in pts)  # c == 1: no scatter
        assert any(p.schedule == "chunked" and p.y_chunks == 4 for p in pts)

    def test_search_plans_returns_validated_ranked_plans(self):
        g = default_geometry(16, n_proj=8)
        props = search_plans(g, None, top_k=6)
        assert props and all(p.feasible for p in props)
        for p in props:
            assert p.plan is not None
            assert p.plan.validate() is p.plan
        ts = [p.predicted for p in props]
        assert ts == sorted(ts)

    def test_tight_budget_prunes_fused_for_chunked(self):
        """Acceptance: with a budget between the chunked and fused
        footprints, the fused plan is infeasible and the search returns a
        chunked winner instead."""
        g = default_geometry(16, n_proj=64)
        grid = IFDKGrid(r=1, c=1)
        fused_total = plan_footprint(
            g, PlanPoint(grid=grid, schedule="fused")).total
        chunk_total = plan_footprint(
            g, PlanPoint(grid=grid, schedule="chunked", n_steps=8,
                         y_chunks=4)).total
        assert chunk_total < fused_total
        budget = (fused_total + chunk_total) // 2
        props = search_plans(g, None, hbm_bytes=budget,
                             schedules=("fused", "chunked"), top_k=4)
        assert props and props[0].point.schedule == "chunked"
        assert all(p.point.schedule != "fused" for p in props)
        # and the fused plan really was pruned as infeasible, not absent
        # (top_k must cover the whole space — 170 points since the
        # precision axis grew to five codecs):
        with_inf = search_plans(g, None, hbm_bytes=budget,
                                schedules=("fused", "chunked"), top_k=1000,
                                include_infeasible=True)
        fused = [p for p in with_inf if p.point.schedule == "fused"]
        assert fused and not fused[0].feasible

    def test_bf16_outranks_f32_when_allgather_bound(self):
        """Acceptance: make AllGather the Eq. 17 bottleneck -> the halved
        collective bytes of bf16 storage win the ranking."""
        g = default_geometry(16, n_proj=8)
        ag_bound = dataclasses.replace(ABCI, th_allgather=1e-3)
        props = search_plans(g, None, system=ag_bound,
                             precisions=("fp32", "bf16"),
                             schedules=("pipelined",),
                             n_steps_candidates=(2,),
                             impls=("factorized",), top_k=8)
        assert [p.point.precision for p in props] == ["bf16", "fp32"]
        b = props[0].breakdown
        assert b.t_compute == pytest.approx(b.t_allgather)  # really AG-bound
        assert props[0].predicted == pytest.approx(props[1].predicted / 2,
                                                   rel=0.1)

    def test_search_grids_untileable_device_count_raises(self):
        # 4096 projections cannot spread over 100 ranks: a clear error,
        # not an empty table
        with pytest.raises(ValueError, match="no rectangular R x C"):
            search_grids(paper_problem(), 100)

    def test_search_grids_paper_scale(self):
        g = paper_problem()
        props = search_grids(g, 256, top_k=8)
        assert props and all(p.feasible for p in props)
        assert all(p.point.grid.n_ranks == 256 for p in props)
        assert all(p.plan is None for p in props)
        # every proposal's spec string round-trips through plan_from_spec
        # (construction parses the knobs; validation is geometry-specific)
        for p in props:
            plan = plan_from_spec(g, p.spec())
            assert plan.schedule == p.point.schedule
            assert plan.reduce == p.point.reduce
            assert plan.resolved_precision().storage == p.point.precision


class TestStreamCodecPlanner:
    """ISSUE 5: the planner prices the stream codecs — fp8_e4m3 wire bytes
    (+ scale sidecar) and the scatter_bf16 half-width reduce — with the
    same formulas the engine moves bytes by."""

    def test_search_space_includes_new_tokens(self):
        """`plan_from_spec(g, "auto")`'s search space (the default
        enumerate axes) contains fp8_e4m3 storage and scatter_bf16."""
        g = default_geometry(16, n_proj=8)
        pts = list(enumerate_points(g, IFDKGrid(r=2, c=4)))
        assert any(p.precision == "fp8_e4m3" for p in pts)
        assert any(p.reduce == "scatter_bf16" for p in pts)
        # and the planner's spec strings for them parse right back
        pt8 = next(p for p in pts if p.precision == "fp8_e4m3"
                   and p.reduce == "scatter_bf16")
        plan = plan_from_spec(g, pt8.spec())
        assert plan.resolved_precision().storage == "fp8_e4m3"
        assert plan.reduce == "scatter_bf16"

    def test_fp8_quarters_allgather_time(self):
        g = paper_problem()
        f32 = predict_point(g, PlanPoint(grid=GRID_256, precision="fp32"))
        fp8 = predict_point(g, PlanPoint(grid=GRID_256,
                                         precision="fp8_e4m3"))
        # 1/4 of the data bytes + the (tiny) scale sidecar
        assert fp8.t_allgather < f32.t_allgather / 4 * 1.01
        assert fp8.t_allgather > f32.t_allgather / 4  # sidecar is priced

    def test_fp8_outranks_bf16_when_allgather_bound(self):
        g = default_geometry(16, n_proj=8)
        ag_bound = dataclasses.replace(ABCI, th_allgather=1e-3)
        props = search_plans(g, None, system=ag_bound,
                             precisions=("bf16", "fp8_e4m3"),
                             schedules=("pipelined",),
                             n_steps_candidates=(2,),
                             impls=("factorized",), top_k=8)
        assert [p.point.precision for p in props] == ["fp8_e4m3", "bf16"]
        assert props[0].predicted == pytest.approx(props[1].predicted / 2,
                                                   rel=0.1)

    def test_scatter_bf16_halves_reduce_term(self):
        g = paper_problem()
        sc = predict_point(g, PlanPoint(grid=GRID_256, reduce="scatter"))
        hf = predict_point(g, PlanPoint(grid=GRID_256,
                                        reduce="scatter_bf16"))
        assert hf.t_reduce == pytest.approx(sc.t_reduce / 2)

    def test_wire_byte_accounting(self):
        from repro.planner.cost import (
            allgather_wire_bytes, reduce_wire_bytes,
        )
        g = paper_problem()
        ag = {p: allgather_wire_bytes(g, PlanPoint(grid=GRID_256,
                                                   precision=p))
              for p in ("fp32", "bf16", "fp8_e4m3")}
        assert ag["bf16"] * 2 == ag["fp32"]
        sidecar_moved = 256 * (4 * (g.n_proj // 8)) * 31 // 32
        assert ag["fp8_e4m3"] == ag["fp32"] // 4 + sidecar_moved
        rd = {r: reduce_wire_bytes(g, PlanPoint(grid=GRID_256, reduce=r))
              for r in ("psum", "scatter", "scatter_bf16")}
        assert rd["psum"] == 2 * rd["scatter"]
        assert rd["scatter_bf16"] * 2 == rd["scatter"]
        # nothing moves on a 1-rank axis
        assert allgather_wire_bytes(g, PlanPoint(grid=IFDKGrid(r=1,
                                                               c=8))) == 0
        assert reduce_wire_bytes(g, PlanPoint(grid=IFDKGrid(r=32,
                                                            c=1))) == 0

    def test_reduce_wire_bytes_multipod_scatters_data_axis_only(self):
        """The engine's scatter epilogue runs over the DATA axis and
        finishes across pods with an f32 psum of the 1/D-scattered slab —
        the accounting must NOT bill the whole C-column at bf16 width."""
        from repro.planner.cost import reduce_wire_bytes
        g = paper_problem()
        slab4 = (g.n_x // 32) * g.n_y * g.n_z * 4
        pt = PlanPoint(grid=GRID_256, reduce="scatter_bf16", data_size=2)
        # bf16 ring over the 2 data ranks + f32 allreduce over the 4 pods
        # of the half-slab
        per_rank = (slab4 // 2) * 1 // 2 + 2 * (slab4 // 2) * 3 // 4
        assert reduce_wire_bytes(g, pt) == 256 * per_rank
        # single-pod (data_size == c): pure half-width ring, no finish term
        single = PlanPoint(grid=GRID_256, reduce="scatter_bf16",
                           data_size=8)
        full = PlanPoint(grid=GRID_256, reduce="scatter", data_size=8)
        assert (reduce_wire_bytes(g, single) * 2
                == reduce_wire_bytes(g, full))

    def test_footprint_counts_sidecar_and_carry(self):
        g = default_geometry(16, n_proj=64)
        grid = IFDKGrid(r=1, c=1)
        f32 = plan_footprint(g, PlanPoint(grid=grid, precision="fp32"))
        fp8 = plan_footprint(g, PlanPoint(grid=grid, precision="fp8_e4m3"))
        # wire-format gathered batch: a quarter of f32 + 4 B/projection
        assert fp8.gathered == f32.gathered // 4 + 4 * g.n_proj
        # the compensated reduce's f32 error-feedback carry costs a full
        # slab of memory under the chunked schedule
        grid2 = IFDKGrid(r=1, c=2)
        plain = plan_footprint(g, PlanPoint(
            grid=grid2, schedule="chunked", n_steps=2, y_chunks=4,
            reduce="scatter"))
        comp = plan_footprint(g, PlanPoint(
            grid=grid2, schedule="chunked", n_steps=2, y_chunks=4,
            reduce="scatter_bf16"))
        assert comp.slab == plain.slab + g.n_x * g.n_y * g.n_z * 4

    def test_scatter_bf16_writer_count_matches_scatter(self):
        from repro.planner.cost import io_writers
        assert (io_writers(PlanPoint(grid=GRID_256, reduce="scatter_bf16"))
                == io_writers(PlanPoint(grid=GRID_256, reduce="scatter")))

    def test_search_grids_accepts_pinned_new_tokens(self):
        """The benchmark CLI path: restricting the axes to the new tokens
        yields a ranked table of only those plans."""
        g = paper_problem()
        props = search_grids(g, 256, precisions=("fp8_e4m3",),
                             reduces=("scatter_bf16",), top_k=4)
        assert props
        assert all(p.point.precision == "fp8_e4m3" for p in props)
        assert all(p.point.reduce == "scatter_bf16" for p in props)


# ---------------------------------------------------------------------------
# auto_plan / plan_from_spec("auto") wiring
# ---------------------------------------------------------------------------

class TestAutoPlan:
    @pytest.fixture(scope="class")
    def case16(self):
        g = default_geometry(16, n_proj=8)
        proj = forward_project(g)
        oracle = np.array(reconstruct(g, proj, impl="factorized",
                                      precision="fp32"))
        return g, proj, oracle

    def _check_oracle(self, out, oracle, storage):
        p = Precision(storage)
        scale = float(np.max(np.abs(oracle))) + 1e-12
        rmse = float(np.sqrt(np.mean((out - oracle) ** 2))) / scale
        assert rmse < p.rmse_tol(), rmse

    def test_auto_engine_matches_oracle_on_1x1x1_mesh(self, case16):
        """Acceptance: plan_from_spec(g, "auto") on a 1x1x1 mesh returns a
        validate()-clean plan whose engine reproduces the f32 oracle."""
        g, proj, oracle = case16
        mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
        plan = plan_from_spec(g, "auto", mesh=mesh)
        assert plan.validate() is plan
        out = np.asarray(plan.build()(
            jax.device_put(proj, input_sharding(mesh))))
        out = out.reshape(g.n_x, g.n_y, g.n_z)
        self._check_oracle(out, oracle, plan.resolved_precision().storage)

    def test_auto_no_mesh_matches_oracle(self, case16):
        g, proj, oracle = case16
        plan = plan_from_spec(g, "auto,precision=fp32")
        out = np.asarray(plan.build()(proj))
        self._check_oracle(out, oracle, "fp32")

    def test_auto_pins_restrict_the_search(self, case16):
        g, _, _ = case16
        plan = plan_from_spec(g, "auto,schedule=chunked,precision=bf16")
        assert plan.schedule == "chunked" and plan.y_chunks is not None
        assert plan.resolved_precision().storage == "bf16"

    def test_auto_pinned_knobs_constrain_the_schedule(self, case16):
        """Pinning n_steps/y_chunks must not let a schedule that ignores
        the knob win with the pin silently dropped."""
        g, _, _ = case16
        p = plan_from_spec(g, "auto,y_chunks=4")
        assert p.schedule == "chunked" and p.y_chunks == 4
        p = plan_from_spec(g, "auto,n_steps=4")
        assert p.schedule != "fused" and p.n_steps == 4
        with pytest.raises(ValueError, match="pins conflict"):
            plan_from_spec(g, "auto,schedule=fused,n_steps=4")
        with pytest.raises(ValueError, match="pins conflict"):
            plan_from_spec(g, "auto,schedule=pipelined,y_chunks=4")

    def test_auto_unknown_pin_raises(self, case16):
        g, _, _ = case16
        with pytest.raises(ValueError, match="cannot pin"):
            auto_plan(g, bogus=3)

    def test_auto_infeasible_raises_with_cause(self, case16):
        """Budget failures and divisibility failures get DIFFERENT errors —
        the user must be steered at the knob that actually failed."""
        g, _, _ = case16
        with pytest.raises(ValueError, match="exceed the memory budget"):
            auto_plan(g, hbm_bytes=1024)
        # N_p local = 8: n_steps=3 never divides -> not a budget problem
        with pytest.raises(ValueError, match="no valid candidate"):
            auto_plan(g, n_steps=3)

    def test_cpu_auto_avoids_interpret_mode_kernel(self, case16):
        g, _, _ = case16
        if jax.default_backend() != "tpu":
            assert plan_from_spec(g, "auto").impl == "factorized"


# ---------------------------------------------------------------------------
# measure.py: timed refinement + file-backed cache
# ---------------------------------------------------------------------------

class TestMeasure:
    def test_refine_times_and_reranks(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE",
                           str(tmp_path / "plan_cache.json"))
        plan_measure.clear_cache()
        g = default_geometry(16, n_proj=8)
        props = search_plans(g, None, impls=("factorized",), top_k=4)
        refined = plan_measure.refine(g, props, top_k=2, iters=1)
        assert len(refined) == len(props)
        head = refined[:2]
        assert all(p.measured is not None and p.measured > 0 for p in head)
        assert head[0].measured <= head[1].measured
        assert all(p.measured is None for p in refined[2:])

    def test_file_cache_serves_second_lookup(self, tmp_path, monkeypatch):
        cache = tmp_path / "plan_cache.json"
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(cache))
        plan_measure.clear_cache()
        g = default_geometry(16, n_proj=8)
        props = search_plans(g, None, impls=("factorized",), top_k=1)
        t0 = plan_measure.measure_proposal(g, props[0], iters=1)
        assert cache.exists()
        plan_measure.clear_cache()  # simulate a fresh process
        hits = plan_measure.file_cache_hits()
        t1 = plan_measure.measure_proposal(g, props[0], iters=1)
        assert t1 == t0  # served verbatim from disk, not re-timed
        assert plan_measure.file_cache_hits() == hits + 1

    def test_cache_key_sees_engine_identity(self, tmp_path, monkeypatch):
        """Two plans differing only in a knob outside the spec string (the
        ramp window) must not share a timing entry."""
        monkeypatch.setenv("REPRO_PLAN_CACHE",
                           str(tmp_path / "plan_cache.json"))
        plan_measure.clear_cache()
        g = default_geometry(16, n_proj=8)
        a = search_plans(g, None, impls=("factorized",), top_k=1)[0]
        b = search_plans(g, None, impls=("factorized",), top_k=1,
                         window="hann")[0]
        assert a.spec() == b.spec()  # the spec alone cannot tell them apart
        ka = plan_measure._measure_key(g, a, 1)
        kb = plan_measure._measure_key(g, b, 1)
        assert ka != kb

    def test_grid_only_proposal_is_not_measurable(self):
        g = paper_problem()
        props = search_grids(g, 256, top_k=1)
        with pytest.raises(ValueError, match="grid-only"):
            plan_measure.measure_proposal(g, props[0])


# ---------------------------------------------------------------------------
# plan_from_spec error ergonomics (satellite)
# ---------------------------------------------------------------------------

class TestSpecErrors:
    def test_bare_typo_suggests_key_value(self):
        g = default_geometry(16, n_proj=8)
        with pytest.raises(ValueError) as ei:
            plan_from_spec(g, "pipelned")
        msg = str(ei.value)
        assert "valid keys: impl, window, precision, schedule" in msg
        assert "did you mean 'schedule=pipelined'?" in msg

    def test_unknown_key_lists_valid_and_nearest(self):
        g = default_geometry(16, n_proj=8)
        with pytest.raises(ValueError) as ei:
            plan_from_spec(g, "shedule=fused")
        msg = str(ei.value)
        assert "unknown plan spec key 'shedule'" in msg
        assert "did you mean 'schedule=...'" in msg

    def test_valid_value_of_wrong_kind_suggests_its_key(self):
        g = default_geometry(16, n_proj=8)
        with pytest.raises(ValueError, match="did you mean 'reduce=scatter'"):
            plan_from_spec(g, "scatter")

    def test_auto_token_still_parses_normally(self):
        g = default_geometry(16, n_proj=8)
        plan = plan_from_spec(g, "auto , precision=fp32")
        assert plan.resolved_precision().storage == "fp32"


# ---------------------------------------------------------------------------
# legacy entry-point deprecation (satellite)
# ---------------------------------------------------------------------------

class TestDeprecationWarnings:
    def _fired(self, fn):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            fn()
        return [w for w in rec if issubclass(w.category, DeprecationWarning)
                and "ReconstructionPlan" in str(w.message)]

    def test_each_legacy_entry_point_warns_exactly_once_per_process(self):
        from repro.core import fdk
        from repro.core.distributed import make_distributed_fdk
        from repro.core.pipeline import make_chunked_fdk, make_pipelined_fdk

        g = default_geometry(16, n_proj=8)
        proj = forward_project(g)
        mesh = single_device_mesh()
        calls = {
            "fdk.reconstruct": lambda: reconstruct(g, proj),
            "make_distributed_fdk": lambda: make_distributed_fdk(mesh, g),
            "make_pipelined_fdk": lambda: make_pipelined_fdk(mesh, g,
                                                             n_steps=2),
            "make_chunked_fdk": lambda: make_chunked_fdk(mesh, g, n_steps=2,
                                                         y_chunks=4),
        }
        # the registry is process-wide; reset so this test is order-independent
        fdk._DEPRECATION_FIRED.clear()
        for name, call in calls.items():
            first = self._fired(call)
            assert len(first) == 1, name
            assert name in str(first[0].message)
            assert len(self._fired(call)) == 0, f"{name} warned twice"
