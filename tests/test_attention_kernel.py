"""Flash-attention Pallas kernel vs oracle: shape/dtype/causality sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention_ref, flash_attention

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, h, kh, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kh,d", [
    (2, 128, 4, 2, 32),     # GQA
    (1, 256, 8, 8, 64),     # MHA
    (2, 128, 4, 1, 32),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref_f32(b, s, h, kh, d, causal):
    q, k, v = _qkv(b, s, h, kh, d, jnp.float32)
    want = attention_ref(q, k, v, causal)
    got = flash_attention(q, k, v, causal, bq=64, bk=64)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_tolerance():
    q, k, v = _qkv(2, 128, 4, 2, 32, jnp.bfloat16)
    want = attention_ref(q, k, v, True).astype(jnp.float32)
    got = flash_attention(q, k, v, True, bq=64, bk=64).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(got - want))) < 0.02


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_sweep(bq, bk):
    q, k, v = _qkv(1, 128, 2, 2, 32, jnp.float32)
    want = attention_ref(q, k, v, True)
    got = flash_attention(q, k, v, True, bq=bq, bk=bk)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=2e-5, atol=2e-5)


def test_causality_property():
    """Perturbing future keys must not change earlier outputs."""
    q, k, v = _qkv(1, 128, 2, 2, 32, jnp.float32)
    out1 = flash_attention(q, k, v, True, bq=64, bk=64)
    k2 = k.at[:, 100:].set(0.0)
    v2 = v.at[:, 100:].set(0.0)
    out2 = flash_attention(q, k2, v2, True, bq=64, bk=64)
    np.testing.assert_allclose(np.array(out1[:, :100]),
                               np.array(out2[:, :100]), atol=1e-6)
    assert float(jnp.max(jnp.abs(out1[:, 100:] - out2[:, 100:]))) > 1e-4
