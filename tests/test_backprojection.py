"""Back-projection: Alg. 2 (reference) vs Alg. 4 (factorized), interp2."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.backprojection import (
    backproject_factorized, backproject_reference, bilinear_gather,
    from_dual_slab, to_dual_slab,
)
from repro.core.filtering import filter_projections
from repro.core.geometry import default_geometry, projection_matrices
from repro.core.phantom import forward_project


class TestBilinearGather:
    def test_exact_at_integer_coords(self):
        img = jnp.arange(20.0).reshape(4, 5)
        r = jnp.array([0.0, 1.0, 3.0])
        c = jnp.array([0.0, 2.0, 4.0])
        out = bilinear_gather(img, r, c)
        np.testing.assert_allclose(np.array(out), [0.0, 7.0, 19.0])

    def test_midpoint_interpolation(self):
        img = jnp.array([[0.0, 2.0], [4.0, 6.0]])
        out = bilinear_gather(img, jnp.array([0.5]), jnp.array([0.5]))
        assert float(out[0]) == pytest.approx(3.0)

    def test_zero_outside(self):
        img = jnp.ones((4, 4))
        out = bilinear_gather(
            img, jnp.array([-2.0, 5.0, 0.0]), jnp.array([0.0, 0.0, -3.0])
        )
        np.testing.assert_allclose(np.array(out), 0.0)

    def test_partial_boundary(self):
        """Half a pixel outside contributes half weight (zero padding)."""
        img = jnp.ones((4, 4))
        out = bilinear_gather(img, jnp.array([-0.5]), jnp.array([1.0]))
        assert float(out[0]) == pytest.approx(0.5)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_within_convex_hull(self, seed):
        """Interpolated values never exceed the data range (in-bounds)."""
        rng = np.random.default_rng(seed)
        img = jnp.asarray(rng.normal(size=(8, 9)), jnp.float32)
        r = jnp.asarray(rng.uniform(0, 7, size=16), jnp.float32)
        c = jnp.asarray(rng.uniform(0, 8, size=16), jnp.float32)
        out = bilinear_gather(img, r, c)
        assert float(out.max()) <= float(img.max()) + 1e-5
        assert float(out.min()) >= float(img.min()) - 1e-5


class TestDualSlab:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           nz=st.sampled_from([2, 4, 8, 16]))
    def test_roundtrip(self, seed, nz):
        rng = np.random.default_rng(seed)
        vol = jnp.asarray(rng.normal(size=(3, 5, nz)), jnp.float32)
        assert jnp.array_equal(from_dual_slab(to_dual_slab(vol)), vol)

    def test_mirror_pairing(self):
        vol = jnp.arange(8.0).reshape(1, 1, 8)
        dual = to_dual_slab(vol)
        # dual[..., 1, k] must hold voxel nz-1-k
        np.testing.assert_allclose(np.array(dual[0, 0, 1]), [7, 6, 5, 4])


class TestEquivalence:
    """The paper's validation: factorized output == reference (RMSE < 1e-5)."""

    # (16, 12) was (24, 12): the second point only needs a distinct
    # (size, view-count) pair, not a bigger volume — fast-tier diet.
    @pytest.mark.parametrize("n,n_proj", [(16, 8), (16, 12)])
    def test_reference_vs_factorized(self, n, n_proj):
        g = default_geometry(n, n_proj=n_proj)
        pm = jnp.asarray(projection_matrices(g))
        q = filter_projections(g, forward_project(g))
        ref = backproject_reference(pm, q, g.n_x, g.n_y, g.n_z)
        fac = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-12
        rmse = float(jnp.sqrt(jnp.mean((ref - fac) ** 2))) / scale
        assert rmse < 1e-5  # the paper's acceptance bound
        assert float(jnp.max(jnp.abs(ref - fac))) / scale < 1e-4

    def test_factorized_requires_even_nz(self):
        g = default_geometry(16, n_proj=4)
        pm = jnp.asarray(projection_matrices(g))
        q = jnp.zeros(g.proj_shape(), jnp.float32)
        with pytest.raises(ValueError):
            backproject_factorized(pm, q, g.n_x, g.n_y, 15)

    def test_zero_projections_give_zero_volume(self):
        g = default_geometry(12, n_proj=4)
        pm = jnp.asarray(projection_matrices(g))
        q = jnp.zeros(g.proj_shape(), jnp.float32)
        for fn in (backproject_reference, backproject_factorized):
            vol = fn(pm, q, g.n_x, g.n_y, g.n_z)
            assert float(jnp.max(jnp.abs(vol))) == 0.0

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_linearity_in_projections(self, seed):
        """BP is linear: BP(a+b) == BP(a) + BP(b) — the property that makes
        the distributed column-sum (MPI_Reduce) decomposition exact."""
        g = default_geometry(12, n_proj=4)
        pm = jnp.asarray(projection_matrices(g))
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        b = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        lhs = backproject_factorized(pm, a + b, g.n_x, g.n_y, g.n_z)
        rhs = (backproject_factorized(pm, a, g.n_x, g.n_y, g.n_z)
               + backproject_factorized(pm, b, g.n_x, g.n_y, g.n_z))
        np.testing.assert_allclose(np.array(lhs), np.array(rhs),
                                   rtol=2e-3, atol=2e-5)
