"""Filtering stage (Alg. 1): ramp kernel, windows, FFT convolution."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.filtering import (
    cosine_weights, fft_length, filter_projections, make_filter,
    ramp_frequency_response, ramp_kernel,
)
from repro.core.geometry import default_geometry


class TestRampKernel:
    def test_kak_slaney_values(self):
        tau = 0.5
        h = ramp_kernel(16, tau)
        assert h[0] == pytest.approx(1 / (4 * tau * tau))
        assert h[2] == 0.0 and h[4] == 0.0
        assert h[1] == pytest.approx(-1 / (np.pi * tau) ** 2)
        assert h[3] == pytest.approx(-1 / (3 * np.pi * tau) ** 2)
        # wrapped negative lags
        assert h[15] == h[1] and h[13] == h[3]

    def test_dc_is_suppressed(self):
        """The ramp filter kills constant signals: DC of the truncated
        kernel is small and decays ~1/N with kernel length."""
        h256 = ramp_kernel(256, 1.0)
        h1k = ramp_kernel(1024, 1.0)
        assert abs(h256.sum()) < 5e-3 * abs(h256[0])
        assert abs(h1k.sum()) < 0.3 * abs(h256.sum())

    def test_fft_length(self):
        assert fft_length(64) == 128
        assert fft_length(65) == 256
        assert fft_length(100) == 256


class TestWindows:
    @pytest.mark.parametrize("window", ["ramlak", "shepp-logan", "hann",
                                        "hamming"])
    def test_windows_real_and_bounded(self, window):
        g = default_geometry(16, n_proj=4)
        hf = ramp_frequency_response(g, window)
        assert hf.dtype == np.complex64
        ramlak = ramp_frequency_response(g, "ramlak")
        assert np.all(np.abs(hf) <= np.abs(ramlak) + 1e-5)

    def test_unknown_window_raises(self):
        g = default_geometry(16, n_proj=4)
        with pytest.raises(ValueError):
            ramp_frequency_response(g, "lanczos")


class TestFiltering:
    def test_constant_rows_filter_to_near_zero(self):
        g = default_geometry(64, n_proj=4)
        proj = jnp.ones(g.proj_shape(), jnp.float32)
        q = filter_projections(g, proj)
        # interior of a constant row is ~0 after the ramp (edges ring);
        # the truncation tail shrinks with detector width
        inner = q[..., g.n_u // 4: -g.n_u // 4]
        assert float(jnp.max(jnp.abs(inner))) < 0.05 * float(
            jnp.max(jnp.abs(q))
        )

    def test_linearity(self):
        g = default_geometry(16, n_proj=2)
        k1, k2 = jnp.ones(g.proj_shape()), 0.0
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        b = jnp.asarray(rng.normal(size=g.proj_shape()), jnp.float32)
        filt = make_filter(g)
        lhs = filt(2.0 * a + 3.0 * b)
        rhs = 2.0 * filt(a) + 3.0 * filt(b)
        np.testing.assert_allclose(np.array(lhs), np.array(rhs),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_filter_preserves_shape_and_finiteness(self, seed):
        g = default_geometry(12, n_proj=3)
        rng = np.random.default_rng(seed)
        proj = jnp.asarray(
            rng.uniform(0, 2, size=g.proj_shape()), jnp.float32
        )
        q = filter_projections(g, proj)
        assert q.shape == proj.shape
        assert bool(jnp.all(jnp.isfinite(q)))

    def test_cosine_weights_max_at_center(self):
        g = default_geometry(16, n_proj=2)
        w = cosine_weights(g)
        assert w.shape == (g.n_v, g.n_u)
        assert np.all(w <= 1.0 + 1e-6) and np.all(w > 0)
        cu, cv = (g.n_u - 1) // 2, (g.n_v - 1) // 2
        assert w[cv, cu] == w.max()
