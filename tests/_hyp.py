"""Optional-hypothesis shim.

With `hypothesis` installed the property tests run as real property tests.
Without it (this container ships no hypothesis), `given`/`settings`/`st`
degrade to a deterministic pytest.mark.parametrize fallback: each strategy
contributes a fixed sample pool and the test runs once per zipped sample
tuple — the same properties, exercised on a small fixed grid, so
`pytest -x -q` never dies at collection and the round-trip/equivalence
properties keep coverage either way.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import pytest

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _FallbackStrategies:
        @staticmethod
        def integers(lo, hi):
            span = hi - lo
            picks = {lo, hi, lo + span // 3, lo + (2 * span) // 3,
                     lo + span // 7}
            return _Strategy(sorted(picks))

        @staticmethod
        def floats(lo, hi, **_kw):
            span = hi - lo
            return _Strategy([lo, lo + 0.37 * span, lo + 0.73 * span, hi])

        @staticmethod
        def sampled_from(xs):
            return _Strategy(list(xs))

    st = _FallbackStrategies()

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**kwargs):
        names = sorted(kwargs)
        pools = [kwargs[n].samples for n in names]
        width = max(len(p) for p in pools)
        cases = [tuple(p[i % len(p)] for p in pools) for i in range(width)]
        if len(names) == 1:
            cases = [c[0] for c in cases]

        def deco(fn):
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
