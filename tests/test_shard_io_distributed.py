"""Shard store + checkpoint + pipeline I/O on a virtual 8-device mesh
(subprocess: the device-count flag must be set before jax initializes, and
the main test process keeps the real 1-device CPU view).

Proves the three multi-host claims the single-device tier cannot:
  * save under an 8-device mesh writes ONE FILE PER ADDRESSABLE SHARD;
  * scatter-read restore opens only the shard files each target region
    intersects (file-open accounting), bit-exactly;
  * restore onto a DIFFERENT mesh shape (elastic 8 -> 4) is the same code
    path, including through `load_checkpoint`.
"""
import json
import os
import subprocess
import sys
import tempfile

import pytest

pytestmark = pytest.mark.slow  # multi-minute subprocess (8 virtual devices)

_SCRIPT = r"""
import os, sys, glob
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.mesh import make_mesh
from repro.io import shard_store
from repro.io.streams import ProjectionSource, VolumeSink

tmp = sys.argv[1]
results = {}

mesh8 = make_mesh((2, 4), ("data", "model"))
a = jnp.arange(8 * 6 * 4, dtype=jnp.float32).reshape(8, 6, 4)
sharded = jax.device_put(a, NamedSharding(mesh8, P(("data", "model"))))

# 1. one file per addressable shard (8 devices -> 8 shard files)
path = os.path.join(tmp, "arr")
shard_store.save_array(path, sharded)
results["n_files"] = len(glob.glob(os.path.join(path, "shards", "*.bin")))
results["n_manifest"] = len(shard_store.read_manifest(path)["shards"])

# 2. bit-exact scatter-read restore onto the WRITER's sharding: every
#    region is exactly one shard -> exactly 8 file opens, no over-read
shard_store.reset_open_count()
out8 = shard_store.load_array(path, NamedSharding(mesh8, P(("data", "model"))))
results["opens_8way"] = shard_store.open_count()
results["exact_8way"] = bool((np.asarray(out8) == np.asarray(a)).all())

# 3. elastic 8 -> 4: restore onto a 2x2 mesh over the first 4 devices;
#    each of the 4 target regions straddles exactly 2 of the 8 files
mesh4 = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
shard_store.reset_open_count()
out4 = shard_store.load_array(path, NamedSharding(mesh4, P(("data", "model"))))
results["opens_4way"] = shard_store.open_count()
results["exact_4way"] = bool((np.asarray(out4) == np.asarray(a)).all())
results["shards_4way"] = len([s for s in out4.addressable_shards
                              if s.replica_id == 0])

# 3b. one rank's slice costs one file open (the restoring host reads only
#     what it owns)
shard_store.reset_open_count()
region = shard_store.read_region(path, (slice(0, 1), slice(0, 6), slice(0, 4)))
results["opens_one_rank"] = shard_store.open_count()
results["exact_one_rank"] = bool((region == np.asarray(a[:1])).all())

# 4. checkpoint on the async-manager path: per-shard leaf files, restore
#    onto the 4-device mesh via the manifest's PartitionSpec
from repro.checkpoint import CheckpointManager, load_checkpoint
ckdir = os.path.join(tmp, "ckpt")
mgr = CheckpointManager(ckdir)
tree = {"vol": sharded, "step": np.int64(3)}
mgr.save(7, tree, blocking=False)
mgr.wait()
manifest = json.load(open(os.path.join(ckdir, "step_00000007",
                                       "MANIFEST.json")))
by_key = {e["key"]: e for e in manifest["leaves"]}
vol_name = by_key["['vol']"]["name"]
results["ckpt_vol_files"] = len(glob.glob(os.path.join(
    ckdir, "step_00000007", "leaves", vol_name, "shards", "*.bin")))
results["ckpt_vol_spec"] = by_key["['vol']"]["spec"]
results["ckpt_step_spec"] = by_key["['step']"]["spec"]

like = {"vol": jnp.zeros_like(a), "step": np.int64(0)}
shard_store.reset_open_count()
step, restored = mgr.restore_latest(like, mesh=mesh4)
results["ckpt_opens"] = shard_store.open_count()
results["ckpt_step"] = step
results["ckpt_exact"] = bool(
    (np.asarray(restored["vol"]) == np.asarray(a)).all()
    and int(restored["step"]) == 3)
results["ckpt_resharded"] = bool(
    isinstance(restored["vol"].sharding, NamedSharding)
    and restored["vol"].sharding.mesh.shape == {"data": 2, "model": 2})

# 5. full pipeline: ProjectionSource -> plan engine -> VolumeSink
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan

g = default_geometry(16, n_proj=32)
proj = forward_project(g)
ref = np.asarray(ReconstructionPlan(geometry=g).build()(proj))

src = ProjectionSource.write(os.path.join(tmp, "proj"), np.asarray(proj),
                             chunks=(8, 1, 1))   # slice-per-rank layout
plan = ReconstructionPlan(geometry=g, mesh=mesh8, reduce="scatter")
sink = VolumeSink(os.path.join(tmp, "vol_out"))
fdk = plan.build(source=src, sink=sink)
shard_store.reset_open_count()
vol = np.asarray(fdk())
results["e2e_src_opens"] = shard_store.open_count()
results["e2e_err"] = float(np.max(np.abs(vol - ref)))
results["e2e_sink_files"] = len(glob.glob(os.path.join(
    tmp, "vol_out", "shards", "*.bin")))
results["e2e_store_exact"] = bool((sink.read() == vol).all())

print("RESULTS" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def io_results(tmp_path_factory):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    tmp = str(tmp_path_factory.mktemp("shard_io"))
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, tmp], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


def test_one_file_per_addressable_shard(io_results):
    assert io_results["n_files"] == 8
    assert io_results["n_manifest"] == 8


def test_scatter_read_is_bit_exact_and_opens_only_needed(io_results):
    assert io_results["exact_8way"] is True
    assert io_results["opens_8way"] == 8       # one file per region, no more
    assert io_results["exact_one_rank"] is True
    assert io_results["opens_one_rank"] == 1   # one rank slice -> one file


def test_elastic_restore_onto_smaller_mesh(io_results):
    assert io_results["exact_4way"] is True
    assert io_results["shards_4way"] == 4
    # 4 target regions x 2 straddled files each — NOT 4 devices x 8 files
    assert io_results["opens_4way"] == 8


def test_checkpoint_writes_per_shard_files_and_reshards(io_results):
    assert io_results["ckpt_vol_files"] == 8
    assert io_results["ckpt_vol_spec"] == [["data", "model"]]
    assert io_results["ckpt_step_spec"] is None
    assert io_results["ckpt_step"] == 7
    assert io_results["ckpt_exact"] is True
    assert io_results["ckpt_resharded"] is True
    # vol: 4 regions x 2 files; step scalar: 1 file
    assert io_results["ckpt_opens"] == 9


def test_pipeline_source_to_sink(io_results):
    assert io_results["e2e_err"] < 5e-6
    # each rank's projection slice is exactly one stored chunk
    assert io_results["e2e_src_opens"] == 8
    # slice-per-rank PFS store: R x C_data = 4 x 2 slab files
    assert io_results["e2e_sink_files"] == 8
    assert io_results["e2e_store_exact"] is True
