"""Shard-level array store (repro/io): layout, scatter reads, corruption.

The multi-device behaviours (one file per addressable shard, elastic
8 -> 4 restore) live in tests/test_shard_io_distributed.py; this module
covers everything observable on one device — including the file-open
accounting of region reads, which needs no mesh because `read_region`
takes global coordinates directly.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.io import (
    ProjectionSource, StoreError, VolumeSink, load_array, open_count,
    read_manifest, read_region, reset_open_count, save_array, snapshot,
    stored_spec,
)
from repro.io.shard_store import HostShardedArray
from repro.parallel.mesh import single_device_mesh

from tests._hyp import given, settings, st


class TestRoundTrip:
    @pytest.mark.parametrize("value", [
        np.arange(24.0, dtype=np.float32).reshape(4, 6),
        np.arange(8, dtype=np.int64),
        np.int64(7),                       # 0-d host scalar
        jnp.float32(3.5),                  # 0-d device scalar
    ], ids=["f32-2d", "i64-1d", "host-scalar", "dev-scalar"])
    def test_bit_exact(self, tmp_path, value):
        path = str(tmp_path / "a")
        save_array(path, value)
        out = load_array(path)
        assert out.shape == np.shape(value)
        assert out.dtype == np.asarray(value).dtype
        np.testing.assert_array_equal(np.asarray(out), np.asarray(value))

    def test_bf16_storage_dtype_survives(self, tmp_path):
        """Raw-bytes shard files round-trip the ml_dtypes storage types
        numpy's .npy format cannot represent."""
        arr = (jnp.arange(12.0).reshape(3, 4) * 0.25).astype(jnp.bfloat16)
        path = str(tmp_path / "bf16")
        save_array(path, arr)
        assert read_manifest(path)["dtype"] == "bfloat16"
        out = load_array(path)
        assert out.dtype == jnp.bfloat16.dtype
        np.testing.assert_array_equal(np.asarray(out), np.asarray(arr))

    def test_chunked_host_write_one_file_per_chunk(self, tmp_path):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        path = str(tmp_path / "a")
        save_array(path, a, chunks=(2, 2))
        files = sorted(os.listdir(os.path.join(path, "shards")))
        assert len(files) == 4
        np.testing.assert_array_equal(load_array(path), a)

    def test_save_clears_stale_store(self, tmp_path):
        path = str(tmp_path / "a")
        save_array(path, np.zeros((8, 8), np.float32), chunks=(4, 1))
        save_array(path, np.ones((4, 4), np.float32))  # smaller, 1 shard
        assert len(os.listdir(os.path.join(path, "shards"))) == 1
        np.testing.assert_array_equal(load_array(path),
                                      np.ones((4, 4), np.float32))

    def test_bad_chunks_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="chunks"):
            save_array(str(tmp_path / "a"), np.zeros((8, 8)), chunks=(3, 1))
        with pytest.raises(ValueError, match="chunks"):
            save_array(str(tmp_path / "a"), np.zeros((8, 8)), chunks=(2,))


class TestScatterRead:
    def _store(self, tmp_path):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        path = str(tmp_path / "a")
        save_array(path, a, chunks=(2, 2))  # 4 files of 4x4
        return path, a

    def test_region_opens_only_intersecting_files(self, tmp_path):
        path, a = self._store(tmp_path)
        reset_open_count()
        out = read_region(path, (slice(0, 4), slice(0, 4)))
        assert open_count() == 1            # one quadrant -> one file
        np.testing.assert_array_equal(out, a[:4, :4])
        reset_open_count()
        out = read_region(path, (slice(2, 6), slice(0, 8)))
        assert open_count() == 4            # straddles every quadrant
        np.testing.assert_array_equal(out, a[2:6, :])
        reset_open_count()
        out = read_region(path, (slice(5, 7), slice(1, 6)))
        assert open_count() == 2            # bottom two quadrants only
        np.testing.assert_array_equal(out, a[5:7, 1:6])

    def test_full_load_opens_every_file_once(self, tmp_path):
        path, a = self._store(tmp_path)
        reset_open_count()
        np.testing.assert_array_equal(load_array(path), a)
        assert open_count() == 4

    def test_load_onto_sharding_resharding(self, tmp_path):
        """Restore a host-chunked store onto a mesh sharding the writer
        never saw (reshard-on-restore, single-device edition)."""
        path, a = self._store(tmp_path)
        mesh = single_device_mesh()
        out = load_array(path, NamedSharding(mesh, P("model")))
        assert isinstance(out, jax.Array)
        assert isinstance(out.sharding, NamedSharding)
        np.testing.assert_array_equal(np.asarray(out), a)

    def test_snapshot_roundtrip_keeps_spec(self, tmp_path):
        mesh = single_device_mesh()
        arr = jax.device_put(jnp.arange(16.0).reshape(4, 4),
                             NamedSharding(mesh, P("model")))
        snap = snapshot(arr)
        assert isinstance(snap, HostShardedArray)
        assert snap.spec == ["model"]
        path = str(tmp_path / "s")
        save_array(path, snap)
        assert stored_spec(path) == P("model")
        np.testing.assert_array_equal(load_array(path), np.asarray(arr))

    def test_snapshot_of_host_value_is_numpy(self):
        snap = snapshot(np.int64(3))
        assert isinstance(snap, np.ndarray) and snap.shape == ()

    def test_spec_none_vs_empty_distinguished(self, tmp_path):
        """A replicated NamedSharding records spec [] (a REAL, empty
        PartitionSpec); a host array records None (no spec at all)."""
        mesh = single_device_mesh()
        rep = jax.device_put(jnp.ones((3,)), NamedSharding(mesh, P()))
        save_array(str(tmp_path / "rep"), rep)
        save_array(str(tmp_path / "host"), np.ones((3,), np.float32))
        assert read_manifest(str(tmp_path / "rep"))["spec"] == []
        assert read_manifest(str(tmp_path / "host"))["spec"] is None
        assert stored_spec(str(tmp_path / "rep")) == P()
        assert stored_spec(str(tmp_path / "host")) is None


class TestCorruption:
    def _store(self, tmp_path):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        path = str(tmp_path / "a")
        save_array(path, a, chunks=(2, 2))
        return path, a

    @settings(max_examples=10, deadline=None)
    @given(kind=st.sampled_from(["truncate", "delete_file", "drop_entry",
                                 "no_manifest"]))
    def test_each_corruption_raises_store_error(self, tmp_path, kind):
        path, _ = self._store(tmp_path)
        shard0 = os.path.join(path, "shards", "shard_00000.bin")
        if kind == "truncate":
            with open(shard0, "r+b") as f:
                f.truncate(10)
            match = "truncated"
        elif kind == "delete_file":
            os.remove(shard0)
            match = "missing shard file"
        elif kind == "drop_entry":
            mpath = os.path.join(path, "MANIFEST.json")
            with open(mpath) as f:
                m = json.load(f)
            del m["shards"][0]
            with open(mpath, "w") as f:
                json.dump(m, f)
            match = "does not cover"
        else:  # no_manifest
            os.remove(os.path.join(path, "MANIFEST.json"))
            match = "missing MANIFEST"
        with pytest.raises(StoreError, match=match):
            load_array(path)

    def test_intact_region_readable_despite_distant_corruption(self,
                                                               tmp_path):
        """Scatter reads only open what they need: corruption in one
        quadrant leaves the others readable."""
        path, a = self._store(tmp_path)
        with open(os.path.join(path, "shards", "shard_00003.bin"),
                  "r+b") as f:
            f.truncate(3)
        np.testing.assert_array_equal(
            read_region(path, (slice(0, 4), slice(0, 4))), a[:4, :4])
        with pytest.raises(StoreError, match="truncated"):
            read_region(path, (slice(4, 8), slice(4, 8)))


class TestStreams:
    def test_projection_source_shape_dtype_and_load(self, tmp_path):
        proj = np.random.default_rng(0).standard_normal(
            (8, 4, 6)).astype(np.float32)
        src = ProjectionSource.write(str(tmp_path / "proj"), proj,
                                     chunks=(4, 1, 1))
        assert src.shape == (8, 4, 6)
        assert src.dtype == np.float32
        np.testing.assert_array_equal(np.asarray(src.load()), proj)
        mesh = single_device_mesh()
        out = src.load(mesh)
        assert isinstance(out.sharding, NamedSharding)
        np.testing.assert_array_equal(np.asarray(out), proj)

    def test_volume_sink_write_read_nbytes(self, tmp_path):
        vol = np.arange(4 * 4 * 2, dtype=np.float32).reshape(4, 4, 2)
        sink = VolumeSink(str(tmp_path / "vol"))
        assert sink.write(vol) == str(tmp_path / "vol")
        np.testing.assert_array_equal(sink.read(), vol)
        assert sink.nbytes() == vol.nbytes

    def test_plan_build_with_source_and_sink_matches_engine(self, tmp_path):
        from repro.core.geometry import default_geometry
        from repro.core.phantom import forward_project
        from repro.core.plan import ReconstructionPlan

        g = default_geometry(16, n_proj=32)
        proj = forward_project(g)
        plan = ReconstructionPlan(geometry=g)
        ref = np.asarray(plan.build()(proj))
        src = ProjectionSource.write(str(tmp_path / "p"), np.asarray(proj),
                                     chunks=(8, 1, 1))
        sink = VolumeSink(str(tmp_path / "v"))
        fdk = plan.build(source=src, sink=sink)
        vol = np.asarray(fdk())                 # argument-free: streams in
        np.testing.assert_array_equal(vol, ref)
        np.testing.assert_array_equal(sink.read(), vol)  # and streams out

    def test_plan_build_without_source_needs_projections(self):
        from repro.core.geometry import default_geometry
        from repro.core.plan import ReconstructionPlan

        plan = ReconstructionPlan(geometry=default_geometry(16, n_proj=32))
        fdk = plan.build(sink=VolumeSink("/nonexistent"))
        with pytest.raises(TypeError, match="ProjectionSource"):
            fdk()


class TestEncodedStreams:
    """ISSUE 5: ProjectionSource persists/loads stream-codec wire formats —
    quantized shards + the per-projection scale sidecar store."""

    def _case(self):
        from repro.core.filtering import filter_projections
        from repro.core.geometry import default_geometry
        from repro.core.phantom import forward_project

        g = default_geometry(16, n_proj=8)
        return g, filter_projections(g, forward_project(g),
                                     out_dtype=jnp.float32)

    def test_fp8_roundtrip_bitexact(self, tmp_path):
        """Acceptance: encoded projections round-trip bit-exactly through
        the shard store (data bytes AND scale sidecar)."""
        from repro.core.precision import Precision

        g, q = self._case()
        codec = Precision("fp8_e4m3").codec
        want_data, want_scales = codec.encode(q)
        src = ProjectionSource.write(str(tmp_path / "enc"), np.asarray(q),
                                     chunks=(4, 1, 1), codec="fp8_e4m3")
        assert src.codec_name == "fp8_e4m3"
        assert src.dtype == np.dtype(jnp.float8_e4m3fn)
        data, scales = src.load_encoded()
        np.testing.assert_array_equal(
            np.asarray(data).view(np.uint8),
            np.asarray(want_data).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(scales),
                                      np.asarray(want_scales))
        # decode on load: both the host path and the scatter-read path
        want = np.asarray(codec.decode(want_data, want_scales))
        np.testing.assert_array_equal(np.asarray(src.load()), want)
        mesh = single_device_mesh()
        np.testing.assert_array_equal(np.asarray(src.load(mesh)), want)

    def test_fp16_sidecar_store_is_written(self, tmp_path):
        """The fp16 codec is scaled too (scale-on-overflow): its sidecar
        store exists and holds exact ones for an in-range stream."""
        _, q = self._case()
        src = ProjectionSource.write(str(tmp_path / "h"), np.asarray(q),
                                     codec="fp16")
        data, scales = src.load_encoded()
        assert data.dtype == np.dtype(np.float16)
        assert scales is not None and np.all(np.asarray(scales) == 1.0)

    def test_raw_store_has_no_codec(self, tmp_path):
        _, q = self._case()
        src = ProjectionSource.write(str(tmp_path / "raw"), np.asarray(q))
        assert src.codec_name is None
        _, scales = src.load_encoded()
        assert scales is None

    def test_fp8_store_quarters_disk_bytes(self, tmp_path):
        """The on-disk stream is 1/4 of f32 + the 4 B/projection sidecar —
        the same arithmetic as the AllGather wire bytes."""
        from repro.io import shard_store

        g, q = self._case()
        raw = ProjectionSource.write(str(tmp_path / "raw"), np.asarray(q))
        enc = ProjectionSource.write(str(tmp_path / "enc"), np.asarray(q),
                                     codec="fp8_e4m3")

        def payload(path, sub=""):
            sdir = os.path.join(path, sub, shard_store.SHARD_DIR)
            return sum(os.path.getsize(os.path.join(sdir, f))
                       for f in os.listdir(sdir))

        assert payload(enc.path) == payload(raw.path) // 4
        assert payload(enc.path, "scales") == 4 * g.n_proj

    def test_encoded_source_feeds_plan_engine(self, tmp_path):
        """An fp8-encoded source closes the pipeline: load decodes to f32
        and the engine reconstructs within the fp8 tolerance."""
        from repro.core.geometry import default_geometry
        from repro.core.phantom import forward_project
        from repro.core.plan import ReconstructionPlan
        from repro.core.precision import Precision

        g = default_geometry(16, n_proj=8)
        proj = forward_project(g)
        plan = ReconstructionPlan(geometry=g)
        ref = np.asarray(plan.build()(proj))
        src = ProjectionSource.write(str(tmp_path / "p8"), np.asarray(proj),
                                     chunks=(4, 1, 1), codec="fp8_e4m3")
        vol = np.asarray(plan.build(source=src)())
        p = Precision("fp8_e4m3")
        scale = float(np.max(np.abs(ref))) + 1e-12
        rmse = float(np.sqrt(np.mean((vol - ref) ** 2))) / scale
        assert rmse < p.rmse_tol()
