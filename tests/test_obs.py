"""Observability (repro/obs): span tracer, metrics registry, attribution.

Covers the PR-8 acceptance checks: span nesting + thread-safety, the
disabled-mode overhead contract (<1% of the fast e2e reconstruction),
Perfetto trace_event schema of exported traces, histogram bucket edge
semantics, and the predicted-vs-measured attribution join on a 1x1x1-mesh
traced reconstruction (every nonzero PerfBreakdown stage must get a
measured counterpart).
"""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.cache import CountingLRU
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import clear_engine_cache, plan_from_spec
from repro.io import ProjectionSource, SourcePrefetcher, VolumeSink
from repro.obs import attribution, metrics, trace
from repro.obs.metrics import (
    DEFAULT_TIME_BUCKETS, Counter, Gauge, Histogram, MetricsRegistry,
)
from repro.obs.trace import Span, Tracer
from repro.parallel.mesh import make_mesh

PERFETTO_KEYS = {"ph", "ts", "dur", "name", "pid", "tid"}


@pytest.fixture
def tracer():
    """Fresh enabled tracer installed as the process default (so library
    instrumentation points record into it), restored afterward."""
    tr = Tracer(enabled=True)
    prev = trace.set_tracer(tr)
    yield tr
    trace.set_tracer(prev)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

class TestSpans:
    def test_span_records_complete_event(self, tracer):
        with tracer.span("unit.outer", k=1) as sp:
            sp.set(extra="v")
        (ev,) = tracer.events()
        assert ev["ph"] == "X" and ev["name"] == "unit.outer"
        assert ev["dur"] >= 0 and ev["ts"] >= 0
        assert ev["args"] == {"k": 1, "extra": "v"}
        assert ev["tid"] == threading.get_ident()

    def test_nesting_by_interval_containment(self, tracer):
        with tracer.span("unit.outer"):
            with tracer.span("unit.inner"):
                time.sleep(0.001)
        by_name = {e["name"]: e for e in tracer.events()}
        inner, outer = by_name["unit.inner"], by_name["unit.outer"]
        assert inner["tid"] == outer["tid"]
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert outer["dur"] >= inner["dur"]

    def test_disabled_returns_shared_null_span(self):
        tr = Tracer(enabled=False)
        s1, s2 = tr.span("a"), tr.span("b", k=1)
        assert s1 is s2                       # preallocated no-op singleton
        with s1 as sp:
            assert sp.fence(123) == 123
            sp.set(x=1)
        assert tr.events() == [] and sp.duration_s == 0.0

    def test_timed_span_measures_without_recording(self):
        tr = Tracer(enabled=False)
        with tr.span("unit.measured", timed=True) as sp:
            time.sleep(0.002)
        assert sp.duration_s >= 0.002
        assert tr.events() == []              # measured, never recorded

    def test_fence_records_dispatch_time(self, tracer):
        with tracer.span("unit.fenced") as sp:
            out = jnp.arange(8) * 2
            sp.fence(out)
        (ev,) = tracer.events()
        assert "dispatch_us" in ev["args"]
        assert 0 <= ev["args"]["dispatch_us"] <= ev["dur"]

    def test_exception_annotates_and_still_records(self, tracer):
        with pytest.raises(ValueError):
            with tracer.span("unit.bad"):
                raise ValueError("boom")
        (ev,) = tracer.events()
        assert ev["args"]["error"] == "ValueError"

    def test_thread_safety(self, tracer):
        n_threads, per = 8, 200
        barrier = threading.Barrier(n_threads)   # all truly concurrent

        def work():
            barrier.wait()
            for i in range(per):
                with tracer.span("unit.t", i=i):
                    pass

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        evs = tracer.events()
        assert len(evs) == n_threads * per
        assert len({e["tid"] for e in evs}) == n_threads

    def test_max_events_bound_drops_new_spans(self):
        tr = Tracer(enabled=True, max_events=10)
        for i in range(15):
            with tr.span(f"unit.{i}"):
                pass
        assert len(tr.events()) == 10 and tr.dropped == 5
        assert tr.export()["otherData"]["dropped"] == 5
        tr.clear()
        assert tr.events() == [] and tr.dropped == 0

    def test_stage_totals_sums_per_name(self, tracer):
        for _ in range(3):
            with tracer.span("stage.fake"):
                time.sleep(0.001)
        totals = tracer.stage_totals()
        assert totals["stage.fake"] >= 0.003
        assert tracer.stage_totals("nomatch.") == {}


class TestPerfettoExport:
    def test_schema_required_keys(self, tracer):
        with tracer.span("unit.a", k=1):
            with tracer.span("unit.b"):
                pass
        tracer.instant("unit.marker")
        out = tracer.export()
        json.loads(json.dumps(out))           # wire-format serializable
        assert out["traceEvents"]
        for ev in out["traceEvents"]:
            if ev["ph"] == "X":
                assert PERFETTO_KEYS <= set(ev)
                assert isinstance(ev["ts"], float) and ev["ts"] >= 0
                assert isinstance(ev["dur"], float) and ev["dur"] >= 0
            else:
                assert ev["ph"] == "i" and "ts" in ev

    def test_save_round_trips(self, tracer, tmp_path):
        with tracer.span("unit.saved"):
            pass
        path = tracer.save(str(tmp_path / "trace.json"))
        loaded = json.load(open(path))
        assert loaded["traceEvents"][0]["name"] == "unit.saved"
        assert PERFETTO_KEYS <= set(loaded["traceEvents"][0])


class TestDisabledOverhead:
    def test_disabled_span_under_one_percent_of_fast_e2e(self):
        """The acceptance contract: with tracing disabled, the per-span
        hot-path cost (one attr load + branch, shared null span) must be
        <1% of the fast e2e reconstruction at well above the real span
        density (a source->engine->sink call crosses 3 instrumentation
        points; assert at 8)."""
        g = default_geometry(16, n_proj=8)
        proj = jnp.asarray(forward_project(g))
        clear_engine_cache()
        fdk = plan_from_spec(g, "auto").build()
        jax.block_until_ready(fdk(proj))      # compile + warm
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fdk(proj))
        e2e_s = (time.perf_counter() - t0) / 5

        tr = Tracer(enabled=False)
        n = 100_000
        t0 = time.perf_counter()
        for _ in range(n):
            with tr.span("hot"):
                pass
        per_span_s = (time.perf_counter() - t0) / n
        assert per_span_s * 8 < 0.01 * e2e_s, (
            f"disabled span costs {per_span_s * 1e9:.0f} ns; 8 of them "
            f"exceed 1% of the {e2e_s * 1e3:.1f} ms e2e reconstruction")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter(self):
        c = Counter("n")
        c.inc()
        c.inc(4)
        c.inc(0)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_tracks_high_water(self):
        gg = Gauge("depth")
        gg.set(3)
        gg.inc()
        gg.dec(2)
        assert gg.value == 2.0 and gg.max_value == 4.0

    def test_histogram_edge_validation(self):
        with pytest.raises(ValueError):
            Histogram("h", buckets=())
        with pytest.raises(ValueError):
            Histogram("h", buckets=(1.0, 1.0, 2.0))       # not strict
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))            # not increasing
        with pytest.raises(ValueError):
            Histogram("h", buckets=(1.0, float("inf")))   # inf is implicit

    def test_histogram_bucket_placement(self):
        h = Histogram("h", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 3.0, 100.0):
            h.observe(v)
        s = h.snapshot()
        assert s["buckets"] == {"le_1": 2, "le_2": 0, "le_4": 1,
                                "le_inf": 1}
        assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 100.0
        assert s["sum"] == pytest.approx(104.5)
        assert s["mean"] == pytest.approx(104.5 / 4)

    def test_empty_histogram_snapshot(self):
        s = Histogram("h", buckets=(1.0,)).snapshot()
        assert s["count"] == 0 and s["mean"] is None and s["min"] is None

    def test_default_time_buckets_are_valid_edges(self):
        h = Histogram("h")                    # default edges must construct
        assert h.edges == DEFAULT_TIME_BUCKETS
        assert list(h.edges) == sorted(h.edges)

    def test_registry_get_or_create_and_collisions(self):
        reg = MetricsRegistry()
        assert reg.counter("a.b") is reg.counter("a.b")
        with pytest.raises(TypeError):
            reg.gauge("a.b")                  # name taken by a Counter
        reg.histogram("h", (1.0, 2.0))
        with pytest.raises(ValueError):
            reg.histogram("h", (1.0, 3.0))    # edge mismatch
        assert reg.names() == ["a.b", "h"]

    def test_registry_value_and_snapshot(self):
        reg = MetricsRegistry()
        reg.counter("c").inc(2)
        reg.gauge("g").set(7)
        reg.histogram("h", (1.0,)).observe(0.5)
        assert reg.value("c") == 2
        assert reg.value("missing", default=None) is None
        snap = reg.snapshot()
        assert snap["c"] == 2 and snap["g"] == {"value": 7.0, "max": 7.0}
        assert snap["h"]["count"] == 1
        assert "c: 2" in reg.render()
        reg.reset()
        assert reg.names() == []

    def test_counting_lru_mirrors_to_default_registry(self):
        reg = metrics.default_registry()
        base = reg.value("cache.obs_test_lru.hits", 0)
        lru = CountingLRU(2, name="obs_test_lru")
        lru.put("a", 1)
        lru.put("b", 2)
        lru.put("c", 3)                       # evicts "a"
        assert lru.get("b") == 2
        assert lru.get("zz") is None
        lru.get([1, 2])                       # unhashable
        assert reg.value("cache.obs_test_lru.hits") - base == lru.hits == 1
        assert reg.value("cache.obs_test_lru.misses") >= lru.misses == 1
        assert reg.value("cache.obs_test_lru.evictions") >= 1
        assert reg.value("cache.obs_test_lru.unhashable") >= 1

    def test_prefetcher_counts_into_default_registry(self):
        reg = metrics.default_registry()
        before = reg.value("io.prefetch.loads", 0)
        pf = SourcePrefetcher([lambda: 1, lambda: 2], depth=2)
        assert list(pf) == [1, 2]
        pf.close()
        assert reg.value("io.prefetch.loads") - before == 2


# ---------------------------------------------------------------------------
# attribution: predicted (PerfBreakdown) vs measured (traced engine)
# ---------------------------------------------------------------------------

class TestAttribution:
    @pytest.fixture(scope="class")
    def traced_run(self, tmp_path_factory):
        """One traced source -> engine -> sink reconstruction on the 1x1x1
        mesh, auto-planned, with the resulting trace."""
        tmp = tmp_path_factory.mktemp("attr")
        g = default_geometry(16, n_proj=8)
        proj = np.asarray(forward_project(g))
        src = ProjectionSource.write(str(tmp / "proj"), proj,
                                     chunks=(1, 1, 1))
        sink = VolumeSink(str(tmp / "vol"))
        mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
        clear_engine_cache()
        plan = plan_from_spec(g, "auto", mesh=mesh)
        tr = Tracer(enabled=True)
        prev = trace.set_tracer(tr)
        try:
            fdk = plan.build_traced(source=src, sink=sink)
            volume = np.asarray(fdk())
        finally:
            trace.set_tracer(prev)
        return g, plan, mesh, src, sink, tr, volume

    def test_every_engine_stage_measured(self, traced_run):
        _, _, _, _, _, tr, _ = traced_run
        measured = {e["name"] for e in tr.spans("stage.")}
        assert measured == set(attribution.STAGE_FIELDS), (
            "traced run must emit one span per engine stage")
        for name in attribution.STAGE_FIELDS:
            assert len([e for e in tr.spans(name)]) >= 1

    def test_every_nonzero_predicted_stage_has_measured_counterpart(
            self, traced_run):
        _, plan, _, _, _, tr, _ = traced_run
        rows = attribution.compare(plan, tr)
        assert {r.field for r in rows} == set(
            attribution.STAGE_FIELDS.values())
        for r in rows:
            if r.predicted_s > 0:
                assert r.n_spans > 0 and r.measured_s > 0, (
                    f"stage {r.stage} predicted {r.predicted_s}s but "
                    "never measured")
            if r.predicted_s <= 0:
                assert r.error is None
            else:
                assert r.error == pytest.approx(
                    r.measured_s / r.predicted_s - 1.0)

    def test_traced_engine_matches_untraced(self, traced_run):
        g, plan, mesh, src, _, _, volume = traced_run
        ref = np.asarray(plan.build()(src.load(mesh)))
        np.testing.assert_allclose(volume, ref, rtol=2e-5, atol=2e-5)

    def test_sink_holds_the_volume(self, traced_run):
        _, _, _, _, sink, _, volume = traced_run
        np.testing.assert_allclose(np.asarray(sink.read()), volume,
                                   rtol=1e-6, atol=1e-6)

    def test_compare_accepts_exported_dict_and_event_list(self, traced_run):
        _, plan, _, _, _, tr, _ = traced_run
        from_tracer = attribution.compare(plan, tr)
        from_dict = attribution.compare(plan, tr.export())
        from_list = attribution.compare(plan, tr.events())
        for a, b, c in zip(from_tracer, from_dict, from_list):
            assert a == b == c

    def test_render_report(self, traced_run):
        _, plan, _, _, _, tr, _ = traced_run
        report = attribution.render_report(attribution.compare(plan, tr))
        for stage in attribution.STAGE_FIELDS:
            assert stage in report
        assert "predicted" in report and "measured" in report

    def test_perfetto_schema_of_real_engine_trace(self, traced_run):
        _, _, _, _, _, tr, _ = traced_run
        for ev in tr.export()["traceEvents"]:
            assert PERFETTO_KEYS <= set(ev)


# ---------------------------------------------------------------------------
# instrumented subsystems
# ---------------------------------------------------------------------------

class TestInstrumentation:
    def test_built_engine_emits_fenced_span(self, tracer):
        g = default_geometry(16, n_proj=8)
        proj = jnp.asarray(forward_project(g))
        clear_engine_cache()
        fdk = plan_from_spec(g, "auto").build()
        jax.block_until_ready(fdk(proj))
        spans = tracer.spans("engine.reconstruct")
        assert len(spans) == 1
        ev = spans[0]
        assert "dispatch_us" in ev["args"]
        assert ev["args"]["schedule"] in ("fused", "pipelined", "chunked")
        assert ev["args"]["grid"] == "1x1"

    def test_service_drain_emits_spans_and_latency(self, tracer):
        from repro.service import ReconstructionService
        g = default_geometry(16, n_proj=8)
        proj = jnp.asarray(forward_project(g))
        svc = ReconstructionService(max_batch=2)
        try:
            for _ in range(2):
                svc.submit(projections=proj, geometry=g)
            svc.drain()
            st = svc.stats()
        finally:
            svc.close()
        assert st["served"] == 2 and st["buckets"] == 1
        assert st["latency"]["queue_wait"]["count"] == 2
        assert st["latency"]["time_to_volume"]["count"] == 2
        assert st["latency"]["bucket_assembly"]["count"] == 1
        assert st["latency"]["time_to_volume"]["min"] > 0
        names = {e["name"] for e in tracer.spans("service.")}
        assert {"service.drain", "service.bucket",
                "service.bucket.assemble"} <= names
        assert svc.metrics.value("service.scans.served") == 2

    def test_measure_proposal_traces_through_timed_span(self, tracer):
        from repro.planner import auto_plan
        g = default_geometry(16, n_proj=8)
        clear_engine_cache()
        auto_plan(g, measure=True, top_k=1)
        # measured refinement runs inside planner.measure spans (timed=True
        # records them when the tracer is enabled); cache hits skip them,
        # so only assert when any measurement actually ran.
        spans = tracer.spans("planner.measure")
        for ev in spans:
            assert ev["dur"] > 0 and ev["args"]["iters"] >= 1
