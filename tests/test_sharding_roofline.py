"""ShardingRules shape-aware degradation + roofline HLO parser."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import (
    CollectiveStats, Roofline, _line_group_size, _shape_bytes,
    _split_computations, collective_stats, forward_flops_per_token,
    analytic_costs, model_flops_for,
)
from repro.configs import get_config
from repro.models.config import count_params, count_active_params
from repro.parallel.sharding import ShardingRules


class TestSpecDegradation:
    """Pure spec logic (no mesh needed beyond names/sizes)."""

    def test_no_mesh_is_fully_replicated(self):
        r = ShardingRules(mesh=None)
        assert r.spec_for_shape((4, 8), "dp", "tp") == P(None, None)

    def test_shape_bytes(self):
        assert _shape_bytes("f32", "4,4") == 64
        assert _shape_bytes("bf16", "8") == 16
        assert _shape_bytes("pred", "2,3") == 6
        assert _shape_bytes("weird", "4") == 0

    def test_group_size_iota(self):
        assert _line_group_size("replica_groups=[16,16]<=[256]") == 16
        assert _line_group_size("replica_groups=[2,4]<=[8]") == 4

    def test_group_size_list(self):
        assert _line_group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4


HLO = """HloModule test, is_scheduled=true

%cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(6)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ag = f32[8]{0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={0}
}

ENTRY %main_spmd (a: f32[4]) -> f32[] {
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %ar = f32[] all-reduce(%s), replica_groups=[1,8]<=[8], to_apply=%add
}
"""


class TestHLOParser:
    def test_split_computations(self):
        comps, entry = _split_computations(HLO)
        assert entry == "main_spmd"
        assert set(comps) == {"cond", "body", "main_spmd"}

    def test_trip_count_multiplies_body_collectives(self):
        stats = collective_stats(HLO)
        # body all-gather: 32B result x 6 trips, group 4 -> wire 3/4*32*6
        assert stats.op_count["all-gather"] == 6.0
        assert stats.op_bytes["all-gather"] == pytest.approx(32 * 6)
        # entry all-reduce: 4B, group 8 -> once
        assert stats.op_count["all-reduce"] == 1.0
        want = (3 / 4) * 32 * 6 + 2 * (7 / 8) * 4
        assert stats.wire_bytes == pytest.approx(want)

    def test_ring_factors(self):
        s = CollectiveStats()
        s.add("all-gather", 100.0, 4)
        s.add("all-reduce", 100.0, 4)
        s.add("reduce-scatter", 100.0, 4)
        s.add("collective-permute", 100.0, 4)
        assert s.wire_bytes == pytest.approx(75 + 150 + 75 + 100)


class TestRoofline:
    def test_dominant_term(self):
        r = Roofline("a", "s", "m", 256, hlo_flops=197e12, hlo_bytes=819e9,
                     wire_bytes=1e9)
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(1.0)
        assert r.dominant in ("compute", "memory")
        r2 = Roofline("a", "s", "m", 256, 1e12, 1e9, wire_bytes=500e9)
        assert r2.dominant == "collective"

    def test_model_flops_train_6nd(self):
        cfg = get_config("yi-6b")
        n = count_active_params(cfg)
        info = dict(kind="train", seq=4096, batch=256)
        assert model_flops_for(cfg, info, n) == pytest.approx(
            6.0 * n * 4096 * 256
        )

    def test_forward_flops_close_to_2nd(self):
        """Analytic per-token fwd FLOPs ~ 2*N_active*(1+eps) at short seq."""
        for arch in ["yi-6b", "deepseek-coder-33b", "mixtral-8x7b"]:
            cfg = get_config(arch)
            n = count_active_params(cfg)
            f = forward_flops_per_token(cfg, s_kv=1.0)
            assert 1.5 * n < f < 3.5 * n, arch

    def test_analytic_costs_positive_and_scaled(self):
        cfg = get_config("yi-6b")
        info = dict(kind="train", seq=4096, batch=256)
        a256 = analytic_costs(cfg, info, 256, count_params(cfg))
        a512 = analytic_costs(cfg, info, 512, count_params(cfg))
        assert a256.flops_per_dev == pytest.approx(2 * a512.flops_per_dev)
        assert a256.hbm_bytes_per_dev > 0

    def test_useful_ratio(self):
        r = Roofline("a", "s", "m", 2, hlo_flops=3.0, hlo_bytes=1.0,
                     wire_bytes=0.0, model_flops=6.0)
        assert r.useful_ratio == pytest.approx(1.0)
