"""Mamba-2 SSD: chunk-size invariance, decode recurrence, padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_smoke_config
from repro.models.config import SSMConfig
from repro.models.layers import init_tree
from repro.models.ssm import SSMCache, ssm_block, ssm_defs, ssm_dims

KEY = jax.random.PRNGKey(1)


def _cfg(chunk=16):
    base = get_smoke_config("mamba2_130m")
    return dataclasses.replace(
        base, dtype="float32",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                      chunk=chunk),
    )


def _inputs(B=2, L=24):
    cfg = _cfg()
    u = jax.random.normal(jax.random.PRNGKey(2), (B, L, cfg.d_model),
                          jnp.float32)
    return u


class TestSSD:
    @pytest.mark.parametrize("c1,c2", [(1, 16), (4, 16), (8, 32)])
    @pytest.mark.slow
    def test_chunk_size_invariance(self, c1, c2):
        """The chunked algorithm must be independent of the chunk size
        (state-space duality: quadratic-intra + linear-inter is exact)."""
        u = _inputs()
        p = init_tree(KEY, ssm_defs(_cfg()))
        y1, _ = ssm_block(p, _cfg(chunk=c1), u)
        y2, _ = ssm_block(p, _cfg(chunk=c2), u)
        np.testing.assert_allclose(np.array(y1), np.array(y2),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    def test_non_divisible_length_padding(self):
        """L % chunk != 0 is handled by inert zero-padding."""
        u = _inputs(L=19)
        p = init_tree(KEY, ssm_defs(_cfg()))
        y16, _ = ssm_block(p, _cfg(chunk=16), u)
        y1, _ = ssm_block(p, _cfg(chunk=1), u)
        np.testing.assert_allclose(np.array(y16), np.array(y1),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    def test_decode_equals_chunked(self):
        """Sequential recurrent decode reproduces the chunked outputs."""
        cfg = _cfg()
        u = _inputs(L=12)
        p = init_tree(KEY, ssm_defs(cfg))
        y_full, _ = ssm_block(p, cfg, u)
        d_in, nh, cch = ssm_dims(cfg)
        cache = SSMCache(
            conv=jnp.zeros((2, cfg.ssm.d_conv - 1, cch), jnp.float32),
            state=jnp.zeros((2, nh, cfg.ssm.head_dim, cfg.ssm.d_state),
                            jnp.float32),
        )
        ys = []
        for t in range(12):
            yt, cache = ssm_block(p, cfg, u[:, t:t + 1], cache=cache)
            ys.append(yt)
        yd = jnp.concatenate(ys, 1)
        np.testing.assert_allclose(np.array(yd), np.array(y_full),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.slow
    def test_prefill_cache_handoff(self):
        """return_cache=True lets decode continue the stream exactly."""
        cfg = _cfg()
        u = _inputs(L=13)
        p = init_tree(KEY, ssm_defs(cfg))
        y_full, _ = ssm_block(p, cfg, u)
        y_pre, cache = ssm_block(p, cfg, u[:, :12], return_cache=True)
        y_last, _ = ssm_block(p, cfg, u[:, 12:], cache=cache)
        np.testing.assert_allclose(np.array(y_last[:, 0]),
                                   np.array(y_full[:, 12]),
                                   rtol=1e-4, atol=1e-5)

    def test_causality(self):
        """Output at position t must not depend on inputs at positions > t."""
        cfg = _cfg()
        u = _inputs(L=16)
        p = init_tree(KEY, ssm_defs(cfg))
        y1, _ = ssm_block(p, cfg, u)
        u2 = u.at[:, 10:].set(0.0)
        y2, _ = ssm_block(p, cfg, u2)
        np.testing.assert_allclose(np.array(y1[:, :10]), np.array(y2[:, :10]),
                                   rtol=1e-5, atol=1e-6)
        assert float(jnp.max(jnp.abs(y1[:, 10:] - y2[:, 10:]))) > 1e-5

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_state_decay_bounded(self, seed):
        """A = -exp(a_log) < 0 keeps the recurrence contractive: outputs stay
        finite for random inputs."""
        cfg = _cfg()
        u = jax.random.normal(jax.random.PRNGKey(seed),
                              (1, 32, cfg.d_model), jnp.float32)
        p = init_tree(jax.random.PRNGKey(seed % 7), ssm_defs(cfg))
        y, _ = ssm_block(p, cfg, u)
        assert bool(jnp.all(jnp.isfinite(y)))
