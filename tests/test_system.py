"""End-to-end system behaviour: the full reconstruction products and the
serving loop, exercised through the public API only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fdk import reconstruct, timed_reconstruct
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project, shepp_logan_volume
from repro.configs import get_smoke_config
from repro.data import synthetic_batch
from repro.models.transformer import init_params
from repro.serving import greedy_generate


def test_full_ct_pipeline_public_api():
    """projections -> filter -> back-project -> volume, via reconstruct().
    16^3/32 (was 24^3/36): the public-API path is what is under test, not
    resolution — fast-tier diet (DESIGN.md §Test tiers)."""
    g = default_geometry(16, n_proj=32)
    proj = forward_project(g)
    vol = reconstruct(g, proj, impl="kernel")
    ph = shepp_logan_volume(g)
    assert vol.shape == ph.shape
    m = g.n_x // 5
    interior = (slice(m, g.n_x - m),) * 3
    rmse = float(jnp.sqrt(jnp.mean((vol[interior] - ph[interior]) ** 2)))
    assert rmse < 0.2
    # GUPS accounting comes out positive and finite
    _, dt, rate = timed_reconstruct(g, proj, impl="factorized", iters=1)
    assert rate > 0 and np.isfinite(rate)


@pytest.mark.slow
def test_greedy_generation_runs():
    """Serving loop: prefill a prompt, decode 4 tokens, stable output."""
    cfg = get_smoke_config("qwen2_1_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = synthetic_batch(cfg, 2, 8, jax.random.PRNGKey(1))
    out = greedy_generate(cfg, params, {"tokens": batch["tokens"]},
                          steps=4, s_max=16)
    assert out.shape[0] == 2
    assert int(out.max()) < cfg.vocab_size
    # greedy decoding is deterministic
    out2 = greedy_generate(cfg, params, {"tokens": batch["tokens"]},
                           steps=4, s_max=16)
    np.testing.assert_array_equal(np.array(out), np.array(out2))
