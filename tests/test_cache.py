"""CountingLRU (core/cache.py) and the engine-cache regression it fixes:
`_ENGINE_CACHE` used to be an unbounded dict with a bare try/except around
the lookup — every distinct plan leaked a compiled engine forever and
nothing recorded hit rates. The bounded LRU is shared by the plan-level
engine cache and the service's plan cache."""
import pytest

from repro.core.cache import CountingLRU
from repro.core.geometry import default_geometry
from repro.core.plan import (
    ReconstructionPlan, clear_engine_cache, engine_cache_stats,
)


class TestCountingLRU:
    def test_hit_miss_counters(self):
        c = CountingLRU(4)
        assert c.get("a") is None
        c.put("a", 1)
        assert c.get("a") == 1
        s = c.stats()
        assert s["hits"] == 1 and s["misses"] == 1 and s["size"] == 1

    def test_eviction_is_lru_not_fifo(self):
        c = CountingLRU(2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # touch a -> b is now least recent
        c.put("c", 3)                   # evicts b
        assert "b" not in c and "a" in c and "c" in c
        assert c.stats()["evictions"] == 1

    def test_capacity_bounds_size(self):
        c = CountingLRU(8)
        for k in range(100):
            c.put(k, k)
        assert len(c) == 8
        assert c.stats()["evictions"] == 92
        assert list(c.keys()) == list(range(92, 100))

    def test_put_existing_refreshes(self):
        c = CountingLRU(2)
        c.put("a", 1)
        c.put("b", 2)
        c.put("a", 10)                  # refresh, not duplicate
        c.put("c", 3)                   # evicts b, not a
        assert c.get("a") == 10 and "b" not in c

    def test_get_or_build_builds_once(self):
        c = CountingLRU(4)
        calls = []

        def build():
            calls.append(1)
            return "v"
        assert c.get_or_build("k", build) == "v"
        assert c.get_or_build("k", build) == "v"
        assert len(calls) == 1
        assert c.stats()["hits"] == 1 and c.stats()["misses"] == 1

    def test_unhashable_key_builds_uncached(self):
        """The regression: an unhashable key must neither crash nor cache —
        and the event is COUNTED, not swallowed by a bare except."""
        c = CountingLRU(4)
        calls = []

        def build():
            calls.append(1)
            return len(calls)
        key = {"not": "hashable"}
        assert c.get_or_build(key, build) == 1
        assert c.get_or_build(key, build) == 2     # rebuilt every time
        assert len(c) == 0
        assert c.stats()["unhashable"] == 2
        assert c.get(["also unhashable"]) is None

    def test_zero_capacity_disables_storage(self):
        c = CountingLRU(0)
        c.put("a", 1)
        assert c.get("a") is None and len(c) == 0

    def test_clear_keeps_or_resets_counters(self):
        c = CountingLRU(4)
        c.put("a", 1)
        c.get("a")
        c.clear()
        assert len(c) == 0 and c.stats()["hits"] == 1
        c.clear(reset_counters=True)
        assert c.stats()["hits"] == 0


class TestEngineCacheRegression:
    def test_rebuild_is_a_hit(self):
        g = default_geometry(16, n_proj=8)
        clear_engine_cache()
        plan = ReconstructionPlan(geometry=g)
        a = plan.build()
        h0 = engine_cache_stats()["hits"]
        assert plan.build() is a
        assert engine_cache_stats()["hits"] == h0 + 1

    def test_engine_cache_is_bounded(self):
        """Distinct plans can no longer grow the cache without bound: the
        LRU evicts and the engine is simply rebuilt on the next call."""
        clear_engine_cache()
        cap = engine_cache_stats()["capacity"]
        assert cap > 0
        g = default_geometry(16, n_proj=8)
        # distinct plan identities: vary a harmless knob past capacity
        plans = [ReconstructionPlan(geometry=g, schedule="pipelined",
                                    n_steps=2, precision=p)
                 for p in ("fp32", "bf16", "fp16")]
        for plan in plans:
            plan.build()
        assert engine_cache_stats()["size"] <= cap
