"""Per-arch smoke tests (assignment: reduced config, one forward/train step
on CPU, assert output shapes + no NaNs) + decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import batch_specs, synthetic_batch
from repro.models import transformer as T
from repro.models import layers as L
from repro.models.config import count_active_params, count_params
from repro.models.transformer import (
    decode_step, init_cache, init_params, loss_fn, prefill,
)
from repro.training import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)

# Fast tier keeps one cheap full-path arch; the rest of the per-arch smoke
# matrix (several seconds to a minute each on CPU) runs with the slow tier.
_FAST_ARCHS = {"mamba2_130m"}


def _slow_except_fast(archs):
    return [a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


ARCHS = _slow_except_fast(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    """One forward pass: loss finite, metrics well-formed."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = synthetic_batch(cfg, 2, 32, KEY)
    loss, metrics = loss_fn(params, cfg, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One optimizer step on CPU: finite loss, params change, no NaNs."""
    cfg = get_smoke_config(arch)
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, microbatches=1, remat=False))
    batch = synthetic_batch(cfg, 2, 16, KEY)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually move
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params,
    )
    assert max(jax.tree.leaves(moved)) > 0
    # and stay finite
    for leaf in jax.tree.leaves(new_state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.slow
def test_train_loss_decreases():
    cfg = get_smoke_config("qwen2_1_5b")
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, microbatches=2, remat=True))
    batch = synthetic_batch(cfg, 4, 32, KEY)
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize(
    "arch",
    _slow_except_fast(["qwen2_1_5b", "mixtral_8x7b", "mamba2_130m",
                       "jamba_1_5_large", "musicgen_large"]),
)
def test_decode_matches_forward_f32(arch):
    """prefill(S) + decode(token S) == full forward at position S (f32)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(cfg, KEY)
    S = 16
    batch = synthetic_batch(cfg, 2, S + 1, KEY)
    audio = cfg.frontend is not None and cfg.frontend.modality == "audio"

    x = T.embed_inputs(params, cfg, batch, None)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _ = T._run_blocks(params, cfg, x, positions, None, remat=False)
    h = L.rmsnorm(params["final_norm"], h, cfg.rms_eps)
    want = T._logits(params, cfg, h)[:, -1].astype(jnp.float32)

    if audio:
        prompt = {"tokens": batch["tokens"][:, :, :S]}
        last = batch["tokens"][:, :, S:S + 1]
    else:
        prompt = {"tokens": batch["tokens"][:, :S]}
        last = batch["tokens"][:, S:S + 1]
    _, cache = prefill(params, cfg, prompt, None)
    full = init_cache(cfg, 2, S + 8)

    def place(big, small):
        if small.ndim >= 3 and big.ndim == small.ndim and small.shape != big.shape:
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), (0,) * small.ndim
            )
        return small.astype(big.dtype)

    cache = jax.tree.map(place, full, cache)
    got, _ = decode_step(params, cfg, cache, last, jnp.int32(S), None)
    err = float(jnp.max(jnp.abs(want - got.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert err / scale < 1e-4, f"{arch}: decode diverges from forward"


def test_vlm_concats_image_tokens():
    cfg = get_smoke_config("internvl2_26b")
    params = init_params(cfg, KEY)
    batch = synthetic_batch(cfg, 2, 16, KEY)
    x = T.embed_inputs(params, cfg, batch, None)
    assert x.shape[1] == 16 + cfg.frontend.num_positions


def test_musicgen_head_shapes():
    cfg = get_smoke_config("musicgen_large")
    params = init_params(cfg, KEY)
    batch = synthetic_batch(cfg, 2, 8, KEY)
    x = T.embed_inputs(params, cfg, batch, None)
    assert x.shape == (2, 8, cfg.d_model)
    logits = T._logits(params, cfg, x)
    assert logits.shape == (2, 8, 4, cfg.vocab_size)


@pytest.mark.slow
def test_param_count_formula_matches_init():
    """Analytic count_params (used by the roofline) == actual leaf sizes."""
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        params = init_params(cfg, KEY)
        actual = sum(int(p.size) for p in jax.tree.leaves(params))
        assert count_params(cfg) == actual, arch


def test_active_params_less_than_total_for_moe():
    for arch in ["qwen2-moe-a2.7b", "mixtral-8x7b", "jamba-1.5-large-398b"]:
        cfg = get_config(arch)
        assert count_active_params(cfg) < count_params(cfg)


def test_full_config_param_counts_sane():
    """Full (published) configs land near their nameplate sizes."""
    expect = {
        "deepseek-coder-33b": (30e9, 36e9),
        "yi-6b": (5e9, 7e9),
        "internlm2-20b": (17e9, 22e9),
        "mixtral-8x7b": (42e9, 50e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        "mamba2-130m": (0.1e9, 0.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params out of range"


@pytest.mark.slow
def test_sliding_window_masks_distant_tokens():
    cfg = dataclasses.replace(
        get_smoke_config("mixtral_8x7b"), sliding_window=4, dtype="float32"
    )
    params = init_params(cfg, KEY)
    S = 12
    batch = synthetic_batch(cfg, 1, S, KEY)
    t2 = dict(batch)
    # perturb token 0: outputs at positions >= window+0 must NOT change
    t2["tokens"] = batch["tokens"].at[0, 0].set(
        (batch["tokens"][0, 0] + 1) % cfg.vocab_size
    )
    def last_logits(b):
        x = T.embed_inputs(params, cfg, b, None)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (1, S))
        h, _ = T._run_blocks(params, cfg, x, positions, None, remat=False)
        return T._logits(params, cfg, L.rmsnorm(params["final_norm"], h,
                                                cfg.rms_eps))
    a = last_logits(batch)
    b = last_logits(t2)
    # with 2 layers the receptive field is 2*(window-1); beyond it: identical
    reach = 2 * (cfg.sliding_window - 1) + 1
    np.testing.assert_allclose(np.array(a[0, reach:]), np.array(b[0, reach:]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(a[0, 0] - b[0, 0]))) > 1e-4
