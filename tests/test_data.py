"""Data pipeline: spec consistency, restartable determinism, projections."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.data import SyntheticTokens, batch_specs, synthetic_batch, ProjectionSource
from repro.data.pipeline import ProjectionSource


@pytest.mark.parametrize("arch", list_archs())
def test_synthetic_matches_specs(arch):
    cfg = get_smoke_config(arch)
    import jax
    specs = batch_specs(cfg, 2, 16)
    batch = synthetic_batch(cfg, 2, 16, jax.random.PRNGKey(0))
    assert set(batch) == set(specs)
    for k, spec in specs.items():
        assert batch[k].shape == spec.shape, (arch, k)
        assert batch[k].dtype == spec.dtype, (arch, k)
        if spec.dtype == jnp.int32:
            assert int(batch[k].max()) < cfg.vocab_size


def test_stream_restartable_determinism():
    """batch(step) is a pure function of (seed, step): a resumed job sees
    the identical stream."""
    cfg = get_smoke_config("qwen2_1_5b")
    s1 = SyntheticTokens(cfg, 2, 8, seed=3)
    s2 = SyntheticTokens(cfg, 2, 8, seed=3)
    a, b = s1(5), s2(5)
    np.testing.assert_array_equal(np.array(a["tokens"]), np.array(b["tokens"]))
    c = s1(6)
    assert not np.array_equal(np.array(a["tokens"]), np.array(c["tokens"]))


def test_projection_source_slicing():
    proj = np.arange(4 * 2 * 3, dtype=np.float32).reshape(4, 2, 3)
    src = ProjectionSource(proj, micro_batch=2)
    assert src.n_batches == 2
    np.testing.assert_array_equal(src.batch(1), proj[2:4])
    batches = list(src)
    np.testing.assert_array_equal(np.concatenate(batches), proj)


def test_projection_source_rejects_ragged():
    with pytest.raises(ValueError):
        ProjectionSource(np.zeros((5, 2, 2), np.float32), micro_batch=2)
