"""Reconstruction-as-a-service (repro/service): admission, bucketing,
plan-cache amortization, async I/O overlap, and failure isolation.

This file doubles as the CI fast-tier service smoke test (ci.yml), so it
stays on the 16^3 geometry and the 1x1x1 mesh.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.geometry import default_geometry
from repro.core.plan import clear_engine_cache, plan_from_spec
from repro.io import (
    AsyncWriteback, PrefetchError, ProjectionSource, SourcePrefetcher,
    VolumeSink,
)
from repro.parallel.mesh import make_mesh
from repro.service import (
    AdmissionError, QueueFullError, ReconstructionService, ScanFamily,
    TicketState,
)


@pytest.fixture(scope="module")
def case16():
    from repro.core.phantom import forward_project
    g = default_geometry(16, n_proj=8)
    base = np.asarray(forward_project(g))
    rng = np.random.default_rng(3)
    scans = [jnp.asarray(base * (1.0 + 0.25 * k)
                         + rng.standard_normal(base.shape).astype(np.float32)
                         * 0.01)
             for k in range(5)]
    return g, scans


def _mesh():
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


class TestServeAndBucket:
    def test_drain_is_bitexact_vs_single_scan_engine(self, case16):
        g, scans = case16
        mesh = _mesh()
        clear_engine_cache()
        svc = ReconstructionService(mesh, max_batch=8)
        tickets = [svc.submit(projections=p, geometry=g) for p in scans]
        served = svc.drain()
        assert [t.scan_id for t in served] == [t.scan_id for t in tickets]
        assert all(t.state is TicketState.DONE for t in tickets)
        ref = plan_from_spec(g, "auto", mesh=mesh).build()
        for p, t in zip(scans, tickets):
            np.testing.assert_array_equal(np.asarray(ref(p)),
                                          np.asarray(t.result()))
        st = svc.stats()
        # 5 scans -> one bucket of 8 (next power of two), 3 pad lanes
        assert st["buckets"] == 1 and st["padded_lanes"] == 3
        assert st["served"] == 5 and st["queued"] == 0
        svc.close()

    def test_plan_cache_amortizes_planner_search(self, case16):
        """ISSUE 7 acceptance: the second same-family request does ZERO
        planner-search work — the searches counter stays at 1."""
        g, scans = case16
        svc = ReconstructionService(max_batch=4)
        svc.submit(projections=scans[0], geometry=g)
        svc.drain()
        assert svc.stats()["plan_cache"]["searches"] == 1
        svc.submit(projections=scans[1], geometry=g)
        svc.drain()
        st = svc.stats()
        assert st["plan_cache"]["searches"] == 1      # no new search
        assert st["plan_cache"]["hits"] >= 1
        # a pinned request is a NEW family -> exactly one more search
        svc.submit(projections=scans[2], geometry=g, precision="bf16")
        svc.drain()
        assert svc.stats()["plan_cache"]["searches"] == 2
        svc.close()

    def test_families_never_share_a_bucket(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_batch=8)
        t1 = svc.submit(projections=scans[0], geometry=g)
        t2 = svc.submit(projections=scans[1], geometry=g, precision="bf16")
        svc.drain()
        assert svc.stats()["buckets"] == 2
        assert t1.family != t2.family
        assert t1.done and t2.done
        svc.close()

    def test_max_batch_splits_buckets(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_batch=2)
        for p in scans:                       # 5 scans, cap 2
            svc.submit(projections=p, geometry=g)
        tickets = svc.drain()
        assert all(t.done for t in tickets)
        st = svc.stats()
        assert st["buckets"] == 3             # 2 + 2 + 1
        # the trailing bucket of 1 runs at batch size 1 — no pad needed
        assert st["padded_lanes"] == 0
        svc.close()


class TestAdmission:
    def test_footprint_over_budget_rejected(self, case16):
        g, scans = case16
        svc = ReconstructionService(hbm_bytes=1024)
        with pytest.raises(AdmissionError, match="budget"):
            svc.submit(projections=scans[0], geometry=g)
        assert svc.queued == 0
        svc.close()

    def test_queue_full_backpressure(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_queue=1)
        svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(QueueFullError):
            svc.submit(projections=scans[1], geometry=g)
        assert svc.queued == 1
        svc.drain()
        svc.submit(projections=scans[1], geometry=g)   # drained -> space
        svc.close()

    def test_shape_mismatch_rejected(self, case16):
        g, _ = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="shape"):
            svc.submit(projections=jnp.zeros((1, 2, 3)), geometry=g)
        svc.close()

    def test_exactly_one_data_source(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(geometry=g)
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(projections=scans[0], source=object(), geometry=g)
        svc.close()

    def test_incremental_schedule_pin_rejected_at_submit(self, case16):
        """schedule='incremental' has no batched engine; a pinned request
        must be rejected at submit, not queue work that fails at drain."""
        g, scans = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="incremental"):
            svc.submit(projections=scans[0], geometry=g,
                       schedule="incremental")
        assert svc.queued == 0
        assert svc.stats()["rejected"] == 1
        svc.close()

    def test_every_rejection_path_counts(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_queue=1)
        with pytest.raises(AdmissionError, match="shape"):
            svc.submit(projections=jnp.zeros((1, 2, 3)), geometry=g)
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(geometry=g)
        svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(QueueFullError):
            svc.submit(projections=scans[1], geometry=g)
        assert svc.stats()["rejected"] == 3
        svc.close()
        svc = ReconstructionService(hbm_bytes=1024)
        with pytest.raises(AdmissionError, match="budget"):
            svc.submit(projections=scans[0], geometry=g)
        assert svc.stats()["rejected"] == 1
        svc.close()

    def test_result_before_drain_raises(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        t = svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(RuntimeError, match="queued"):
            t.result()
        svc.close()


class TestAsyncIO:
    def test_source_and_sink_roundtrip(self, case16, tmp_path):
        """PFS-backed scan: projections prefetch-read from a shard store,
        volume written behind to a sink, both byte-faithful."""
        g, scans = case16
        mesh = _mesh()
        src = ProjectionSource.write(str(tmp_path / "scan"),
                                     np.asarray(scans[0]))
        sink = VolumeSink(str(tmp_path / "vol"))
        svc = ReconstructionService(mesh)
        t = svc.submit(source=src, geometry=g, sink=sink)
        svc.drain()
        assert t.done
        ref = plan_from_spec(g, "auto", mesh=mesh).build()(scans[0])
        np.testing.assert_array_equal(np.asarray(t.result()),
                                      np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(sink.read()),
                                      np.asarray(ref))
        st = svc.stats()
        assert st["prefetched_loads"] == 1 and st["writebacks"] == 1
        svc.close()

    def test_failed_writeback_fails_only_its_ticket(self, case16, tmp_path):
        g, scans = case16

        class ExplodingSink:
            def write(self, volume, layout=None):
                raise IOError("disk full")

        svc = ReconstructionService()
        ok = svc.submit(projections=scans[0], geometry=g,
                        sink=VolumeSink(str(tmp_path / "ok")))
        bad = svc.submit(projections=scans[1], geometry=g,
                         sink=ExplodingSink())
        svc.drain()
        assert ok.state is TicketState.DONE
        assert bad.state is TicketState.FAILED
        with pytest.raises(RuntimeError, match="failed"):
            bad.result()
        assert isinstance(bad.error, IOError)
        st = svc.stats()
        assert st["failed"] == 1 and st["served"] == 1
        svc.close()


class TestFailureIsolation:
    def test_failed_engine_build_does_not_corrupt_next_bucket(
            self, case16, tmp_path):
        """REVIEW regression: a bucket that fails BEFORE consuming its
        prefetched loads (plan resolve / engine build raising at drain
        time) must not leave them queued — the next bucket's scans would
        silently reconstruct from the wrong scans' data and be DONE."""
        g, scans = case16
        src_a = ProjectionSource.write(str(tmp_path / "a"),
                                       np.asarray(scans[0]))
        src_b = ProjectionSource.write(str(tmp_path / "b"),
                                       np.asarray(scans[1]))
        svc = ReconstructionService()
        ta = svc.submit(source=src_a, geometry=g)
        # a pinned request is its own family -> its own (later) bucket
        tb = svc.submit(source=src_b, geometry=g, precision="bf16")
        real_resolve = svc.plan_cache.resolve
        calls = {"a": 0}

        def poisoned(family):
            if family == ta.family:
                calls["a"] += 1
                if calls["a"] > 1:   # bucketing resolve OK, serving fails
                    raise RuntimeError("engine build exploded")
            return real_resolve(family)

        svc.plan_cache.resolve = poisoned
        served = svc.drain()
        svc.plan_cache.resolve = real_resolve
        assert len(served) == 2
        assert ta.state is TicketState.FAILED
        assert isinstance(ta.error, RuntimeError)
        # bucket B served from ITS OWN projections, bit-exact
        assert tb.state is TicketState.DONE
        ref = plan_from_spec(g, "auto", precision="bf16").build()(scans[1])
        np.testing.assert_array_equal(np.asarray(tb.result()),
                                      np.asarray(ref))
        st = svc.stats()
        assert st["failed"] == 1 and st["served"] == 1
        svc.close()

    def test_bucket_construction_failure_fails_only_its_family(
            self, case16):
        """REGRESSION (ISSUE 9): _make_buckets used to swap the queue out
        and THEN resolve each family's plan — a resolve/capacity exception
        unwound drain() with every pending ticket (all families) already
        out of the queue, silently stuck in QUEUED forever with no error
        recorded. Now the failing family's tickets FAIL (error set,
        counted) and the other families still serve."""
        g, scans = case16
        svc = ReconstructionService()
        ta1 = svc.submit(projections=scans[0], geometry=g)
        ta2 = svc.submit(projections=scans[1], geometry=g)
        tb = svc.submit(projections=scans[2], geometry=g, precision="bf16")
        real_resolve = svc.plan_cache.resolve

        def poisoned(family):
            if family == ta1.family:
                raise RuntimeError("poisoned plan cache")
            return real_resolve(family)

        svc.plan_cache.resolve = poisoned
        served = svc.drain()
        svc.plan_cache.resolve = real_resolve
        # nothing lost: all three tickets came back, all terminal
        assert {t.scan_id for t in served} == {ta1.scan_id, ta2.scan_id,
                                               tb.scan_id}
        assert ta1.state is TicketState.FAILED
        assert ta2.state is TicketState.FAILED
        assert "poisoned" in str(ta1.error) and "poisoned" in str(ta2.error)
        assert tb.state is TicketState.DONE
        ref = plan_from_spec(g, "auto", precision="bf16").build()(scans[2])
        np.testing.assert_array_equal(np.asarray(tb.result()),
                                      np.asarray(ref))
        st = svc.stats()
        assert st["failed"] == 2 and st["served"] == 1
        assert st["queued"] == 0
        svc.close()

    def test_failed_load_fails_only_its_bucket(self, case16, tmp_path):
        """A source whose load raises fails its own bucket's tickets with
        PrefetchError; later buckets still serve from their own data."""
        g, scans = case16

        class ExplodingSource:
            def load(self, mesh=None):
                raise IOError("bad shard")

        src_b = ProjectionSource.write(str(tmp_path / "b"),
                                       np.asarray(scans[1]))
        svc = ReconstructionService()
        ta = svc.submit(source=ExplodingSource(), geometry=g)
        tb = svc.submit(source=src_b, geometry=g, precision="bf16")
        svc.drain()
        assert ta.state is TicketState.FAILED
        assert isinstance(ta.error, PrefetchError)
        assert tb.state is TicketState.DONE
        ref = plan_from_spec(g, "auto", precision="bf16").build()(scans[1])
        np.testing.assert_array_equal(np.asarray(tb.result()),
                                      np.asarray(ref))
        svc.close()


class TestPrefetcher:
    def test_order_preserved(self):
        """Jobs complete in submission order regardless of their cost —
        the service pairs get() k with scan k by position."""
        def slow():
            time.sleep(0.05)
            return "a"
        pf = SourcePrefetcher([slow, lambda: "b", lambda: "c"],
                              depth=2).start()
        assert [pf.get(), pf.get(), pf.get()] == ["a", "b", "c"]
        with pytest.raises(StopIteration):
            pf.get()
        pf.close()

    def test_depth_bounds_readahead(self):
        """Double-buffering, not slurping: at most `depth` loads sit in
        memory before the consumer asks."""
        started = []

        def job(k):
            def run():
                started.append(k)
                return k
            return run
        pf = SourcePrefetcher([job(k) for k in range(6)], depth=2).start()
        deadline = time.monotonic() + 5.0
        while len(started) < 3 and time.monotonic() < deadline:
            time.sleep(0.005)   # fill: depth queued + 1 blocked in put
        time.sleep(0.05)
        assert len(started) <= 4
        assert [pf.get() for _ in range(6)] == list(range(6))
        pf.close()

    def test_error_propagates_as_prefetch_error(self):
        """A failed load is re-raised by the MATCHING get(); later jobs
        still run, so the queue stays positionally aligned (one bad shard
        fails only its own scan, not every scan behind it)."""
        def boom():
            raise IOError("bad shard")
        pf = SourcePrefetcher([lambda: 1, boom, lambda: 3]).start()
        assert pf.get() == 1
        with pytest.raises(PrefetchError, match="bad shard"):
            pf.get()
        assert pf.get() == 3          # the worker did NOT stop at the error
        with pytest.raises(StopIteration):
            pf.get()
        pf.close()

    def test_get_after_exhaustion_raises_idempotently(self):
        """REGRESSION (ISSUE 9): the DONE sentinel was consumed exactly
        once, so a second get() after exhaustion blocked forever on the
        empty queue. Exhaustion is now latched — every later get() raises
        StopIteration again."""
        pf = SourcePrefetcher([lambda: 1]).start()
        assert pf.get() == 1
        for _ in range(3):            # pre-fix: the second of these hung
            with pytest.raises(StopIteration):
                pf.get()
        pf.close()

    def test_get_after_close_raises_stopiteration(self):
        """close() abandons pending jobs; a straggler consumer must get a
        clean StopIteration, not a deadlock (the worker's DONE put gives
        up once close() is requested)."""
        release = threading.Event()

        def slow():
            release.wait(5.0)
            return 1

        pf = SourcePrefetcher([slow, lambda: 2], depth=1).start()
        release.set()
        assert pf.get() == 1
        pf.close()
        for _ in range(2):
            with pytest.raises(StopIteration):
                pf.get()

    def test_persistent_mode_extends_across_batches(self):
        """Serve-loop reuse: one worker thread serves several extend()
        batches (no per-drain prefetcher churn), DONE only on finish()."""
        pf = SourcePrefetcher(depth=2, persistent=True).start()
        pf.extend([lambda: "a", lambda: "b"])
        assert [pf.get(), pf.get()] == ["a", "b"]
        pf.extend([lambda: "c"])      # same worker, second drain pass
        assert pf.get() == "c"
        pf.finish()
        with pytest.raises(StopIteration):
            pf.get()
        with pytest.raises(RuntimeError, match="finished"):
            pf.extend([lambda: "d"])
        pf.close()

    def test_one_shot_prefetcher_rejects_extend(self):
        pf = SourcePrefetcher([lambda: 1])
        with pytest.raises(RuntimeError, match="finished"):
            pf.extend([lambda: 2])
        assert pf.get() == 1
        pf.close()


class TestWriteback:
    def test_drain_reraises_first_failure(self, tmp_path):
        class Sink:
            def __init__(self):
                self.wrote = []

            def write(self, volume, layout=None):
                self.wrote.append(np.asarray(volume).copy())

        class Bad:
            def write(self, volume, layout=None):
                raise IOError("enospc")

        wb = AsyncWriteback(max_pending=2)
        good = Sink()
        wb.submit(good, jnp.ones((2, 2)))
        wb.submit(Bad(), jnp.ones((2, 2)))
        with pytest.raises(IOError, match="enospc"):
            wb.drain()
        assert len(good.wrote) == 1
        wb.close()

    def test_completed_futures_pruned_on_submit(self):
        """REVIEW regression: a long-lived service result()s futures
        directly and never calls drain(); submit must prune completed-OK
        writes or the pending list grows forever."""
        class Sink:
            def write(self, volume, layout=None):
                pass

        wb = AsyncWriteback(max_pending=2)
        for _ in range(8):
            wb.submit(Sink(), jnp.ones((2,))).result()
        assert len(wb._futures) <= 2    # not 8: done futures were pruned
        wb.close()

    def test_backpressure_blocks_at_max_pending(self):
        release = threading.Event()
        wrote = []

        class SlowSink:
            def write(self, volume, layout=None):
                release.wait(5.0)
                wrote.append(1)

        wb = AsyncWriteback(max_pending=1)
        t0 = time.monotonic()
        wb.submit(SlowSink(), jnp.ones((2,)))

        def delayed_release():
            time.sleep(0.1)
            release.set()
        threading.Thread(target=delayed_release, daemon=True).start()
        wb.submit(SlowSink(), jnp.ones((2,)))   # must wait for slot
        assert time.monotonic() - t0 >= 0.05
        # the first write completed during submit #2's backpressure wait
        # and was pruned there; drain joins (at least) the second.
        assert wb.drain() >= 1
        assert len(wrote) == 2      # both writes ran
        wb.close()


class TestServeLoop:
    """The background drain loop (ISSUE 9 tentpole): serve()/shutdown()
    lifecycle, condition-variable wakeup, caller wait()/result(), and the
    loop surviving failures."""

    def test_serve_shutdown_roundtrip(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_batch=4).serve()
        assert svc.serving
        tickets = [svc.submit(projections=p, geometry=g) for p in scans]
        for t in tickets:
            assert t.wait(timeout=60.0), t.state
        assert all(t.done for t in tickets)
        ref = plan_from_spec(g, "auto").build()
        np.testing.assert_array_equal(np.asarray(ref(scans[0])),
                                      np.asarray(tickets[0].result()))
        svc.shutdown()
        assert not svc.serving
        st = svc.stats()
        assert st["served"] == len(scans) and st["queued"] == 0
        assert st["loop"]["passes"] >= 1 and st["loop"]["errors"] == 0
        svc.close()

    def test_shutdown_drains_queued_work_first(self, case16):
        """Graceful shutdown: scans admitted before shutdown() are served,
        never stranded non-terminal."""
        g, scans = case16
        svc = ReconstructionService(max_batch=8)
        tickets = [svc.submit(projections=p, geometry=g) for p in scans]
        svc.serve()
        svc.shutdown()            # must serve the queue before exiting
        assert all(t.terminal for t in tickets)
        assert all(t.done for t in tickets)
        svc.close()

    def test_serve_is_idempotent_and_restartable(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        svc.serve()
        first = svc._serve_thread
        svc.serve()                          # idempotent: same thread
        assert svc._serve_thread is first
        svc.shutdown()
        svc.serve()                          # restartable after shutdown
        t = svc.submit(projections=scans[0], geometry=g)
        assert t.wait(timeout=60.0)
        svc.shutdown()
        svc.close()

    def test_drain_while_serving_raises(self, case16):
        g, scans = case16
        svc = ReconstructionService().serve()
        with pytest.raises(RuntimeError, match="serve"):
            svc.drain()
        svc.shutdown()
        svc.drain()                          # fine once the loop is down
        svc.close()

    def test_ticket_wait_and_result_timeout(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        t = svc.submit(projections=scans[0], geometry=g)
        assert not t.wait(timeout=0.02)      # nothing serving yet
        with pytest.raises(RuntimeError, match="queued"):
            t.result(timeout=0.02)
        svc.serve()
        assert t.wait(timeout=60.0)
        t.result(timeout=60.0)
        svc.shutdown()
        svc.close()

    def test_loop_keeps_serving_after_a_failed_bucket(self, case16):
        """Graceful degradation: a failing load fails its own ticket and
        the loop stays alive to serve what comes next."""
        g, scans = case16

        class ExplodingSource:
            def load(self, mesh=None):
                raise IOError("bad shard")

        svc = ReconstructionService().serve()
        bad = svc.submit(source=ExplodingSource(), geometry=g)
        assert bad.wait(timeout=60.0)
        assert bad.state is TicketState.FAILED
        assert isinstance(bad.error, PrefetchError)
        good = svc.submit(projections=scans[0], geometry=g)
        assert good.wait(timeout=60.0)
        assert good.done
        assert svc.serving
        svc.shutdown()
        st = svc.stats()
        assert st["served"] == 1 and st["failed"] == 1
        assert st["loop"]["errors"] == 0     # bucket isolation, not a crash
        svc.close()

    def test_queue_full_backpressure_fires_under_loop(self, case16):
        """QueueFullError still protects the queue while the loop serves:
        block the loop on a slow load, fill the queue, next submit is
        rejected."""
        g, scans = case16
        release = threading.Event()

        class SlowSource:
            def load(self, mesh=None):
                release.wait(10.0)
                return np.asarray(scans[0])

        svc = ReconstructionService(max_queue=2).serve()
        slow = svc.submit(source=SlowSource(), geometry=g)
        # wait until the loop has snapshotted `slow` out of the queue and
        # is blocked on its load — then the queue is empty and ours alone
        deadline = time.monotonic() + 5.0
        while ((svc.queued or slow.state is TicketState.QUEUED)
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert slow.state is not TicketState.QUEUED
        queued = [svc.submit(projections=scans[1], geometry=g)
                  for _ in range(2)]            # fills max_queue=2
        with pytest.raises(QueueFullError):
            svc.submit(projections=scans[2], geometry=g)
        release.set()
        for t in [slow] + queued:
            assert t.wait(timeout=60.0)
        svc.shutdown()
        st = svc.stats()
        assert st["rejected"] >= 1
        assert st["submitted"] == st["served"] + st["failed"] == 3
        svc.close()

    def test_concurrent_submitters_race_the_loop(self, case16):
        """ISSUE 9 headline test: N threads submit against the running
        loop. No ticket is lost, duplicated, or left non-terminal, and
        submitted == served + failed (+ rejected on the submit side) at
        shutdown."""
        g, scans = case16
        n_threads, per_thread = 4, 6
        svc = ReconstructionService(max_batch=4, max_queue=8).serve()
        tickets, rejected = [], []
        lock = threading.Lock()

        def submitter(tid):
            for k in range(per_thread):
                while True:
                    try:
                        t = svc.submit(projections=scans[k % len(scans)],
                                       geometry=g,
                                       scan_id=f"t{tid}-{k}")
                    except QueueFullError:
                        with lock:
                            rejected.append(1)
                        time.sleep(0.005)     # backpressure: retry
                        continue
                    with lock:
                        tickets.append(t)
                    break

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=120.0)
        assert not any(th.is_alive() for th in threads)
        for t in tickets:
            assert t.wait(timeout=120.0), t.state
        svc.shutdown()
        # no ticket lost or duplicated
        assert len(tickets) == n_threads * per_thread
        assert len({t.scan_id for t in tickets}) == len(tickets)
        # every ticket terminal, every volume present
        assert all(t.terminal for t in tickets)
        assert all(t.done and t.volume is not None for t in tickets)
        st = svc.stats()
        assert st["submitted"] == len(tickets)
        assert st["submitted"] == st["served"] + st["failed"]
        assert st["rejected"] == len(rejected)
        assert st["queued"] == 0
        ref = plan_from_spec(g, "auto").build()
        np.testing.assert_array_equal(
            np.asarray(ref(scans[0])),
            np.asarray(next(t for t in tickets
                            if t.scan_id == "t0-0").result()))
        svc.close()


class TestSchedulingPolicies:
    """Cross-family bucket ordering (`policy=`): drain() returns tickets
    in execution order, which is what these assertions read."""

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError, match="policy"):
            ReconstructionService(policy="sjf")

    def test_deadline_policy_reorders_ahead_of_fifo(self, case16):
        """ISSUE 9 acceptance: EDF serves the urgent family first even
        though the lax one arrived first; fifo keeps arrival order."""
        g, scans = case16

        def submit_mixed(svc):
            lax = svc.submit(projections=scans[0], geometry=g,
                             deadline_s=100.0)
            urgent = svc.submit(projections=scans[1], geometry=g,
                                precision="bf16", deadline_s=0.5)
            return lax, urgent

        svc = ReconstructionService(policy="deadline")
        lax, urgent = submit_mixed(svc)
        order = [t.scan_id for t in svc.drain()]
        assert order == [urgent.scan_id, lax.scan_id]
        svc.close()

        svc = ReconstructionService(policy="fifo")
        lax, urgent = submit_mixed(svc)
        order = [t.scan_id for t in svc.drain()]
        assert order == [lax.scan_id, urgent.scan_id]
        svc.close()

    def test_deadline_less_buckets_run_last_in_arrival_order(self, case16):
        g, scans = case16
        svc = ReconstructionService(policy="deadline")
        plain = svc.submit(projections=scans[0], geometry=g)
        slo = svc.submit(projections=scans[1], geometry=g,
                         precision="bf16", deadline_s=5.0)
        order = [t.scan_id for t in svc.drain()]
        assert order == [slo.scan_id, plain.scan_id]
        svc.close()

    def test_largest_bucket_policy_maximizes_occupancy_first(self, case16):
        g, scans = case16

        def submit_mixed(svc):
            small = [svc.submit(projections=scans[0], geometry=g)]
            big = [svc.submit(projections=p, geometry=g, precision="bf16")
                   for p in scans[1:4]]
            return small, big

        svc = ReconstructionService(max_batch=4, policy="largest_bucket")
        small, big = submit_mixed(svc)
        order = [t.scan_id for t in svc.drain()]
        assert order == [t.scan_id for t in big + small]
        svc.close()

        svc = ReconstructionService(max_batch=4, policy="fifo")
        small, big = submit_mixed(svc)
        order = [t.scan_id for t in svc.drain()]
        assert order == [t.scan_id for t in small + big]
        svc.close()

    def test_fifo_round_robin_is_fair_across_families(self, case16):
        """A chatty family (3 buckets queued) cannot starve a quiet one:
        round-robin serves the quiet family's bucket in round one, not
        after the whole backlog."""
        g, scans = case16
        svc = ReconstructionService(max_batch=2, policy="fifo")
        chatty = [svc.submit(projections=scans[k % len(scans)], geometry=g)
                  for k in range(5)]                  # buckets: 2 + 2 + 1
        quiet = svc.submit(projections=scans[0], geometry=g,
                           precision="bf16")          # arrives LAST
        order = [t.scan_id for t in svc.drain()]
        expect = [chatty[0].scan_id, chatty[1].scan_id,   # A bucket 1
                  quiet.scan_id,                          # B bucket 1 (!)
                  chatty[2].scan_id, chatty[3].scan_id,   # A bucket 2
                  chatty[4].scan_id]                      # A bucket 3
        assert order == expect
        assert all(t.done for t in chatty + [quiet])
        svc.close()


class TestSLO:
    def test_met_and_missed_counters(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        met = svc.submit(projections=scans[0], geometry=g, deadline_s=60.0)
        missed = svc.submit(projections=scans[1], geometry=g,
                            deadline_s=0.0)   # already due at submit
        nolo = svc.submit(projections=scans[2], geometry=g)
        svc.drain()
        assert met.done and missed.done and nolo.done
        st = svc.stats()["slo"]
        assert st == {"met": 1, "missed": 1, "attainment": 0.5}
        svc.close()

    def test_no_deadlines_means_no_attainment(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        svc.submit(projections=scans[0], geometry=g)
        svc.drain()
        assert svc.stats()["slo"] == {"met": 0, "missed": 0,
                                      "attainment": None}
        svc.close()

    def test_failed_ticket_with_deadline_counts_missed(self, case16):
        g, _ = case16

        class ExplodingSource:
            def load(self, mesh=None):
                raise IOError("bad shard")

        svc = ReconstructionService()
        t = svc.submit(source=ExplodingSource(), geometry=g,
                       deadline_s=60.0)
        svc.drain()
        assert t.state is TicketState.FAILED
        assert svc.stats()["slo"]["missed"] == 1
        svc.close()

    def test_negative_deadline_rejected(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="deadline_s"):
            svc.submit(projections=scans[0], geometry=g, deadline_s=-1.0)
        assert svc.stats()["rejected"] == 1
        svc.close()

    def test_ticket_deadline_is_absolute(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        t = svc.submit(projections=scans[0], geometry=g, deadline_s=30.0)
        assert t.deadline == pytest.approx(t.submitted_at + 30.0)
        plain = svc.submit(projections=scans[1], geometry=g)
        assert plain.deadline is None
        svc.close()


class TestScanFamily:
    def test_identity_is_geometry_mesh_pins(self, case16):
        g, _ = case16
        g2 = default_geometry(16, n_proj=24)
        m = _mesh()
        a = ScanFamily.make(g, m, {})
        assert a == ScanFamily.make(g, m, {})
        assert a != ScanFamily.make(g2, m, {})
        assert a != ScanFamily.make(g, None, {})
        assert a != ScanFamily.make(g, m, {"precision": "bf16"})
        # pin order canonicalized
        assert (ScanFamily.make(g, m, {"a": 1, "b": 2})
                == ScanFamily.make(g, m, {"b": 2, "a": 1}))
        assert hash(a) == hash(ScanFamily.make(g, m, {}))
