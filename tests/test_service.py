"""Reconstruction-as-a-service (repro/service): admission, bucketing,
plan-cache amortization, async I/O overlap, and failure isolation.

This file doubles as the CI fast-tier service smoke test (ci.yml), so it
stays on the 16^3 geometry and the 1x1x1 mesh.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.geometry import default_geometry
from repro.core.plan import clear_engine_cache, plan_from_spec
from repro.io import (
    AsyncWriteback, PrefetchError, ProjectionSource, SourcePrefetcher,
    VolumeSink,
)
from repro.parallel.mesh import make_mesh
from repro.service import (
    AdmissionError, QueueFullError, ReconstructionService, ScanFamily,
    TicketState,
)


@pytest.fixture(scope="module")
def case16():
    from repro.core.phantom import forward_project
    g = default_geometry(16, n_proj=8)
    base = np.asarray(forward_project(g))
    rng = np.random.default_rng(3)
    scans = [jnp.asarray(base * (1.0 + 0.25 * k)
                         + rng.standard_normal(base.shape).astype(np.float32)
                         * 0.01)
             for k in range(5)]
    return g, scans


def _mesh():
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


class TestServeAndBucket:
    def test_drain_is_bitexact_vs_single_scan_engine(self, case16):
        g, scans = case16
        mesh = _mesh()
        clear_engine_cache()
        svc = ReconstructionService(mesh, max_batch=8)
        tickets = [svc.submit(projections=p, geometry=g) for p in scans]
        served = svc.drain()
        assert [t.scan_id for t in served] == [t.scan_id for t in tickets]
        assert all(t.state is TicketState.DONE for t in tickets)
        ref = plan_from_spec(g, "auto", mesh=mesh).build()
        for p, t in zip(scans, tickets):
            np.testing.assert_array_equal(np.asarray(ref(p)),
                                          np.asarray(t.result()))
        st = svc.stats()
        # 5 scans -> one bucket of 8 (next power of two), 3 pad lanes
        assert st["buckets"] == 1 and st["padded_lanes"] == 3
        assert st["served"] == 5 and st["queued"] == 0
        svc.close()

    def test_plan_cache_amortizes_planner_search(self, case16):
        """ISSUE 7 acceptance: the second same-family request does ZERO
        planner-search work — the searches counter stays at 1."""
        g, scans = case16
        svc = ReconstructionService(max_batch=4)
        svc.submit(projections=scans[0], geometry=g)
        svc.drain()
        assert svc.stats()["plan_cache"]["searches"] == 1
        svc.submit(projections=scans[1], geometry=g)
        svc.drain()
        st = svc.stats()
        assert st["plan_cache"]["searches"] == 1      # no new search
        assert st["plan_cache"]["hits"] >= 1
        # a pinned request is a NEW family -> exactly one more search
        svc.submit(projections=scans[2], geometry=g, precision="bf16")
        svc.drain()
        assert svc.stats()["plan_cache"]["searches"] == 2
        svc.close()

    def test_families_never_share_a_bucket(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_batch=8)
        t1 = svc.submit(projections=scans[0], geometry=g)
        t2 = svc.submit(projections=scans[1], geometry=g, precision="bf16")
        svc.drain()
        assert svc.stats()["buckets"] == 2
        assert t1.family != t2.family
        assert t1.done and t2.done
        svc.close()

    def test_max_batch_splits_buckets(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_batch=2)
        for p in scans:                       # 5 scans, cap 2
            svc.submit(projections=p, geometry=g)
        tickets = svc.drain()
        assert all(t.done for t in tickets)
        st = svc.stats()
        assert st["buckets"] == 3             # 2 + 2 + 1
        # the trailing bucket of 1 runs at batch size 1 — no pad needed
        assert st["padded_lanes"] == 0
        svc.close()


class TestAdmission:
    def test_footprint_over_budget_rejected(self, case16):
        g, scans = case16
        svc = ReconstructionService(hbm_bytes=1024)
        with pytest.raises(AdmissionError, match="budget"):
            svc.submit(projections=scans[0], geometry=g)
        assert svc.queued == 0
        svc.close()

    def test_queue_full_backpressure(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_queue=1)
        svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(QueueFullError):
            svc.submit(projections=scans[1], geometry=g)
        assert svc.queued == 1
        svc.drain()
        svc.submit(projections=scans[1], geometry=g)   # drained -> space
        svc.close()

    def test_shape_mismatch_rejected(self, case16):
        g, _ = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="shape"):
            svc.submit(projections=jnp.zeros((1, 2, 3)), geometry=g)
        svc.close()

    def test_exactly_one_data_source(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(geometry=g)
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(projections=scans[0], source=object(), geometry=g)
        svc.close()

    def test_incremental_schedule_pin_rejected_at_submit(self, case16):
        """schedule='incremental' has no batched engine; a pinned request
        must be rejected at submit, not queue work that fails at drain."""
        g, scans = case16
        svc = ReconstructionService()
        with pytest.raises(AdmissionError, match="incremental"):
            svc.submit(projections=scans[0], geometry=g,
                       schedule="incremental")
        assert svc.queued == 0
        assert svc.stats()["rejected"] == 1
        svc.close()

    def test_every_rejection_path_counts(self, case16):
        g, scans = case16
        svc = ReconstructionService(max_queue=1)
        with pytest.raises(AdmissionError, match="shape"):
            svc.submit(projections=jnp.zeros((1, 2, 3)), geometry=g)
        with pytest.raises(AdmissionError, match="exactly one"):
            svc.submit(geometry=g)
        svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(QueueFullError):
            svc.submit(projections=scans[1], geometry=g)
        assert svc.stats()["rejected"] == 3
        svc.close()
        svc = ReconstructionService(hbm_bytes=1024)
        with pytest.raises(AdmissionError, match="budget"):
            svc.submit(projections=scans[0], geometry=g)
        assert svc.stats()["rejected"] == 1
        svc.close()

    def test_result_before_drain_raises(self, case16):
        g, scans = case16
        svc = ReconstructionService()
        t = svc.submit(projections=scans[0], geometry=g)
        with pytest.raises(RuntimeError, match="queued"):
            t.result()
        svc.close()


class TestAsyncIO:
    def test_source_and_sink_roundtrip(self, case16, tmp_path):
        """PFS-backed scan: projections prefetch-read from a shard store,
        volume written behind to a sink, both byte-faithful."""
        g, scans = case16
        mesh = _mesh()
        src = ProjectionSource.write(str(tmp_path / "scan"),
                                     np.asarray(scans[0]))
        sink = VolumeSink(str(tmp_path / "vol"))
        svc = ReconstructionService(mesh)
        t = svc.submit(source=src, geometry=g, sink=sink)
        svc.drain()
        assert t.done
        ref = plan_from_spec(g, "auto", mesh=mesh).build()(scans[0])
        np.testing.assert_array_equal(np.asarray(t.result()),
                                      np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(sink.read()),
                                      np.asarray(ref))
        st = svc.stats()
        assert st["prefetched_loads"] == 1 and st["writebacks"] == 1
        svc.close()

    def test_failed_writeback_fails_only_its_ticket(self, case16, tmp_path):
        g, scans = case16

        class ExplodingSink:
            def write(self, volume, layout=None):
                raise IOError("disk full")

        svc = ReconstructionService()
        ok = svc.submit(projections=scans[0], geometry=g,
                        sink=VolumeSink(str(tmp_path / "ok")))
        bad = svc.submit(projections=scans[1], geometry=g,
                         sink=ExplodingSink())
        svc.drain()
        assert ok.state is TicketState.DONE
        assert bad.state is TicketState.FAILED
        with pytest.raises(RuntimeError, match="failed"):
            bad.result()
        assert isinstance(bad.error, IOError)
        st = svc.stats()
        assert st["failed"] == 1 and st["served"] == 1
        svc.close()


class TestFailureIsolation:
    def test_failed_engine_build_does_not_corrupt_next_bucket(
            self, case16, tmp_path):
        """REVIEW regression: a bucket that fails BEFORE consuming its
        prefetched loads (plan resolve / engine build raising at drain
        time) must not leave them queued — the next bucket's scans would
        silently reconstruct from the wrong scans' data and be DONE."""
        g, scans = case16
        src_a = ProjectionSource.write(str(tmp_path / "a"),
                                       np.asarray(scans[0]))
        src_b = ProjectionSource.write(str(tmp_path / "b"),
                                       np.asarray(scans[1]))
        svc = ReconstructionService()
        ta = svc.submit(source=src_a, geometry=g)
        # a pinned request is its own family -> its own (later) bucket
        tb = svc.submit(source=src_b, geometry=g, precision="bf16")
        real_resolve = svc.plan_cache.resolve
        calls = {"a": 0}

        def poisoned(family):
            if family == ta.family:
                calls["a"] += 1
                if calls["a"] > 1:   # bucketing resolve OK, serving fails
                    raise RuntimeError("engine build exploded")
            return real_resolve(family)

        svc.plan_cache.resolve = poisoned
        served = svc.drain()
        svc.plan_cache.resolve = real_resolve
        assert len(served) == 2
        assert ta.state is TicketState.FAILED
        assert isinstance(ta.error, RuntimeError)
        # bucket B served from ITS OWN projections, bit-exact
        assert tb.state is TicketState.DONE
        ref = plan_from_spec(g, "auto", precision="bf16").build()(scans[1])
        np.testing.assert_array_equal(np.asarray(tb.result()),
                                      np.asarray(ref))
        st = svc.stats()
        assert st["failed"] == 1 and st["served"] == 1
        svc.close()

    def test_failed_load_fails_only_its_bucket(self, case16, tmp_path):
        """A source whose load raises fails its own bucket's tickets with
        PrefetchError; later buckets still serve from their own data."""
        g, scans = case16

        class ExplodingSource:
            def load(self, mesh=None):
                raise IOError("bad shard")

        src_b = ProjectionSource.write(str(tmp_path / "b"),
                                       np.asarray(scans[1]))
        svc = ReconstructionService()
        ta = svc.submit(source=ExplodingSource(), geometry=g)
        tb = svc.submit(source=src_b, geometry=g, precision="bf16")
        svc.drain()
        assert ta.state is TicketState.FAILED
        assert isinstance(ta.error, PrefetchError)
        assert tb.state is TicketState.DONE
        ref = plan_from_spec(g, "auto", precision="bf16").build()(scans[1])
        np.testing.assert_array_equal(np.asarray(tb.result()),
                                      np.asarray(ref))
        svc.close()


class TestPrefetcher:
    def test_order_preserved(self):
        """Jobs complete in submission order regardless of their cost —
        the service pairs get() k with scan k by position."""
        def slow():
            time.sleep(0.05)
            return "a"
        pf = SourcePrefetcher([slow, lambda: "b", lambda: "c"],
                              depth=2).start()
        assert [pf.get(), pf.get(), pf.get()] == ["a", "b", "c"]
        with pytest.raises(StopIteration):
            pf.get()
        pf.close()

    def test_depth_bounds_readahead(self):
        """Double-buffering, not slurping: at most `depth` loads sit in
        memory before the consumer asks."""
        started = []

        def job(k):
            def run():
                started.append(k)
                return k
            return run
        pf = SourcePrefetcher([job(k) for k in range(6)], depth=2).start()
        deadline = time.monotonic() + 5.0
        while len(started) < 3 and time.monotonic() < deadline:
            time.sleep(0.005)   # fill: depth queued + 1 blocked in put
        time.sleep(0.05)
        assert len(started) <= 4
        assert [pf.get() for _ in range(6)] == list(range(6))
        pf.close()

    def test_error_propagates_as_prefetch_error(self):
        """A failed load is re-raised by the MATCHING get(); later jobs
        still run, so the queue stays positionally aligned (one bad shard
        fails only its own scan, not every scan behind it)."""
        def boom():
            raise IOError("bad shard")
        pf = SourcePrefetcher([lambda: 1, boom, lambda: 3]).start()
        assert pf.get() == 1
        with pytest.raises(PrefetchError, match="bad shard"):
            pf.get()
        assert pf.get() == 3          # the worker did NOT stop at the error
        with pytest.raises(StopIteration):
            pf.get()
        pf.close()


class TestWriteback:
    def test_drain_reraises_first_failure(self, tmp_path):
        class Sink:
            def __init__(self):
                self.wrote = []

            def write(self, volume, layout=None):
                self.wrote.append(np.asarray(volume).copy())

        class Bad:
            def write(self, volume, layout=None):
                raise IOError("enospc")

        wb = AsyncWriteback(max_pending=2)
        good = Sink()
        wb.submit(good, jnp.ones((2, 2)))
        wb.submit(Bad(), jnp.ones((2, 2)))
        with pytest.raises(IOError, match="enospc"):
            wb.drain()
        assert len(good.wrote) == 1
        wb.close()

    def test_completed_futures_pruned_on_submit(self):
        """REVIEW regression: a long-lived service result()s futures
        directly and never calls drain(); submit must prune completed-OK
        writes or the pending list grows forever."""
        class Sink:
            def write(self, volume, layout=None):
                pass

        wb = AsyncWriteback(max_pending=2)
        for _ in range(8):
            wb.submit(Sink(), jnp.ones((2,))).result()
        assert len(wb._futures) <= 2    # not 8: done futures were pruned
        wb.close()

    def test_backpressure_blocks_at_max_pending(self):
        release = threading.Event()
        wrote = []

        class SlowSink:
            def write(self, volume, layout=None):
                release.wait(5.0)
                wrote.append(1)

        wb = AsyncWriteback(max_pending=1)
        t0 = time.monotonic()
        wb.submit(SlowSink(), jnp.ones((2,)))

        def delayed_release():
            time.sleep(0.1)
            release.set()
        threading.Thread(target=delayed_release, daemon=True).start()
        wb.submit(SlowSink(), jnp.ones((2,)))   # must wait for slot
        assert time.monotonic() - t0 >= 0.05
        # the first write completed during submit #2's backpressure wait
        # and was pruned there; drain joins (at least) the second.
        assert wb.drain() >= 1
        assert len(wrote) == 2      # both writes ran
        wb.close()


class TestScanFamily:
    def test_identity_is_geometry_mesh_pins(self, case16):
        g, _ = case16
        g2 = default_geometry(16, n_proj=24)
        m = _mesh()
        a = ScanFamily.make(g, m, {})
        assert a == ScanFamily.make(g, m, {})
        assert a != ScanFamily.make(g2, m, {})
        assert a != ScanFamily.make(g, None, {})
        assert a != ScanFamily.make(g, m, {"precision": "bf16"})
        # pin order canonicalized
        assert (ScanFamily.make(g, m, {"a": 1, "b": 2})
                == ScanFamily.make(g, m, {"b": 2, "a": 1}))
        assert hash(a) == hash(ScanFamily.make(g, m, {}))
