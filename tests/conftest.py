import os
import tempfile

import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU.
# Distributed tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.

# Hermetic autotuner persistence: keep the file-backed tuning cache out of
# ~/.cache during test runs (subprocess tests inherit this env, so
# cross-process persistence still works within one session).
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-"),
                 "bp_tune_cache.json"),
)
# Same hermeticity for the planner's measured-refinement cache.
os.environ.setdefault(
    "REPRO_PLAN_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-plan-"),
                 "plan_measure_cache.json"),
)
# The calibration store stays OFF by default in tests: traced runs and
# measured refinements would otherwise accumulate host-specific timings
# into ~/.cache and make auto_plan's "auto" calibration nondeterministic
# across the suite. Tests that want a store install one explicitly
# (planner.calibrate.set_default_store / CalibrationStore(path=...)).
os.environ.setdefault("REPRO_CALIB_CACHE", "off")

jax.config.update("jax_enable_x64", False)

# The fast tier is compile-bound (hundreds of small jitted engines), not
# compute-bound: XLA's persistent compilation cache cuts repeat runs on the
# same machine by roughly a third. Keyed by HLO, so it can never change
# results — only skip recompiles. REPRO_COMPILE_CACHE=off disables it;
# any other value overrides the cache directory.
_cc = os.environ.get("REPRO_COMPILE_CACHE", "")
if _cc.lower() not in ("off", "0"):
    jax.config.update(
        "jax_compilation_cache_dir",
        _cc or os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "xla_cache"))
    # Only persist compiles that cost real time — writing every trivial
    # executable to disk costs more on the cold run than it saves warm.
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)


def pytest_configure(config):
    # Fast tier: `pytest -m "not slow"` (~80 s warm on this container, vs
    # ~7 min full — see DESIGN.md §Test tiers) skips the multi-minute
    # subprocess/distributed runs and the heavyweight LM smoke configs;
    # the full suite runs everything (nightly CI).
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess/distributed or heavyweight smoke "
        "tests; deselect with -m 'not slow' for the fast tier",
    )


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
