import os

import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU.
# Distributed tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
