import os
import tempfile

import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU.
# Distributed tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.

# Hermetic autotuner persistence: keep the file-backed tuning cache out of
# ~/.cache during test runs (subprocess tests inherit this env, so
# cross-process persistence still works within one session).
os.environ.setdefault(
    "REPRO_TUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-tune-"),
                 "bp_tune_cache.json"),
)
# Same hermeticity for the planner's measured-refinement cache.
os.environ.setdefault(
    "REPRO_PLAN_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-plan-"),
                 "plan_measure_cache.json"),
)

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # Fast tier: `pytest -m "not slow"` (~90 s on this container, vs ~6 min
    # full) skips the multi-minute subprocess/distributed runs and the
    # heavyweight LM smoke configs; the full suite runs everything.
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess/distributed or heavyweight smoke "
        "tests; deselect with -m 'not slow' for the fast tier",
    )


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
