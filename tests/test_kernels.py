"""Pallas back-projection kernel vs the pure-jnp oracle (ref.py).

Per the deliverable: sweep shapes/dtypes and assert_allclose against the
oracle. interpret=True executes the kernel body on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backprojection import backproject_factorized, to_dual_slab
from repro.core.filtering import filter_projections
from repro.core.geometry import default_geometry, projection_matrices
from repro.core.phantom import forward_project
from repro.kernels.backproject.kernel import backproject_dual_pallas, vmem_bytes
from repro.kernels.backproject.ops import backproject_mxu, backproject_pallas
from repro.kernels.backproject.ref import backproject_dual_ref


def _case(n, n_proj):
    g = default_geometry(n, n_proj=n_proj)
    pm = jnp.asarray(projection_matrices(g))
    q = filter_projections(g, forward_project(g))
    return g, pm, q


class TestPallasKernel:
    # (12, 6) was (24, 6): same non-power-of-two/odd-batch coverage at an
    # eighth of the voxels — fast-tier diet (DESIGN.md §Test tiers).
    @pytest.mark.parametrize("n,n_proj", [(8, 4), (16, 8), (16, 12), (12, 6)])
    def test_shape_sweep_vs_oracle(self, n, n_proj):
        g, pm, q = _case(n, n_proj)
        want = backproject_dual_ref(pm, jnp.swapaxes(q, -1, -2),
                                    g.n_x, g.n_y, g.n_z)
        got = to_dual_slab(backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z))
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("bi,bj,bs", [(4, 4, 2), (8, 8, 4), (16, 16, 12)])
    def test_block_shape_sweep(self, bi, bj, bs):
        g, pm, q = _case(16, 12)
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        got = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z,
                                 bi=bi, bj=bj, bs=bs)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-6)

    def test_bf16_projections(self):
        """bf16 input with f32 accumulation stays within bf16 tolerance."""
        g, pm, q = _case(16, 8)
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        got = backproject_pallas(pm, q.astype(jnp.bfloat16),
                                 g.n_x, g.n_y, g.n_z)
        scale = float(jnp.max(jnp.abs(want))) + 1e-12
        assert float(jnp.max(jnp.abs(got - want))) / scale < 0.03

    def test_projection_padding(self):
        """N_p not divisible by the batch block is padded harmlessly."""
        g, pm, q = _case(16, 10)  # 10 % 8 != 0
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        got = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z, bs=8)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-6)

    def test_vmem_budget_helper(self):
        # a VMEM-conscious config for a 1k detector (bf16 batch of 2) fits
        assert vmem_bytes(8, 8, 2, 1024, 1024, 512, jnp.bfloat16) < 8 * 2**20
        # and the helper scales linearly in the batch block
        assert vmem_bytes(8, 8, 4, 64, 64, 32) > vmem_bytes(8, 8, 2, 64, 64, 32)

    def test_kernel_accumulates_over_projection_batches(self):
        """Grid revisiting: two batches must sum, not overwrite."""
        g, pm, q = _case(8, 8)
        got = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z, bs=4)
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-5, atol=1e-6)


class TestMXUVariant:
    """Gather-free (relu-hat matmul) formulation — bit-exact semantics."""

    @pytest.mark.parametrize("n,n_proj", [(8, 4), (16, 8)])
    def test_vs_factorized(self, n, n_proj):
        g, pm, q = _case(n, n_proj)
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        got = backproject_mxu(pm, q, g.n_x, g.n_y, g.n_z)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-6)

    def test_boundary_handling_without_masks(self):
        """Out-of-range coordinates get zero weight for free."""
        g, pm, _ = _case(8, 4)
        # projections of ones: center voxels accumulate, far voxels may be 0
        q = jnp.ones(g.proj_shape(), jnp.float32)
        got = backproject_mxu(pm, q, g.n_x, g.n_y, g.n_z)
        want = backproject_factorized(pm, q, g.n_x, g.n_y, g.n_z)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   rtol=1e-4, atol=1e-5)
