"""`ReconstructionPlan.build_batched` — the service's bucketed engine.

The contract under test is BIT-exactness: lane i of the vmapped batched
engine must produce byte-identical output to the single-scan engine on
scan i, for every (schedule, impl, codec) the plan space offers. The
engine earns this two ways (core/plan.py):

  * filter + encode are hoisted OUT of the vmap (the batch is flattened
    into the projection axis — legal because filtering is per-projection
    independent), which also sidesteps the XLA CPU bug where a collective
    after an FFT under vmap(shard_map) poisons the FFT operand layout;
  * the back-projectors pin their coordinate chains behind an
    optimization_barrier so batched and unbatched compilations contract
    the same FMAs (core/backprojection.py).

Padding is the other half of the bucketing story: a junk lane (even one
full of NaNs) must not perturb the real lanes' bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import batched_input_sharding, input_sharding
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import (
    ReconstructionPlan, clear_engine_cache, engine_cache_stats,
)
from repro.parallel.mesh import make_mesh

IMPLS = ("reference", "factorized", "kernel")
CODECS = ("fp32", "bf16", "fp16", "fp8_e4m3", "fp8_e5m2")


@pytest.fixture(scope="module")
def case16():
    g = default_geometry(16, n_proj=8)
    base = np.asarray(forward_project(g))
    rng = np.random.default_rng(7)
    scans = np.stack([
        base,
        base * 1.5,
        rng.standard_normal(base.shape).astype(np.float32),
    ])
    return g, scans


def _mesh():
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


def _assert_lanes_bitexact(plan, scans, mesh):
    single = plan.build()
    batched = plan.build_batched(scans.shape[0])
    if mesh is None:
        out = batched(scans)
        refs = [single(s) for s in scans]
    else:
        out = batched(jax.device_put(jnp.asarray(scans),
                                     batched_input_sharding(mesh)))
        refs = [single(jax.device_put(jnp.asarray(s), input_sharding(mesh)))
                for s in scans]
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(
            np.asarray(out[i]), np.asarray(ref),
            err_msg=f"lane {i} not bit-equal to the single-scan engine")


class TestBitExactness:
    @pytest.mark.parametrize("codec", CODECS)
    @pytest.mark.parametrize("impl", IMPLS)
    def test_impl_codec_cross_product(self, case16, impl, codec):
        g, scans = case16
        plan = ReconstructionPlan(geometry=g, mesh=_mesh(), impl=impl,
                                  precision=codec)
        _assert_lanes_bitexact(plan, scans, plan.mesh)

    @pytest.mark.parametrize("schedule", ("fused", "pipelined", "chunked"))
    def test_schedules(self, case16, schedule):
        g, scans = case16
        kw = ({} if schedule == "fused" else
              {"n_steps": 2} if schedule == "pipelined" else
              {"n_steps": 2, "y_chunks": 4})
        plan = ReconstructionPlan(geometry=g, mesh=_mesh(),
                                  schedule=schedule, **kw)
        _assert_lanes_bitexact(plan, scans, plan.mesh)

    @pytest.mark.parametrize("schedule", ("fused", "pipelined"))
    def test_no_mesh(self, case16, schedule):
        """mesh=None batched path (the CPU bench / single-host service)."""
        g, scans = case16
        kw = {} if schedule == "fused" else {"n_steps": 2}
        plan = ReconstructionPlan(geometry=g, schedule=schedule, **kw)
        _assert_lanes_bitexact(plan, scans, None)

    def test_scatter_reduce(self, case16):
        g, scans = case16
        plan = ReconstructionPlan(geometry=g, mesh=_mesh(),
                                  reduce="scatter")
        _assert_lanes_bitexact(plan, scans, plan.mesh)


class TestPadding:
    def test_junk_lane_cannot_perturb_real_lanes(self, case16):
        """The padded-bucket guarantee: real lanes are bit-identical
        whether the pad lane holds zeros, 1e30s, or NaNs — vmap lanes
        share no data."""
        g, scans = case16
        plan = ReconstructionPlan(geometry=g, mesh=_mesh())
        batched = plan.build_batched(4)
        sh = batched_input_sharding(plan.mesh)
        pads = [np.zeros_like(scans[0]),
                np.full_like(scans[0], 1e30),
                np.full_like(scans[0], np.nan)]
        outs = []
        for pad in pads:
            batch = jnp.asarray(np.concatenate([scans, pad[None]]))
            outs.append(np.asarray(batched(jax.device_put(batch, sh))))
        for other in outs[1:]:
            np.testing.assert_array_equal(outs[0][:3], other[:3])

    def test_nan_pad_stays_in_its_lane(self, case16):
        g, scans = case16
        plan = ReconstructionPlan(geometry=g)
        batch = np.concatenate(
            [scans, np.full_like(scans[0], np.nan)[None]])
        out = np.asarray(plan.build_batched(4)(jnp.asarray(batch)))
        assert np.all(np.isfinite(out[:3]))
        assert np.all(np.isnan(out[3]))


class TestBatchedEngineContract:
    def test_incremental_schedule_rejected(self, case16):
        g, _ = case16
        plan = ReconstructionPlan(geometry=g, schedule="incremental",
                                  n_steps=2)
        with pytest.raises(ValueError, match="incremental"):
            plan.build_batched(2)

    def test_batch_size_validated(self, case16):
        g, _ = case16
        with pytest.raises(ValueError):
            ReconstructionPlan(geometry=g).build_batched(0)

    def test_batched_engines_are_cached_per_batch_size(self, case16):
        g, _ = case16
        clear_engine_cache()
        plan = ReconstructionPlan(geometry=g)
        a = plan.build_batched(2)
        assert plan.build_batched(2) is a          # hit
        assert plan.build_batched(4) is not a      # different key
        assert plan.build() is not a               # single-scan key distinct
        stats = engine_cache_stats()
        assert stats["hits"] >= 1 and stats["misses"] >= 3
