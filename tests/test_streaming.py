"""Streaming instant-CT tests: IncrementalSession equivalence against the
batch engines (bit-for-bit for fp32 in-order folding, codec floors for the
quantized streams), the stage/fold split, the delta discovery protocol
(StreamingProjectionWriter -> ProjectionSource.poll/iter_deltas), the
VolumeSink layout round-trip, and the planner's incremental pricing.

The fast tier doubles as the CI smoke test (fast CI runs
`pytest -m "not slow"`); the mesh cross-product runs in a slow subprocess
with 8 virtual devices, like tests/test_plan.py."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.backprojection import backproject_reference
from repro.core.distributed import choose_grid
from repro.core.fdk import fdk_scale
from repro.core.filtering import filter_projections
from repro.core.geometry import default_geometry, projection_matrices
from repro.core.phantom import forward_project
from repro.core.plan import (
    IncrementalSession, ReconstructionPlan, StagedDelta, plan_from_spec,
)
from repro.core.precision import Precision
from repro.io import shard_store
from repro.io.streams import (
    ProjectionSource, StreamingProjectionWriter, VolumeSink,
)
from repro.planner.cost import (
    point_from_plan, predict_point, time_from_last_delta,
)
from repro.planner.feasibility import plan_footprint


@pytest.fixture(scope="module")
def geo():
    return default_geometry(16, n_proj=16)


@pytest.fixture(scope="module")
def proj(geo):
    return np.asarray(forward_project(geo))


@pytest.fixture(scope="module")
def fused_oracle(geo, proj):
    return np.asarray(ReconstructionPlan(geometry=geo).build()(proj))


def _session(geo, n_steps=4, **kw):
    plan = ReconstructionPlan(geometry=geo, schedule="incremental",
                              n_steps=n_steps, **kw)
    return plan.build_incremental()


# ---------------------------------------------------------------------------
# CI smoke + fp32 exactness contract
# ---------------------------------------------------------------------------


class TestIncrementalEquivalence:
    def test_smoke_session_lifecycle(self, geo, proj, fused_oracle):
        """The small incremental-session smoke test the fast CI tier runs:
        fold all deltas, finalize, match the fused engine."""
        sess = _session(geo, n_steps=2)
        assert sess.n_folded == 0 and not sess.is_complete
        sess.update(proj[:8], (0, 8))
        assert sess.n_folded == 8
        assert sess.pending_ranges() == [(8, 16)]
        sess.update(proj[8:], (8, 16))
        assert sess.is_complete
        vol = np.asarray(sess.finalize())
        np.testing.assert_array_equal(vol, fused_oracle)

    @pytest.mark.parametrize("impl", ["reference", "factorized"])
    def test_in_order_bit_exact(self, geo, proj, impl):
        """In-order incremental folding continues the fused engine's
        per-voxel addition sequence (`init=` threading): bit-for-bit."""
        oracle = np.asarray(
            ReconstructionPlan(geometry=geo, impl=impl).build()(proj))
        sess = _session(geo, n_steps=4, impl=impl)
        for k in range(4):
            sess.update(proj[4 * k:4 * (k + 1)], (4 * k, 4 * (k + 1)))
        np.testing.assert_array_equal(np.asarray(sess.finalize()), oracle)

    def test_any_order_matches_permuted_fused_stream(self, geo, proj,
                                                     fused_oracle):
        """Folding deltas out of order is bit-identical to the fused fold
        of that same permuted projection stream (f32 addition does not
        commute, so no schedule can make every order bit-equal to the
        canonical one — permutations agree with it only to reassociation
        tolerance)."""
        order = [2, 0, 3, 1]
        sess = _session(geo, n_steps=4, impl="reference")
        for k in order:
            sess.update(proj[4 * k:4 * (k + 1)], (4 * k, 4 * (k + 1)))
        vol = np.asarray(sess.finalize())

        # the fused engine's own stages, fed the permuted stream
        perm = np.concatenate([np.arange(4 * k, 4 * (k + 1))
                               for k in order])
        q = np.asarray(filter_projections(geo, proj))[perm]
        pm = np.asarray(projection_matrices(geo))[perm]
        oracle_perm = np.asarray(backproject_reference(
            pm, q, geo.n_x, geo.n_y, geo.n_z)) * fdk_scale(geo)
        np.testing.assert_array_equal(vol, oracle_perm)

        # ... and within f32 reassociation tolerance of the canonical one
        rel = np.max(np.abs(vol - fused_oracle)) / np.max(
            np.abs(fused_oracle))
        assert rel < 5e-6

    @pytest.mark.parametrize("precision", ["bf16", "fp8_e4m3"])
    def test_codec_floor(self, geo, proj, fused_oracle, precision):
        """Quantized streams: in-order incremental is bit-identical to the
        same-codec fused engine (identical per-projection quantization,
        identical addition order), and within the codec's documented floor
        of the f32 oracle."""
        oracle_codec = np.asarray(ReconstructionPlan(
            geometry=geo, precision=precision).build()(proj))
        sess = _session(geo, n_steps=4, precision=precision)
        for k in range(4):
            sess.update(proj[4 * k:4 * (k + 1)], (4 * k, 4 * (k + 1)))
        vol = np.asarray(sess.finalize())
        np.testing.assert_array_equal(vol, oracle_codec)
        rel = np.max(np.abs(vol - fused_oracle)) / np.max(
            np.abs(fused_oracle))
        assert rel < Precision(precision).max_tol()

    def test_pipelined_n_steps_1_equals_fused(self, geo, proj,
                                              fused_oracle):
        """Degenerate micro-batching: one step, zero-length scan prologue —
        must be the fused result exactly."""
        out = np.asarray(ReconstructionPlan(
            geometry=geo, schedule="pipelined", n_steps=1).build()(proj))
        np.testing.assert_array_equal(out, fused_oracle)

    def test_incremental_n_steps_1_equals_fused(self, geo, proj,
                                                fused_oracle):
        """One delta covering the whole scan == the fused engine."""
        sess = _session(geo, n_steps=1)
        vol = np.asarray(sess.update(proj, (0, 16), finalize=True))
        np.testing.assert_array_equal(vol, fused_oracle)


class TestStagedFold:
    def test_staged_equals_raw(self, geo, proj, fused_oracle):
        sess = _session(geo, n_steps=4)
        for k in range(4):
            staged = sess.stage(proj[4 * k:4 * (k + 1)],
                                (4 * k, 4 * (k + 1)))
            assert isinstance(staged, StagedDelta)
            sess.update(staged)
        np.testing.assert_array_equal(np.asarray(sess.finalize()),
                                      fused_oracle)

    def test_fused_epilogue_matches_finalize(self, geo, proj,
                                             fused_oracle):
        """update(staged, finalize=True) — the one-dispatch tail — returns
        the same volume finalize() would."""
        sess = _session(geo, n_steps=2)
        sess.update(proj[:8], (0, 8))
        vol = np.asarray(sess.update(sess.stage(proj[8:], (8, 16)),
                                     finalize=True))
        np.testing.assert_array_equal(vol, fused_oracle)
        # the session state is folded too: finalize() agrees
        np.testing.assert_array_equal(np.asarray(sess.finalize()), vol)

    def test_staged_rejects_angle_slice(self, geo, proj):
        sess = _session(geo)
        staged = sess.stage(proj[:4], (0, 4))
        with pytest.raises(TypeError, match="carries its own angle range"):
            sess.update(staged, (0, 4))

    def test_stage_is_pure(self, geo, proj):
        sess = _session(geo)
        sess.stage(proj[:4], (0, 4))
        assert sess.n_folded == 0


class TestSessionGuards:
    def test_double_fold_rejected(self, geo, proj):
        sess = _session(geo)
        sess.update(proj[:4], (0, 4))
        with pytest.raises(ValueError, match="already folded"):
            sess.update(proj[:4], (0, 4))

    def test_staged_double_fold_rejected(self, geo, proj):
        """Coverage is re-checked at fold time, not just at stage time."""
        sess = _session(geo)
        staged = sess.stage(proj[:4], (0, 4))
        sess.update(proj[:4], (0, 4))
        with pytest.raises(ValueError, match="already folded"):
            sess.update(staged)

    def test_out_of_range_rejected(self, geo, proj):
        with pytest.raises(ValueError, match="out of range"):
            _session(geo).update(proj[:4], (12, 20))

    def test_shape_mismatch_rejected(self, geo, proj):
        with pytest.raises(ValueError, match="does not match angles"):
            _session(geo).update(proj[:4], (0, 8))

    def test_raw_delta_requires_angle_slice(self, geo, proj):
        with pytest.raises(TypeError, match="angle_slice is required"):
            _session(geo).update(proj[:4])

    def test_incomplete_finalize_raises_with_pending(self, geo, proj):
        sess = _session(geo)
        sess.update(proj[4:8], (4, 8))
        with pytest.raises(ValueError, match=r"\[\(0, 4\), \(8, 16\)\]"):
            sess.finalize()

    def test_partial_peek(self, geo, proj):
        """partial=True returns the limited-angle reconstruction and keeps
        the session open."""
        sess = _session(geo, n_steps=2)
        sess.update(proj[:8], (0, 8))
        peek = np.asarray(sess.finalize(partial=True))
        assert np.isfinite(peek).all()
        sess.update(proj[8:], (8, 16))   # still accepts updates
        assert sess.is_complete

    def test_build_rejects_incremental(self, geo):
        plan = ReconstructionPlan(geometry=geo, schedule="incremental",
                                  n_steps=2)
        with pytest.raises(ValueError, match="build_incremental"):
            plan.build()

    def test_build_incremental_rejects_batch(self, geo):
        with pytest.raises(ValueError, match="schedule='incremental'"):
            ReconstructionPlan(geometry=geo).build_incremental()


# ---------------------------------------------------------------------------
# choose_grid regressions (satellite bugfixes)
# ---------------------------------------------------------------------------


class TestChooseGridRegressions:
    def test_detector_term_alone_too_big_raises(self):
        """The old loop spun forever here: doubling R only shrinks the
        volume term, never the detector working set."""
        g = default_geometry(64)
        with pytest.raises(ValueError, match="detector working set"):
            choose_grid(g, 8, hbm_bytes=4 * g.n_u * g.n_v * 32 - 1)

    def test_r_not_tiling_nx_raises_at_choice_time(self):
        """An R the memory bound forces but N_x cannot tile is rejected
        where the number comes from, not later by validate()."""
        g = default_geometry(48)
        with pytest.raises(ValueError, match="does not tile N_x=48"):
            choose_grid(g, 64, sub_vol_bytes=16 * 1024)


# ---------------------------------------------------------------------------
# Delta discovery protocol + streaming I/O
# ---------------------------------------------------------------------------


class TestStreamingStore:
    def test_append_region_grows_manifest(self, tmp_path):
        path = str(tmp_path / "store")
        shard_store.init_store(path, (8, 4, 4), np.float32)
        assert shard_store.read_manifest(path)["shards"] == []
        data = np.arange(2 * 4 * 4, dtype=np.float32).reshape(2, 4, 4)
        shard_store.append_region(path, ((0, 2), (0, 4), (0, 4)), data)
        got = shard_store.read_region(path, ((0, 2), (0, 4), (0, 4)))
        np.testing.assert_array_equal(got, data)

    def test_append_overlap_rejected(self, tmp_path):
        path = str(tmp_path / "store")
        shard_store.init_store(path, (8, 4, 4), np.float32)
        d = np.zeros((2, 4, 4), np.float32)
        shard_store.append_region(path, ((0, 2), (0, 4), (0, 4)), d)
        with pytest.raises(shard_store.StoreError, match="overlap"):
            shard_store.append_region(path, ((1, 3), (0, 4), (0, 4)),
                                      d)

    def test_poll_discovers_committed_deltas(self, geo, proj, tmp_path):
        path = str(tmp_path / "proj")
        w = StreamingProjectionWriter(path, (16, geo.n_v, geo.n_u))
        src = ProjectionSource(path)
        assert src.poll() == []
        w.append(proj[:4], 0)
        w.append(proj[8:12], 8)
        assert src.poll() == [(0, 4), (8, 12)]
        # poll is read-only: ranges stay visible until iter_deltas consumes
        assert src.poll() == [(0, 4), (8, 12)]
        seen = [(lo, hi) for lo, hi, _ in src.iter_deltas()]
        assert seen == [(0, 4), (8, 12)]
        assert src.poll() == []
        w.append(proj[4:8], 4)
        assert src.poll() == [(4, 8)]

    def test_poll_missing_store_is_empty(self, tmp_path):
        assert ProjectionSource(str(tmp_path / "nowhere")).poll() == []

    def test_iter_deltas_early_break_is_not_rereported(self, geo, proj,
                                                       tmp_path):
        """REGRESSION (ISSUE 9): consumed used to be marked AFTER the
        yield, so a consumer that broke out of iter_deltas (the delta
        already delivered and folded) closed the generator before the
        mark ran — the next poll() re-reported the folded range and the
        session's coverage bitmap rejected it as an overlap."""
        path = str(tmp_path / "proj")
        w = StreamingProjectionWriter(path, (16, geo.n_v, geo.n_u))
        w.append(proj[:4], 0)
        w.append(proj[8:12], 8)
        src = ProjectionSource(path)
        for lo, hi, delta in src.iter_deltas():
            assert (lo, hi) == (0, 4)
            np.testing.assert_array_equal(np.asarray(delta), proj[:4])
            break                  # consumer bails between deltas
        # the delivered range is consumed; only the second one remains
        assert src.poll() == [(8, 12)]
        assert [(lo, hi) for lo, hi, _ in src.iter_deltas()] == [(8, 12)]
        assert src.poll() == []

    def test_load_slice_matches_source(self, geo, proj, tmp_path):
        path = str(tmp_path / "proj")
        w = StreamingProjectionWriter(path, (16, geo.n_v, geo.n_u))
        w.append(proj, 0)
        got = np.asarray(ProjectionSource(path).load_slice(4, 12))
        np.testing.assert_array_equal(got, proj[4:12])

    def test_scaled_codec_round_trip(self, geo, proj, tmp_path):
        """fp8 streaming store: sidecar committed before data, load_slice
        decodes data x scales — bit-identical to the codec round-trip."""
        path = str(tmp_path / "proj")
        w = StreamingProjectionWriter(path, (16, geo.n_v, geo.n_u),
                                      codec="fp8_e4m3")
        w.append(proj[:8], 0)
        prec = Precision("fp8_e4m3")
        data, scales = prec.codec.encode(proj[:8])
        expect = np.asarray(prec.codec.decode(data, scales))
        got = np.asarray(ProjectionSource(path).load_slice(0, 8))
        np.testing.assert_array_equal(got, expect)
        assert os.path.exists(os.path.join(path, "scales",
                                           shard_store.MANIFEST))

    def test_session_poll_folds_and_finalizes(self, geo, proj, tmp_path,
                                              fused_oracle):
        """The full discovery loop: scanner appends, session.poll folds,
        finalize streams to the sink — matches the fused engine."""
        path = str(tmp_path / "proj")
        w = StreamingProjectionWriter(path, (16, geo.n_v, geo.n_u))
        src = ProjectionSource(path)
        sink = VolumeSink(str(tmp_path / "vol"))
        plan = ReconstructionPlan(geometry=geo, schedule="incremental",
                                  n_steps=4)
        sess = plan.build_incremental(source=src, sink=sink)
        assert sess.poll() == 0
        w.append(proj[:8], 0)
        assert sess.poll() == 1
        assert sess.pending_ranges() == [(8, 16)]
        w.append(proj[8:12], 8)
        w.append(proj[12:16], 12)
        assert sess.poll() == 2
        vol = np.asarray(sess.finalize())
        np.testing.assert_array_equal(vol, fused_oracle)
        np.testing.assert_array_equal(sink.read(), fused_oracle)

    def test_poll_without_source_raises(self, geo):
        with pytest.raises(TypeError, match="without a ProjectionSource"):
            _session(geo).poll()


class TestVolumeSinkLayout:
    def test_canonical_store_has_no_layout(self, tmp_path):
        sink = VolumeSink(str(tmp_path / "vol"))
        vol = np.arange(4 * 4 * 4, dtype=np.float32).reshape(4, 4, 4)
        sink.write(vol)
        assert sink.layout() is None
        np.testing.assert_array_equal(sink.read(), vol)

    def test_y_chunk_major_round_trip(self, tmp_path):
        """The chunked+scatter engine's 4-D accumulator layout is recorded
        in the manifest and canonicalized on read."""
        vol = np.arange(4 * 8 * 4, dtype=np.float32).reshape(4, 8, 4)
        chunked = vol.reshape(4, 2, 4, 4)     # (N_x, y_chunks, yc, N_z)
        sink = VolumeSink(str(tmp_path / "vol"))
        sink.write(chunked, layout={"kind": "y_chunk_major", "y_chunks": 2})
        assert sink.layout() == {"kind": "y_chunk_major", "y_chunks": 2}
        np.testing.assert_array_equal(sink.read(), vol)

    def test_unknown_layout_raises(self, tmp_path):
        sink = VolumeSink(str(tmp_path / "vol"))
        sink.write(np.zeros((2, 2, 2, 2), np.float32),
                   layout={"kind": "z_order"})
        with pytest.raises(shard_store.StoreError, match="unknown layout"):
            sink.read()


# ---------------------------------------------------------------------------
# Planner pricing of the incremental schedule
# ---------------------------------------------------------------------------


class TestIncrementalPlanner:
    def test_spec_pins_incremental(self, geo):
        plan = plan_from_spec(
            geo, "auto,schedule=incremental,n_steps=2,impl=factorized")
        assert plan.schedule == "incremental"
        assert plan.n_steps == 2

    def test_time_from_last_delta_rejects_batch_points(self, geo):
        point = point_from_plan(ReconstructionPlan(geometry=geo))
        with pytest.raises(ValueError, match="incremental"):
            time_from_last_delta(geo, point)

    def test_tail_is_a_fraction_of_batch_runtime(self):
        g = default_geometry(256, n_proj=256)
        plan = ReconstructionPlan(geometry=g, schedule="incremental",
                                  n_steps=4)
        point = point_from_plan(plan)
        tail = time_from_last_delta(g, point)
        assert 0 < tail < predict_point(g, point).t_runtime

    def test_footprint_holds_resident_state(self, geo):
        """The session keeps old + new accumulator live across the fold
        (no donation): 2x the fused slab under psum."""
        fused = plan_footprint(
            geo, point_from_plan(ReconstructionPlan(geometry=geo)))
        incr = plan_footprint(geo, point_from_plan(ReconstructionPlan(
            geometry=geo, schedule="incremental", n_steps=2)))
        assert incr.slab == 2 * fused.slab


# ---------------------------------------------------------------------------
# Benchmark JSON persistence (the BENCH_streaming.json trajectory file)
# ---------------------------------------------------------------------------


def test_bench_write_json(tmp_path):
    sys.path.insert(0, os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..")))
    from benchmarks.bench_streaming import write_json
    path = str(tmp_path / "BENCH_streaming.json")
    write_json(path, [("streaming/x/t_last_delta", 12.5, "OK")])
    rows = json.loads(open(path).read())
    assert rows == [{"name": "streaming/x/t_last_delta",
                     "us_per_call": 12.5, "derived": "OK"}]


# ---------------------------------------------------------------------------
# mesh cross-product (subprocess: needs 8 virtual devices)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, numpy as np
from repro.core.distributed import input_sharding
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan
from repro.io.streams import (ProjectionSource, StreamingProjectionWriter,
                              VolumeSink)
from repro.parallel.mesh import make_mesh

results = {}
g = default_geometry(16, n_proj=16)
proj = np.asarray(forward_project(g))
mesh = make_mesh((2, 2), ("data", "model"))
ref = np.asarray(jax.device_get(ReconstructionPlan(geometry=g).build()(
    proj)))
refmax = float(np.max(np.abs(ref)))

def rel(v):
    return float(np.max(np.abs(np.asarray(v) - ref))) / refmax

for red in ("psum", "scatter", "scatter_bf16"):
    plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="incremental",
                              n_steps=2, reduce=red)
    # raw in-order deltas
    s = plan.build_incremental()
    s.update(proj[:8], (0, 8)); s.update(proj[8:], (8, 16))
    v_raw = np.asarray(jax.device_get(s.finalize()))
    results[f"incr/{red}/in_order"] = rel(v_raw)
    # staged path with the fused last-delta epilogue: same bytes
    s2 = plan.build_incremental()
    s2.update(s2.stage(proj[:8], (0, 8)))
    v_staged = np.asarray(jax.device_get(
        s2.update(s2.stage(proj[8:], (8, 16)), finalize=True)))
    results[f"incr/{red}/staged_eq_raw"] = bool(
        np.array_equal(v_raw, v_staged))
    # out-of-order folding: reassociation-level agreement only
    s3 = plan.build_incremental()
    s3.update(proj[8:], (8, 16)); s3.update(proj[:8], (0, 8))
    results[f"incr/{red}/any_order"] = rel(jax.device_get(s3.finalize()))

# full streaming loop on the mesh: scanner writes, session polls off the
# store, finalize streams to the sink
td = tempfile.mkdtemp()
w = StreamingProjectionWriter(os.path.join(td, "proj"),
                              (g.n_proj, g.n_v, g.n_u))
src = ProjectionSource(os.path.join(td, "proj"))
sink = VolumeSink(os.path.join(td, "vol"))
plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="incremental",
                          n_steps=2)
sess = plan.build_incremental(source=src, sink=sink)
w.append(proj[:8], 0)
n1 = sess.poll()
w.append(proj[8:], 8)
n2 = sess.poll()
sess.finalize()
results["stream_loop/polls"] = [n1, n2]
results["stream_loop/sink"] = rel(sink.read())

# chunked+scatter engine -> VolumeSink: the 4-D y_chunk-major layout must
# round-trip through the manifest record back to the canonical volume
sink2 = VolumeSink(os.path.join(td, "vol_chunked"))
plan2 = ReconstructionPlan(geometry=g, mesh=mesh, schedule="chunked",
                           n_steps=2, y_chunks=4, reduce="scatter")
src_all = ProjectionSource.write(os.path.join(td, "proj_all"), proj,
                                 chunks=(4, 1, 1))
plan2.build(source=src_all, sink=sink2)()
results["chunked_sink/layout"] = sink2.layout()
results["chunked_sink/rel"] = rel(sink2.read())

print("RESULTS" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def mesh_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200)
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


BF16_REDUCE_RTOL = 4 * 2.0 ** -8


@pytest.mark.slow
def test_incremental_on_mesh(mesh_results):
    for red in ("psum", "scatter"):
        assert mesh_results[f"incr/{red}/in_order"] < 5e-6
        assert mesh_results[f"incr/{red}/any_order"] < 5e-6
    assert mesh_results["incr/scatter_bf16/in_order"] < BF16_REDUCE_RTOL
    assert mesh_results["incr/scatter_bf16/any_order"] < BF16_REDUCE_RTOL


@pytest.mark.slow
def test_staged_equals_raw_on_mesh(mesh_results):
    """stage+fold must produce the identical bytes the raw update path
    does, for every reduce (same jitted fold, different entry point)."""
    for red in ("psum", "scatter", "scatter_bf16"):
        assert mesh_results[f"incr/{red}/staged_eq_raw"] is True


@pytest.mark.slow
def test_streaming_loop_on_mesh(mesh_results):
    assert mesh_results["stream_loop/polls"] == [1, 1]
    assert mesh_results["stream_loop/sink"] < 5e-6


@pytest.mark.slow
def test_chunked_scatter_sink_layout_on_mesh(mesh_results):
    """ISSUE satellite: the chunked+scatter engine streams its 4-D
    y_chunk-major accumulator into the sink; the manifest record must
    restore the canonical (N_x, N_y, N_z) volume."""
    assert mesh_results["chunked_sink/layout"] == {
        "kind": "y_chunk_major", "y_chunks": 4}
    assert mesh_results["chunked_sink/rel"] < 5e-6
