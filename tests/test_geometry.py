"""Geometry: projection matrices, and the paper's Theorems 1-3."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.geometry import (
    CBCTGeometry, assert_factorizable, default_geometry, projection_matrices,
    project_voxels, source_position,
)


def _geom(n=16, n_proj=8, **kw):
    g = default_geometry(n, n_proj=n_proj)
    return g


class TestProjectionMatrix:
    def test_shapes(self):
        g = _geom()
        pm = projection_matrices(g)
        assert pm.shape == (g.n_proj, 3, 4)
        assert pm.dtype == np.float32

    def test_structural_zeros_theorems_2_3(self):
        """P[0,2] == P[2,2] == 0 exactly (not approximately)."""
        g = _geom(n_proj=32)
        pm = projection_matrices(g)
        assert np.all(pm[:, 0, 2] == 0.0)
        assert np.all(pm[:, 2, 2] == 0.0)
        assert_factorizable(pm)

    def test_assert_factorizable_rejects_general_matrix(self):
        bad = np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32)
        with pytest.raises(ValueError):
            assert_factorizable(bad)

    def test_volume_center_projects_to_detector_center(self):
        g = _geom(n=17, n_proj=12)  # odd n so the center voxel is exact
        pm = projection_matrices(g)
        for s in range(g.n_proj):
            u, v, w = project_voxels(jnp.asarray(pm[s]), g.n_x, g.n_y, g.n_z)
            c = (g.n_x - 1) // 2
            assert abs(float(u[c, c, c]) - (g.n_u - 1) / 2) < 1e-3
            assert abs(float(v[c, c, c]) - (g.n_v - 1) / 2) < 1e-3

    def test_theorem_1_z_symmetry(self):
        g = _geom(n_proj=8)
        pm = projection_matrices(g)
        u, v, w = project_voxels(jnp.asarray(pm[3]), g.n_x, g.n_y, g.n_z)
        # mirrored voxels: same u, v + v~ == N_v - 1
        assert float(jnp.max(jnp.abs(u - u[..., ::-1]))) < 1e-4
        assert float(jnp.max(jnp.abs(v + v[..., ::-1] - (g.n_v - 1)))) < 1e-3

    def test_v_affine_in_k(self):
        g = _geom(n_proj=8)
        pm = projection_matrices(g)
        u, v, w = project_voxels(jnp.asarray(pm[1]), g.n_x, g.n_y, g.n_z)
        dv = v[..., 1:] - v[..., :-1]
        assert float(jnp.max(jnp.abs(dv - dv[..., :1]))) < 1e-3

    def test_w_constant_in_k(self):
        g = _geom(n_proj=8)
        pm = projection_matrices(g)
        _, _, w = project_voxels(jnp.asarray(pm[5]), g.n_x, g.n_y, g.n_z)
        assert float(jnp.max(jnp.abs(w - w[..., :1]))) == 0.0

    @settings(max_examples=20, deadline=None)
    @given(
        beta_idx=st.integers(0, 31),
        d=st.floats(3.0, 8.0),
        mag=st.floats(1.2, 3.0),
    )
    def test_theorems_hold_for_random_geometry(self, beta_idx, d, mag):
        g = CBCTGeometry(
            n_proj=32, n_u=24, n_v=24, d_u=0.2, d_v=0.25,
            d=d, dsd=d * mag, n_x=8, n_y=8, n_z=8,
            d_x=0.1, d_y=0.12, d_z=0.11,
        )
        pm = projection_matrices(g)
        assert_factorizable(pm)
        u, v, w = project_voxels(jnp.asarray(pm[beta_idx]), 8, 8, 8)
        assert float(jnp.max(jnp.abs(u - u[..., :1]))) < 1e-4
        assert float(jnp.max(jnp.abs(w - w[..., :1]))) < 1e-6
        assert float(jnp.max(jnp.abs(v + v[..., ::-1] - (g.n_v - 1)))) < 1e-3

    def test_source_orbit_radius(self):
        g = _geom()
        for beta in [0.0, 1.0, 2.5]:
            s = source_position(g, beta)
            assert abs(np.linalg.norm(s) - g.d) < 1e-9
            assert s[2] == 0.0

    def test_eq3_z_formula(self):
        """z == d + sin(b)(i-cx)Dx - cos(b)(j-cy)Dy (paper Eq. 3)."""
        g = _geom()
        beta = g.angles[3]
        pm = projection_matrices(g)[3].astype(np.float64)
        i, j, k = 5.0, 2.0, 7.0
        z = pm[2] @ np.array([i, j, k, 1.0])
        want = (g.d + np.sin(beta) * (i - (g.n_x - 1) / 2) * g.d_x
                - np.cos(beta) * (j - (g.n_y - 1) / 2) * g.d_y)
        assert abs(z - want) < 1e-5
