"""ReconstructionPlan / staged-engine tests: the schedule x reduce x
precision cross-product against the single-device f32 oracle, centralized
validate() error messages, plan-time kernel block resolution, and the
choose_grid regression (non-power-of-two device counts)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.distributed import IFDKGrid, choose_grid, input_sharding
from repro.core.fdk import reconstruct
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan, plan_from_spec
from repro.core.precision import Precision
from repro.parallel.mesh import make_mesh, single_device_mesh

SCHEDULES = ("fused", "pipelined", "chunked")
REDUCES = ("psum", "scatter")
STORAGES = ("fp32", "bf16", "fp16")


def _plan_kwargs(schedule):
    if schedule == "fused":
        return {}
    if schedule == "pipelined":
        return {"n_steps": 2}
    return {"n_steps": 2, "y_chunks": 4}


def _run_plan(plan, proj):
    if plan.mesh is None:
        out = plan.build()(proj)
    else:
        out = plan.build()(jax.device_put(proj, input_sharding(plan.mesh)))
    out = np.asarray(out)
    g = plan.geometry
    return out.reshape(g.n_x, g.n_y, g.n_z)  # chunked+scatter store layout


@pytest.fixture(scope="module")
def case16():
    g = default_geometry(16, n_proj=8)
    proj = forward_project(g)
    oracle = np.array(reconstruct(g, proj, impl="factorized",
                                  precision="fp32"))
    return g, proj, oracle


def _assert_matches_oracle(out, oracle, storage, label):
    p = Precision(storage)
    scale = float(np.max(np.abs(oracle))) + 1e-12
    rmse = float(np.sqrt(np.mean((out - oracle) ** 2))) / scale
    mx = float(np.max(np.abs(out - oracle))) / scale
    assert rmse < p.rmse_tol(), f"{label}: rmse {rmse:.3e}"
    assert mx < p.max_tol(), f"{label}: max {mx:.3e}"


class TestCrossProduct:
    """Every (schedule, reduce, precision) plan point on a 1x1x1 mesh must
    match the single-device f32 oracle within the precision policy's
    tolerance — including combinations the legacy builders never offered."""

    @pytest.mark.parametrize("storage", STORAGES)
    @pytest.mark.parametrize("reduce", REDUCES)
    @pytest.mark.parametrize("schedule", SCHEDULES)
    def test_mesh_1x1x1(self, case16, schedule, reduce, storage):
        g, proj, oracle = case16
        mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
        plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule=schedule,
                                  reduce=reduce, precision=storage,
                                  **_plan_kwargs(schedule))
        out = _run_plan(plan, proj)
        _assert_matches_oracle(out, oracle, storage,
                               f"{schedule}/{reduce}/{storage}")

    @pytest.mark.parametrize("schedule", SCHEDULES)
    def test_single_device_no_mesh(self, case16, schedule):
        """mesh=None runs the same staged engine without shard_map —
        pipelined/chunked single-device did not exist before the plan
        layer."""
        g, proj, oracle = case16
        plan = ReconstructionPlan(geometry=g, schedule=schedule,
                                  reduce="psum", **_plan_kwargs(schedule))
        out = _run_plan(plan, proj)
        _assert_matches_oracle(out, oracle, "fp32", f"{schedule}/no-mesh")

    def test_chunked_psum_replicated_slab(self, case16):
        """Previously-impossible combination #1: the chunked schedule with a
        replicated (psum) output — legacy make_chunked_fdk hardwired
        psum_scatter. Output is the canonical 3-D volume."""
        g, proj, oracle = case16
        mesh = single_device_mesh()  # ("data", "model"), no pod axis
        plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="chunked",
                                  n_steps=2, y_chunks=4, reduce="psum")
        out = plan.build()(jax.device_put(proj, input_sharding(mesh)))
        assert out.shape == (g.n_x, g.n_y, g.n_z)
        _assert_matches_oracle(np.asarray(out), oracle, "fp32",
                               "chunked/psum")

    def test_pipelined_single_device(self, case16):
        """Previously-impossible combination #2: the pipelined (Fig. 4
        overlap) schedule without any mesh."""
        g, proj, oracle = case16
        plan = ReconstructionPlan(geometry=g, schedule="pipelined",
                                  n_steps=4)
        out = np.asarray(plan.build()(proj))
        _assert_matches_oracle(out, oracle, "fp32", "pipelined/no-mesh")


class TestStreamCodecPlans:
    """ISSUE 5: the fp8_e4m3 projection codec and the scatter_bf16
    compensated half-width reduce as plan points of the staged engine."""

    # Documented scatter_bf16 tolerance vs the f32 psum reduce: one bf16
    # rounding per rank on the reduced slab — relative error bounded by a
    # small multiple of bf16 eps (2^-8). See DESIGN.md (codec layer).
    BF16_REDUCE_RTOL = 4 * 2.0 ** -8

    def _mesh(self):
        return make_mesh((1, 1, 1), ("pod", "data", "model"))

    @pytest.mark.parametrize("schedule", SCHEDULES)
    def test_scatter_bf16_matches_f32_psum(self, case16, schedule):
        """ISSUE 5 acceptance: scatter_bf16 matches the f32 psum reduce
        within the documented tolerance (at half the reduce wire bytes —
        priced in planner/cost.py, accounted in tests/test_planner.py)."""
        g, proj, _ = case16
        mesh = self._mesh()
        kw = _plan_kwargs(schedule)
        f32 = _run_plan(ReconstructionPlan(geometry=g, mesh=mesh,
                                           schedule=schedule, reduce="psum",
                                           **kw), proj)
        out = _run_plan(ReconstructionPlan(geometry=g, mesh=mesh,
                                           schedule=schedule,
                                           reduce="scatter_bf16", **kw),
                        proj)
        scale = float(np.max(np.abs(f32))) + 1e-12
        mx = float(np.max(np.abs(out - f32))) / scale
        assert mx < self.BF16_REDUCE_RTOL, f"{schedule}: {mx:.3e}"

    def test_chunked_error_feedback_beats_naive_requantize(self, case16):
        """The f32 error-feedback carry keeps the chunked multi-round
        reduce at least as accurate as quantizing a single fused round —
        without it, n_steps independent roundings would accumulate."""
        g, proj, oracle = case16
        mesh = self._mesh()
        chunked = _run_plan(
            ReconstructionPlan(geometry=g, mesh=mesh, schedule="chunked",
                               n_steps=2, y_chunks=4,
                               reduce="scatter_bf16"), proj)
        scale = float(np.max(np.abs(oracle))) + 1e-12
        rmse = float(np.sqrt(np.mean((chunked - oracle) ** 2))) / scale
        # 4 quantized rounds with feedback must stay within the ONE-round
        # error bound (no accumulation across the n_steps micro-batches).
        assert rmse < self.BF16_REDUCE_RTOL, f"rmse {rmse:.3e}"

    @pytest.mark.parametrize("schedule", SCHEDULES)
    def test_fp8_plan_matches_oracle(self, case16, schedule):
        g, proj, oracle = case16
        out = _run_plan(
            ReconstructionPlan(geometry=g, mesh=self._mesh(),
                               schedule=schedule, precision="fp8_e4m3",
                               **_plan_kwargs(schedule)), proj)
        _assert_matches_oracle(out, oracle, "fp8_e4m3",
                               f"{schedule}/fp8_e4m3")

    def test_fp8_with_kernel_impl(self, case16):
        """The Pallas kernel consumes the fp8 wire stream + scale sidecar
        (dequantize at the tap) and agrees with the factorized engine."""
        g, proj, oracle = case16
        fac = _run_plan(ReconstructionPlan(geometry=g, mesh=self._mesh(),
                                           precision="fp8_e4m3"), proj)
        ker = _run_plan(ReconstructionPlan(geometry=g, mesh=self._mesh(),
                                           precision="fp8_e4m3",
                                           impl="kernel"), proj)
        np.testing.assert_allclose(ker, fac, rtol=1e-5, atol=1e-6)
        _assert_matches_oracle(ker, oracle, "fp8_e4m3", "kernel/fp8")

    def test_spec_tokens(self, case16):
        g, _, _ = case16
        p = plan_from_spec(g, "precision=fp8_e4m3,reduce=scatter_bf16")
        assert p.precision == "fp8_e4m3" and p.reduce == "scatter_bf16"
        assert p.resolved_precision().storage == "fp8_e4m3"

    def test_scatter_bf16_needs_data_axis(self, case16):
        g, _, _ = case16
        with pytest.raises(ValueError, match="scatter_bf16.*'data'"):
            ReconstructionPlan(geometry=g, reduce="scatter_bf16").validate()


class TestPlanResolution:
    def test_build_is_cached_per_plan(self, case16):
        g, _, _ = case16
        a = ReconstructionPlan(geometry=g).build()
        b = ReconstructionPlan(geometry=g).build()
        assert a is b
        c = ReconstructionPlan(geometry=g, precision="bf16").build()
        assert c is not a

    def test_kernel_blocks_resolved_at_plan_time(self, case16):
        """impl='kernel' plans resolve (bi, bj, bs) once via the autotuner;
        explicit blocks are honored verbatim and the math is unchanged."""
        g, proj, oracle = case16
        tuned = ReconstructionPlan(geometry=g, impl="kernel")
        bi, bj, bs = tuned.resolved_blocks()
        assert g.n_x % bi == 0 and g.n_y % bj == 0
        pinned = ReconstructionPlan(geometry=g, impl="kernel",
                                    blocks=(4, 4, 4))
        assert pinned.resolved_blocks() == (4, 4, 4)
        out = np.asarray(pinned.build()(proj))
        _assert_matches_oracle(out, oracle, "fp32", "kernel/pinned-blocks")

    def test_non_kernel_has_no_blocks(self, case16):
        g, _, _ = case16
        assert ReconstructionPlan(geometry=g).resolved_blocks() is None

    def test_describe(self, case16):
        g, _, _ = case16
        d = ReconstructionPlan(geometry=g, schedule="pipelined", n_steps=2,
                               precision=None).describe()
        assert d["schedule"] == "pipelined"
        assert d["grid"] == (1, 1)
        assert d["precision"] in ("bf16", "fp16")  # backend default

    def test_plan_from_spec(self, case16):
        g, _, _ = case16
        p = plan_from_spec(
            g, "schedule=chunked,n_steps=2,y_chunks=4,precision=bf16,"
               "impl=factorized,reduce=psum")
        assert (p.schedule, p.n_steps, p.y_chunks) == ("chunked", 2, 4)
        assert p.precision == "bf16" and p.reduce == "psum"
        with pytest.raises(ValueError, match="unknown plan spec key"):
            plan_from_spec(g, "bogus=1")
        with pytest.raises(ValueError, match="key=value"):
            plan_from_spec(g, "pipelined")


class TestValidate:
    """Every divisibility/compatibility failure raises a clear message from
    the one centralized validate()."""

    def _plan(self, g=None, **kw):
        return ReconstructionPlan(geometry=g or default_geometry(16,
                                                                 n_proj=8),
                                  **kw)

    def test_unknown_impl(self):
        with pytest.raises(ValueError, match="unknown back-projection impl"):
            self._plan(impl="cuda").validate()

    def test_unknown_window(self):
        with pytest.raises(ValueError, match="unknown window"):
            self._plan(window="kaiser").validate()

    def test_unknown_schedule(self):
        with pytest.raises(ValueError, match="unknown schedule"):
            self._plan(schedule="eager").validate()

    def test_unknown_reduce(self):
        with pytest.raises(ValueError, match="unknown reduce mode"):
            self._plan(reduce="allreduce").validate()

    def test_unknown_precision(self):
        with pytest.raises(ValueError, match="unknown storage precision"):
            self._plan(precision="int8").validate()

    def test_fused_rejects_micro_batching(self):
        with pytest.raises(ValueError, match="fused schedule has no"):
            self._plan(n_steps=2).validate()

    def test_n_steps_must_divide(self):
        with pytest.raises(ValueError, match="n_steps=3 micro-batches"):
            self._plan(schedule="pipelined", n_steps=3).validate()

    def test_n_steps_positive(self):
        with pytest.raises(ValueError, match="must be >= 1"):
            self._plan(schedule="pipelined", n_steps=0).validate()

    def test_chunked_requires_y_chunks(self):
        with pytest.raises(ValueError, match="requires y_chunks"):
            self._plan(schedule="chunked", n_steps=2).validate()

    def test_y_chunks_must_divide(self):
        with pytest.raises(ValueError, match="y_chunks=5"):
            self._plan(schedule="chunked", n_steps=2, y_chunks=5).validate()

    def test_y_chunks_only_for_chunked(self):
        with pytest.raises(ValueError, match="only applies to the chunked"):
            self._plan(schedule="pipelined", n_steps=2,
                       y_chunks=4).validate()

    def test_scatter_needs_data_axis(self):
        with pytest.raises(ValueError, match="needs a mesh with a 'data'"):
            self._plan(reduce="scatter").validate()

    def test_blocks_only_for_kernel(self):
        with pytest.raises(ValueError, match="only applies to impl='kernel'"):
            self._plan(blocks=(4, 4, 4)).validate()

    def test_blocks_must_tile_call_shape(self):
        with pytest.raises(ValueError, match="must tile the per-call"):
            self._plan(impl="kernel", blocks=(3, 4, 4)).validate()
        with pytest.raises(ValueError, match="must be positive"):
            self._plan(impl="kernel", blocks=(0, 4, 4)).validate()

    def test_kernel_needs_even_nz(self):
        import dataclasses
        g = dataclasses.replace(default_geometry(16, n_proj=8), n_z=15)
        with pytest.raises(ValueError, match="even N_z"):
            self._plan(g=g, impl="kernel").validate()


class TestChooseGrid:
    """Regression: the old `while n_devices % r: r *= 2` never terminated
    for non-power-of-two device counts once the memory bound forced R
    beyond the device count's largest power-of-two factor."""

    def test_non_power_of_two_raises(self):
        g = default_geometry(64)
        # 4*64^3 B volume with 256 KiB sub-volumes -> R=4; 4 does not
        # divide 6 (and no larger power of two can) -> must raise, not hang
        with pytest.raises(ValueError, match="does not divide n_devices=6"):
            choose_grid(g, 6, sub_vol_bytes=256 * 1024)

    def test_non_power_of_two_ok_when_r_divides(self):
        g = default_geometry(64)
        assert choose_grid(g, 6, sub_vol_bytes=512 * 1024) == IFDKGrid(r=2,
                                                                       c=3)

    def test_paper_grid_rule_unchanged(self):
        # paper §5.3: R=32 for 4096^3 with 8 GB sub-volumes on 16 GB GPUs
        g = default_geometry(4096, n_proj=4096)
        assert choose_grid(g, 256) == IFDKGrid(r=32, c=8)

    def test_too_few_devices_still_raises(self):
        g = default_geometry(64)
        with pytest.raises(ValueError, match="only 2 devices"):
            choose_grid(g, 2, sub_vol_bytes=256 * 1024)


# ---------------------------------------------------------------------------
# 2x2x2 mesh cross-product (subprocess: needs 8 virtual devices)
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, numpy as np
from repro.core.fdk import reconstruct
from repro.core.distributed import input_sharding
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.plan import ReconstructionPlan
from repro.parallel.mesh import make_mesh

results = {}
g = default_geometry(16, n_proj=32)
proj = forward_project(g)
ref = np.array(reconstruct(g, proj, impl="factorized"))
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

def kwargs(s):
    if s == "fused": return {}
    if s == "pipelined": return {"n_steps": 2}
    return {"n_steps": 2, "y_chunks": 4}

for sched in ("fused", "pipelined", "chunked"):
    for red in ("psum", "scatter"):
        plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule=sched,
                                  reduce=red, **kwargs(sched))
        out = np.asarray(plan.build()(jax.device_put(proj,
                                                     input_sharding(mesh))))
        out = out.reshape(g.n_x, g.n_y, g.n_z)
        results[f"{sched}/{red}"] = float(np.max(np.abs(out - ref)))

# chunked+psum at bf16: previously-impossible combo under the precision
# policy, against the bf16 single-device reconstruction
ref16 = np.array(reconstruct(g, proj, impl="factorized", precision="bf16"))
plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="chunked",
                          n_steps=2, y_chunks=4, reduce="psum",
                          precision="bf16")
out = np.asarray(plan.build()(jax.device_put(proj, input_sharding(mesh))))
results["chunked/psum/bf16_vs_bf16single"] = float(
    np.max(np.abs(out.reshape(g.n_x, g.n_y, g.n_z) - ref16)))

# ISSUE 5: stream codecs on a real multi-rank grid (relative errors).
refmax = float(np.max(np.abs(ref)))
for sched, red, prec in [("fused", "scatter_bf16", "fp32"),
                         ("chunked", "scatter_bf16", "fp32"),
                         ("fused", "psum", "fp8_e4m3"),
                         ("pipelined", "scatter", "fp8_e4m3")]:
    plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule=sched,
                              reduce=red, precision=prec, **kwargs(sched))
    out = np.asarray(plan.build()(jax.device_put(proj,
                                                 input_sharding(mesh))))
    out = out.reshape(g.n_x, g.n_y, g.n_z)
    results[f"codec/{sched}/{red}/{prec}"] = float(
        np.max(np.abs(out - ref))) / refmax

# fp8 on the mesh vs the fp8 single-device engine: the codec quantizes
# per projection (identical bytes either way), so the only deviation is
# f32 reassociation in the distributed reduce
ref8 = np.array(ReconstructionPlan(geometry=g,
                                   precision="fp8_e4m3").build()(proj))
plan = ReconstructionPlan(geometry=g, mesh=mesh, precision="fp8_e4m3")
out = np.asarray(plan.build()(jax.device_put(proj, input_sharding(mesh))))
results["codec/fused/fp8_vs_fp8single"] = float(
    np.max(np.abs(out - ref8))) / (float(np.max(np.abs(ref8))) + 1e-12)

# validate() failures that need a real multi-rank grid
try:
    ReconstructionPlan(geometry=default_geometry(16, n_proj=30),
                       mesh=mesh).validate()
    results["err/np_ranks"] = ""
except ValueError as e:
    results["err/np_ranks"] = str(e)
try:
    ReconstructionPlan(geometry=default_geometry(17, n_proj=32),
                       mesh=mesh).validate()
    results["err/nx_slabs"] = ""
except ValueError as e:
    results["err/nx_slabs"] = str(e)

print("RESULTS" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def mesh222_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


@pytest.mark.slow
def test_cross_product_on_2x2x2_mesh(mesh222_results):
    for sched in ("fused", "pipelined", "chunked"):
        for red in ("psum", "scatter"):
            err = mesh222_results[f"{sched}/{red}"]
            assert err < 5e-6, f"{sched}/{red}: {err}"


@pytest.mark.slow
def test_chunked_psum_bf16_on_mesh(mesh222_results):
    assert mesh222_results["chunked/psum/bf16_vs_bf16single"] < 5e-6


@pytest.mark.slow
def test_validate_messages_on_mesh(mesh222_results):
    assert "must divide over the 8 ranks" in mesh222_results["err/np_ranks"]
    assert "R=2 volume slabs" in mesh222_results["err/nx_slabs"]


@pytest.mark.slow
def test_scatter_bf16_on_mesh(mesh222_results):
    """Half-width reduce on a real 2-rank data axis: within the documented
    bf16 tolerance of the f32 reference (see TestStreamCodecPlans)."""
    tol = TestStreamCodecPlans.BF16_REDUCE_RTOL
    assert mesh222_results["codec/fused/scatter_bf16/fp32"] < tol
    assert mesh222_results["codec/chunked/scatter_bf16/fp32"] < tol


@pytest.mark.slow
def test_fp8_on_mesh(mesh222_results):
    """fp8 stream + sidecar through real collectives: fp8-tolerance vs the
    f32 reference, and bit-identical to the single-device fp8 engine."""
    tol = Precision("fp8_e4m3").max_tol()
    assert mesh222_results["codec/fused/psum/fp8_e4m3"] < tol
    assert mesh222_results["codec/pipelined/scatter/fp8_e4m3"] < tol
    # per-projection quantization is identical on any grid — only f32
    # reassociation in the distributed reduce separates the two engines
    assert mesh222_results["codec/fused/fp8_vs_fp8single"] < 1e-5
