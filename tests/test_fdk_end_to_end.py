"""End-to-end FDK: Shepp-Logan phantom reconstruction (paper Fig. 7, §5.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fdk import fdk_scale, gups, reconstruct
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project, shepp_logan_volume


@pytest.fixture(scope="module")
def small_case():
    # 24^3/36 (was 32^3/48): the smallest geometry where all three impls
    # and the windows still exercise distinct code paths — part of the
    # fast-tier diet (DESIGN.md §Test tiers).
    g = default_geometry(24, n_proj=36)
    return g, forward_project(g), shepp_logan_volume(g)


class TestReconstruction:
    def test_impl_equivalence(self, small_case):
        g, proj, _ = small_case
        ref = reconstruct(g, proj, impl="reference")
        fac = reconstruct(g, proj, impl="factorized")
        ker = reconstruct(g, proj, impl="kernel")
        scale = float(jnp.max(jnp.abs(ref)))
        assert float(jnp.max(jnp.abs(fac - ref))) / scale < 1e-4
        assert float(jnp.max(jnp.abs(ker - ref))) / scale < 1e-4

    def test_phantom_recovery(self, small_case):
        """Interior RMSE < 0.17 at 24^3/36 views (measured 0.159; the old
        0.15 bound was calibrated at 32^3/48 — fewer views reconstruct a
        bit noisier); mean density of the big flat region within 0.05 of
        truth."""
        g, proj, ph = small_case
        vol = reconstruct(g, proj, impl="factorized")
        m = g.n_x // 5
        interior = (slice(m, g.n_x - m),) * 3
        rmse = float(jnp.sqrt(jnp.mean((vol[interior] - ph[interior]) ** 2)))
        assert rmse < 0.17
        flat = (ph[interior] > 0.15) & (ph[interior] < 0.25)
        err = float(jnp.abs(jnp.mean(vol[interior][flat])
                            - jnp.mean(ph[interior][flat])))
        assert err < 0.05

    def test_resolution_convergence(self):
        """RMSE decreases with resolution/views (consistency of the method).
        The 24^3/36 endpoint shares the module fixture's plan, so only the
        12^3 point compiles fresh."""
        rmses = []
        for n, npj in [(12, 18), (24, 36)]:
            g = default_geometry(n, n_proj=npj)
            vol = reconstruct(g, forward_project(g))
            ph = shepp_logan_volume(g)
            m = n // 5
            interior = (slice(m, n - m),) * 3
            rmses.append(
                float(jnp.sqrt(jnp.mean((vol[interior] - ph[interior]) ** 2)))
            )
        assert rmses[1] < rmses[0]

    @pytest.mark.parametrize("window", ["ramlak", "shepp-logan", "hann"])
    def test_windows_reconstruct(self, small_case, window):
        g, proj, ph = small_case
        vol = reconstruct(g, proj, window=window)
        assert bool(jnp.all(jnp.isfinite(vol)))
        # all windows must land in a sane range
        assert -0.6 < float(vol.min()) and float(vol.max()) < 1.7

    def test_fdk_scale_full_scan(self):
        g = default_geometry(16, n_proj=10)
        assert fdk_scale(g) == pytest.approx(
            0.5 * g.d * g.d * 2 * np.pi / g.n_proj
        )

    def test_gups_metric(self):
        g = default_geometry(16, n_proj=10)
        # N_x*N_y*N_z*N_p / (T * 2^30), paper §2.3
        assert gups(g, 1.0) == pytest.approx(16**3 * 10 / 2**30)
