"""MoE layer: routing, capacity, shared experts, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import MoEConfig, ModelConfig, SubLayer
from repro.models.layers import init_tree
from repro.models.moe import capacity, moe, moe_defs

KEY = jax.random.PRNGKey(0)


def _cfg(num_experts=4, top_k=2, cf=8.0, shared=0):
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        pattern=(SubLayer(kind="attn", ffn="moe"),),
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff_expert=64,
                      capacity_factor=cf, num_shared_experts=shared,
                      d_ff_shared=64),
        dtype="float32",
    )


def _params(cfg):
    return init_tree(KEY, moe_defs(cfg))


class TestMoE:
    def test_output_shape_and_finite(self):
        cfg = _cfg()
        p = _params(cfg)
        x = jax.random.normal(KEY, (2, 16, 32), jnp.float32)
        out, aux = moe(p, cfg, x)
        assert out.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(out)))
        assert float(aux) > 0

    def test_capacity_formula(self):
        cfg = _cfg(num_experts=8, top_k=2, cf=1.25)
        # ceil(64 * 2 / 8 * 1.25) = 20
        assert capacity(cfg, 64) == 20

    @pytest.mark.slow
    def test_high_capacity_no_drops_matches_dense_mixture(self):
        """With capacity covering everything, MoE == explicit per-token
        mixture of expert MLPs."""
        cfg = _cfg(num_experts=4, top_k=2, cf=16.0)
        p = _params(cfg)
        x = jax.random.normal(KEY, (1, 8, 32), jnp.float32)
        out, _ = moe(p, cfg, x)

        # explicit dense computation
        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        gv, gi = jax.lax.top_k(probs, 2)
        gv = gv / gv.sum(-1, keepdims=True)
        def expert(e, v):
            h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
            return h @ p["w_down"][e]
        want = jnp.zeros_like(x)
        for b in range(1):
            for s in range(8):
                acc = 0
                for j in range(2):
                    acc += gv[b, s, j] * expert(int(gi[b, s, j]), x[b, s])
                want = want.at[b, s].set(acc)
        np.testing.assert_allclose(np.array(out), np.array(want),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.slow
    def test_capacity_drops_tokens(self):
        """Tiny capacity must drop overflow tokens (outputs differ from the
        undropped computation) without NaNs."""
        lo = _cfg(cf=0.25)
        hi = _cfg(cf=16.0)
        p = _params(lo)
        x = jax.random.normal(KEY, (1, 32, 32), jnp.float32)
        out_lo, _ = moe(p, lo, x)
        out_hi, _ = moe(p, hi, x)
        assert bool(jnp.all(jnp.isfinite(out_lo)))
        assert float(jnp.max(jnp.abs(out_lo - out_hi))) > 1e-6

    def test_shared_experts_always_contribute(self):
        cfg = _cfg(shared=2)
        p = _params(cfg)
        x = jax.random.normal(KEY, (1, 8, 32), jnp.float32)
        out_with, _ = moe(p, cfg, x)
        # zero the shared experts -> output must change
        p2 = dict(p)
        p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
        out_without, _ = moe(p2, cfg, x)
        assert float(jnp.max(jnp.abs(out_with - out_without))) > 1e-6

    def test_aux_loss_is_one_for_uniform_routing(self):
        """Switch aux loss == weight when routing is perfectly uniform."""
        cfg = _cfg(num_experts=4, top_k=1)
        p = _params(cfg)
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"])  # uniform probs
        x = jax.random.normal(KEY, (1, 64, 32), jnp.float32)
        _, aux = moe(p, cfg, x)
        # frac depends on top_k tie-breaking; prob term is exactly 1/E each
        assert float(aux) == pytest.approx(
            cfg.moe.router_aux_weight, rel=0.5
        )

    def test_batch_rows_independent(self):
        """Dispatch groups are per batch row: row 0's result can't depend on
        row 1's tokens (locality that keeps the cumsum shard-local)."""
        cfg = _cfg(cf=1.0)
        p = _params(cfg)
        x = jax.random.normal(KEY, (2, 16, 32), jnp.float32)
        out1, _ = moe(p, cfg, x)
        x2 = x.at[1].set(jax.random.normal(jax.random.PRNGKey(7), (16, 32)))
        out2, _ = moe(p, cfg, x2)
        np.testing.assert_allclose(np.array(out1[0]), np.array(out2[0]),
                                   atol=1e-6)
