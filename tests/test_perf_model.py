"""iFDK performance model (paper Eqs. 8-19, Table 5, Fig. 5)."""
import pytest

from repro.core.distributed import IFDKGrid
from repro.core.geometry import paper_geometry as paper_problem
from repro.core.perf_model import (
    ABCI, TPU_V5E, MachineSpec, SystemConstants, gups_end_to_end, predict,
)


class TestPerfModel:
    def test_compute_shrinks_with_devices(self):
        """Strong scaling: T_compute inversely proportional to C (paper
        §4.2.3 conclusion I)."""
        g = paper_problem()
        t = [predict(g, IFDKGrid(r=32, c=c), ABCI).t_compute
             for c in (1, 2, 4, 8)]
        assert t[0] > t[1] > t[2] > t[3]
        assert t[0] / t[3] == pytest.approx(8.0, rel=0.35)

    def test_post_time_constant_in_c(self):
        g = paper_problem()
        a = predict(g, IFDKGrid(r=32, c=2), ABCI)
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert a.t_post == pytest.approx(b.t_post, rel=1e-6)

    def test_reduce_vanishes_when_c_is_1(self):
        g = paper_problem()
        assert predict(g, IFDKGrid(r=32, c=1), ABCI).t_reduce == 0.0

    def test_paper_magnitudes_4k_256gpus(self):
        """Paper Fig. 5a / §5.3.3: 4K problem, 256 GPUs (R=32, C=8):
        T_store ~ 9 s, T_D2H ~ 2.6 s, runtime tens of seconds."""
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert b.t_store == pytest.approx(9.0, rel=0.1)
        # paper quotes ~2.6 s; Eq. 14 with their own constants gives ~1.4 s
        # (their text assumes switch contention) — accept the bracket.
        assert 1.2 < b.t_d2h < 3.0
        assert 10.0 < b.t_runtime < 60.0

    def test_paper_table5_compute_breakdown_256(self):
        """Table 5 row (4096^3, 256 GPUs): T_bp ~ 7.0s, T_compute ~ 10.2s.
        The model should land within ~50% (it is a peak projection)."""
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert b.t_bp == pytest.approx(7.0, rel=0.5)
        assert b.t_compute == pytest.approx(10.2, rel=0.5)

    def test_delta_overlap_factor_exceeds_one(self):
        """Table 5: delta > 1 (pipelining wins) for all reported rows."""
        g = paper_problem()
        for c in (2, 4, 8):
            assert predict(g, IFDKGrid(r=32, c=c), ABCI).delta > 1.0

    def test_gups_increases_with_devices(self):
        g = paper_problem()
        g1 = gups_end_to_end(g, predict(g, IFDKGrid(r=32, c=2), ABCI))
        g2 = gups_end_to_end(g, predict(g, IFDKGrid(r=32, c=8), ABCI))
        assert g2 > g1

    def test_tpu_constants_give_finite_projection(self):
        g = paper_problem()
        b = predict(g, IFDKGrid(r=16, c=16), TPU_V5E)
        assert 0 < b.t_runtime < 120


class TestMonotonicity:
    """Structural properties any constant refresh must preserve."""

    def test_more_ranks_never_increases_t_compute(self):
        """Growing the grid in either direction (more columns OR more rows)
        never makes T_compute worse — Eq. 17 terms are each non-increasing
        in R and C (T_load is constant; the rest split further)."""
        g = paper_problem()
        for sys in (ABCI, TPU_V5E):
            for r in (8, 16, 32):
                seq = [predict(g, IFDKGrid(r=r, c=c), sys).t_compute
                       for c in (1, 2, 4, 8, 16)]
                assert all(a >= b for a, b in zip(seq, seq[1:])), (r, seq)
            for c in (2, 8):
                seq = [predict(g, IFDKGrid(r=r, c=c), sys).t_compute
                       for r in (4, 8, 16, 32, 64)]
                assert all(a >= b for a, b in zip(seq, seq[1:])), (c, seq)

    def test_halving_storage_never_increases_t_allgather(self):
        """The precision policy's promise: narrower storage can only shrink
        the projection-stream terms (AllGather, load, H2D)."""
        g = paper_problem()
        grid = IFDKGrid(r=32, c=8)
        for sys in (ABCI, TPU_V5E):
            wide = predict(g, grid, sys, storage_bytes=4.0)
            half = predict(g, grid, sys, storage_bytes=2.0)
            assert half.t_allgather <= wide.t_allgather
            assert half.t_load <= wide.t_load
            assert half.t_h2d <= wide.t_h2d
            assert half.t_allgather == pytest.approx(wide.t_allgather / 2)

    def test_storage_bytes_default_matches_f32(self):
        g = paper_problem()
        grid = IFDKGrid(r=32, c=8)
        assert predict(g, grid, ABCI) == predict(g, grid, ABCI,
                                                 storage_bytes=4.0)


class TestIOTerms:
    """T_read/T_write: the planner-visible I/O terms (Eq. 8/16) and the PFS
    bandwidth knobs (`MachineSpec.with_pfs`) they respond to."""

    def test_machinespec_is_the_old_systemconstants(self):
        assert SystemConstants is MachineSpec
        assert isinstance(ABCI, MachineSpec)

    def test_read_write_alias_the_eq8_eq16_terms(self):
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert b.t_read == b.t_load
        assert b.t_write == b.t_store
        assert b.t_io == pytest.approx(b.t_read + b.t_write)

    def test_with_pfs_only_touches_io(self):
        """Monotonicity-suite anchor: throttling the PFS must move ONLY the
        I/O terms, and move them inversely to bandwidth."""
        g = paper_problem()
        grid = IFDKGrid(r=32, c=8)
        base = predict(g, grid, ABCI)
        prev_read, prev_write = base.t_read, base.t_write
        for f in (2.0, 8.0, 64.0):
            b = predict(g, grid, ABCI.with_pfs(read=ABCI.bw_load / f,
                                               write=ABCI.bw_store / f))
            assert b.t_read == pytest.approx(base.t_read * f)
            assert b.t_write == pytest.approx(base.t_write * f)
            assert b.t_read > prev_read and b.t_write > prev_write
            assert b.t_runtime > base.t_runtime
            # the non-I/O terms are untouched
            assert b.t_flt == base.t_flt
            assert b.t_allgather == base.t_allgather
            assert b.t_bp == base.t_bp
            assert b.t_reduce == base.t_reduce
            prev_read, prev_write = b.t_read, b.t_write

    def test_rank_io_cap_binds_few_ranks_not_many(self):
        """Per-rank PFS links: few concurrent ranks are link-bound, many
        saturate the filesystem aggregate (the slice-per-rank store's
        scaling argument)."""
        sys = ABCI.with_pfs(rank_io=1e9)
        # few readers: capped below aggregate
        assert sys.agg_read_bw(4) == pytest.approx(4e9)
        # many readers: the aggregate wins
        assert sys.agg_read_bw(256) == pytest.approx(ABCI.bw_load)
        assert sys.agg_write_bw(8) == pytest.approx(8e9)
        assert sys.agg_write_bw(256) == pytest.approx(ABCI.bw_store)

    def test_rank_io_cap_preserves_rank_monotonicity(self):
        """More ranks never increases T_compute, capped or not (the
        monotonicity property the planner's ranking rests on)."""
        g = paper_problem()
        sys = ABCI.with_pfs(rank_io=2e9)
        for r in (8, 32):
            seq = [predict(g, IFDKGrid(r=r, c=c), sys).t_compute
                   for c in (1, 2, 4, 8, 16)]
            assert all(x >= y for x, y in zip(seq, seq[1:])), (r, seq)

    def test_uncapped_rank_io_matches_paper_model(self):
        g = paper_problem()
        grid = IFDKGrid(r=32, c=8)
        assert predict(g, grid, ABCI.with_pfs(rank_io=1e30)) == \
            predict(g, grid, ABCI)


class TestPinnedPaperProjection:
    """Pinned ABCI-constants regression: the 4K / 2048-GPU deployment the
    paper headlines (§5.3: 4096^3 from 4096 projections "within 30 s").
    With R=32, C=64 the model is load-bound on T_compute and lands at
    ~15.3 s end-to-end — pinned here so constant drift is caught."""

    def test_4k_2048gpus_breakdown(self):
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=64), ABCI)
        assert b.t_compute == pytest.approx(b.t_load)  # load-bound at C=64
        assert b.t_load == pytest.approx(1.374, rel=0.01)
        assert b.t_bp == pytest.approx(0.820, rel=0.01)
        assert b.t_runtime == pytest.approx(15.33, rel=0.01)
        assert b.t_runtime < 30.0  # the paper's headline claim
        assert gups_end_to_end(g, b) == pytest.approx(17100, rel=0.01)
