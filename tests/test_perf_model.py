"""iFDK performance model (paper Eqs. 8-19, Table 5, Fig. 5)."""
import pytest

from repro.core.distributed import IFDKGrid
from repro.core.geometry import CBCTGeometry
from repro.core.perf_model import ABCI, TPU_V5E, gups_end_to_end, predict


def paper_problem(n_out=4096):
    return CBCTGeometry(
        n_proj=4096, n_u=2048, n_v=2048, d_u=0.002, d_v=0.002,
        d=4.0, dsd=8.0, n_x=n_out, n_y=n_out, n_z=n_out,
        d_x=0.001, d_y=0.001, d_z=0.001,
    )


class TestPerfModel:
    def test_compute_shrinks_with_devices(self):
        """Strong scaling: T_compute inversely proportional to C (paper
        §4.2.3 conclusion I)."""
        g = paper_problem()
        t = [predict(g, IFDKGrid(r=32, c=c), ABCI).t_compute
             for c in (1, 2, 4, 8)]
        assert t[0] > t[1] > t[2] > t[3]
        assert t[0] / t[3] == pytest.approx(8.0, rel=0.35)

    def test_post_time_constant_in_c(self):
        g = paper_problem()
        a = predict(g, IFDKGrid(r=32, c=2), ABCI)
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert a.t_post == pytest.approx(b.t_post, rel=1e-6)

    def test_reduce_vanishes_when_c_is_1(self):
        g = paper_problem()
        assert predict(g, IFDKGrid(r=32, c=1), ABCI).t_reduce == 0.0

    def test_paper_magnitudes_4k_256gpus(self):
        """Paper Fig. 5a / §5.3.3: 4K problem, 256 GPUs (R=32, C=8):
        T_store ~ 9 s, T_D2H ~ 2.6 s, runtime tens of seconds."""
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert b.t_store == pytest.approx(9.0, rel=0.1)
        # paper quotes ~2.6 s; Eq. 14 with their own constants gives ~1.4 s
        # (their text assumes switch contention) — accept the bracket.
        assert 1.2 < b.t_d2h < 3.0
        assert 10.0 < b.t_runtime < 60.0

    def test_paper_table5_compute_breakdown_256(self):
        """Table 5 row (4096^3, 256 GPUs): T_bp ~ 7.0s, T_compute ~ 10.2s.
        The model should land within ~50% (it is a peak projection)."""
        g = paper_problem()
        b = predict(g, IFDKGrid(r=32, c=8), ABCI)
        assert b.t_bp == pytest.approx(7.0, rel=0.5)
        assert b.t_compute == pytest.approx(10.2, rel=0.5)

    def test_delta_overlap_factor_exceeds_one(self):
        """Table 5: delta > 1 (pipelining wins) for all reported rows."""
        g = paper_problem()
        for c in (2, 4, 8):
            assert predict(g, IFDKGrid(r=32, c=c), ABCI).delta > 1.0

    def test_gups_increases_with_devices(self):
        g = paper_problem()
        g1 = gups_end_to_end(g, predict(g, IFDKGrid(r=32, c=2), ABCI))
        g2 = gups_end_to_end(g, predict(g, IFDKGrid(r=32, c=8), ABCI))
        assert g2 > g1

    def test_tpu_constants_give_finite_projection(self):
        g = paper_problem()
        b = predict(g, IFDKGrid(r=16, c=16), TPU_V5E)
        assert 0 < b.t_runtime < 120
