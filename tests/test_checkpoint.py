"""Checkpoint I/O + fault tolerance + elastic remesh."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (
    CheckpointManager, latest_step, load_checkpoint, save_checkpoint,
)
from repro.runtime import (
    ResumableReconstruction, StragglerMonitor, plan_remesh, restart_loop,
)


def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.float32),
                   "step": np.int64(7)},
    }


class TestCheckpointIO:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 3, t)
        assert latest_step(str(tmp_path)) == 3
        out = load_checkpoint(str(tmp_path), 3, t)
        np.testing.assert_array_equal(np.array(out["w"]), np.array(t["w"]))
        np.testing.assert_array_equal(np.array(out["nested"]["b"]),
                                      np.array(t["nested"]["b"]))

    def test_commit_marker_required(self, tmp_path):
        import os
        t = _tree()
        p = save_checkpoint(str(tmp_path), 1, t)
        os.remove(os.path.join(p, ".COMMITTED"))
        assert latest_step(str(tmp_path)) is None  # uncommitted is invisible

    def test_shape_mismatch_rejected(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 1, t)
        bad = dict(t)
        bad["w"] = jnp.zeros((2, 2))
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, bad)

    def test_manager_retention_and_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(), blocking=False)
        mgr.wait()
        mgr._gc()
        steps = sorted(
            int(n.split("_")[1]) for n in
            __import__("os").listdir(str(tmp_path)) if n.startswith("step_")
        )
        assert steps == [3, 4]
        s, tree = mgr.restore_latest(_tree())
        assert s == 4 and tree is not None


class TestFaultTolerance:
    def test_resumable_reconstruction_survives_fault(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        step_fn = lambda acc, b: acc + (b + 1.0)  # noqa: E731
        r1 = ResumableReconstruction(step_fn, jnp.zeros((3,)), 8, mgr,
                                     checkpoint_every=2)
        with pytest.raises(RuntimeError):
            r1.run(fail_at=5)
        r2 = ResumableReconstruction(step_fn, jnp.zeros((3,)), 8, mgr,
                                     checkpoint_every=2)
        r2.resume()
        assert r2.state.cursor == 4  # resumed from the last committed batch
        out = r2.run()
        np.testing.assert_allclose(np.array(out), float(sum(range(1, 9))))

    def test_restart_loop_exact_result_after_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = restart_loop(
            lambda: {"x": np.float64(0.0)},
            lambda s, i: {"x": s["x"] + i},
            n_steps=20, manager=mgr, checkpoint_every=5, fail_at={7, 13},
        )
        assert state["x"] == float(sum(range(20)))

    def test_restart_loop_gives_up_after_max_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(RuntimeError):
            restart_loop(
                lambda: {"x": np.float64(0.0)},
                lambda s, i: (_ for _ in ()).throw(RuntimeError("boom")),
                n_steps=5, manager=mgr, max_failures=2,
            )

    def test_straggler_monitor(self):
        mon = StragglerMonitor(threshold=2.0)
        flags = [mon.record(t) for t in [1.0, 1.1, 0.9, 5.0, 1.0]]
        assert flags == [False, False, False, True, False]
        hint = mon.rebalance_hint(n_batches=4, n_ranks=8)
        assert hint["micro_batches"] >= 8
        assert hint["flagged_steps"][0][0] == 3

    def test_straggler_does_not_pollute_ema(self):
        mon = StragglerMonitor(threshold=2.0)
        for t in [1.0, 1.0, 10.0, 1.0, 1.0]:
            mon.record(t)
        assert mon.ema < 1.5


class TestElastic:
    def test_plan_remesh_full(self):
        plan = plan_remesh(list(range(512)), model_parallel=16, want_pods=2)
        assert plan.mesh_shape == (2, 16, 16)
        assert plan.dropped_devices == 0

    def test_plan_remesh_after_node_loss(self):
        plan = plan_remesh(list(range(508)), model_parallel=16, want_pods=2)
        assert plan.mesh_shape == (2, 15, 16)
        assert plan.dropped_devices == 508 - 2 * 15 * 16

    def test_plan_remesh_single_pod(self):
        plan = plan_remesh(list(range(100)), model_parallel=8)
        assert plan.mesh_shape == (12, 8)

    def test_insufficient_devices(self):
        with pytest.raises(ValueError):
            plan_remesh(list(range(4)), model_parallel=16)
