"""Checkpoint I/O + fault tolerance + elastic remesh."""
import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import (
    CheckpointManager, StoreError, committed_steps, latest_step,
    load_checkpoint, save_checkpoint,
)
from repro.parallel.mesh import single_device_mesh
from repro.runtime import (
    ResumableReconstruction, StragglerMonitor, plan_remesh, restart_loop,
)

from tests._hyp import given, settings, st


def _tree():
    return {
        "w": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.float32),
                   "step": np.int64(7)},
    }


class TestCheckpointIO:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 3, t)
        assert latest_step(str(tmp_path)) == 3
        out = load_checkpoint(str(tmp_path), 3, t)
        np.testing.assert_array_equal(np.array(out["w"]), np.array(t["w"]))
        np.testing.assert_array_equal(np.array(out["nested"]["b"]),
                                      np.array(t["nested"]["b"]))

    def test_commit_marker_required(self, tmp_path):
        import os
        t = _tree()
        p = save_checkpoint(str(tmp_path), 1, t)
        os.remove(os.path.join(p, ".COMMITTED"))
        assert latest_step(str(tmp_path)) is None  # uncommitted is invisible

    def test_shape_mismatch_rejected(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 1, t)
        bad = dict(t)
        bad["w"] = jnp.zeros((2, 2))
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, bad)

    def test_manager_retention_and_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(), blocking=False)
        mgr.wait()
        mgr._gc()
        steps = sorted(
            int(n.split("_")[1]) for n in
            __import__("os").listdir(str(tmp_path)) if n.startswith("step_")
        )
        assert steps == [3, 4]
        s, tree = mgr.restore_latest(_tree())
        assert s == 4 and tree is not None


class TestSpecRecording:
    """Regression for the dead `meta["spec"] is not None` guard: the old
    writer emitted [] for EVERY unsharded leaf, so the branch was always
    taken and host arrays were silently re-mounted with an empty
    NamedSharding. None ("no spec recorded") and [] (a real, replicated
    PartitionSpec) are now distinct in the manifest and on restore."""

    def _manifest(self, path):
        with open(os.path.join(path, "MANIFEST.json")) as f:
            return json.load(f)

    def test_manifest_distinguishes_none_from_empty_spec(self, tmp_path):
        mesh = single_device_mesh()
        t = {
            "host": np.arange(6.0, dtype=np.float32).reshape(2, 3),
            "default": jnp.ones((4,)),                # no NamedSharding
            "replicated": jax.device_put(
                jnp.ones((4,)), NamedSharding(mesh, P())),
            "sharded": jax.device_put(
                jnp.ones((4, 2)), NamedSharding(mesh, P("model"))),
        }
        p = save_checkpoint(str(tmp_path), 1, t)
        specs = {e["key"]: e["spec"] for e in self._manifest(p)["leaves"]}
        by = {k.strip("[']"): v for k, v in specs.items()}
        assert by["host"] is None
        assert by["default"] is None
        assert by["replicated"] == []        # real spec, recorded
        assert by["sharded"] == ["model"]

    def test_restore_applies_spec_only_where_recorded(self, tmp_path):
        mesh = single_device_mesh()
        t = {
            "host": np.arange(3.0, dtype=np.float32),
            "replicated": jax.device_put(
                jnp.ones((4,)), NamedSharding(mesh, P())),
        }
        save_checkpoint(str(tmp_path), 1, t)
        out = load_checkpoint(str(tmp_path), 1, t, mesh=mesh)
        assert isinstance(out["replicated"].sharding, NamedSharding)
        assert not isinstance(out["host"].sharding, NamedSharding)
        np.testing.assert_array_equal(np.asarray(out["host"]), t["host"])

    def test_async_manager_snapshot_keeps_spec(self, tmp_path):
        """The background writer snapshots shard-by-shard, so the spec
        survives the host round-trip (the old manager flattened everything
        to plain numpy and lost it)."""
        mesh = single_device_mesh()
        t = {"w": jax.device_put(jnp.arange(8.0).reshape(4, 2),
                                 NamedSharding(mesh, P("model")))}
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, t, blocking=False)
        mgr.wait()
        p = os.path.join(str(tmp_path), "step_00000001")
        specs = [e["spec"] for e in self._manifest(p)["leaves"]]
        assert specs == [["model"]]
        step, out = mgr.restore_latest(t, mesh=mesh)
        assert step == 1
        assert isinstance(out["w"].sharding, NamedSharding)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(t["w"]))


class TestOrphanedTmpSweep:
    def _seed_tmp(self, directory, step=5):
        tmp = os.path.join(directory, f"step_{step:08d}.tmp")
        os.makedirs(os.path.join(tmp, "leaves", "leaf_99999"))
        with open(os.path.join(tmp, "leaves", "leaf_99999", "junk.bin"),
                  "w") as f:
            f.write("crashed writer leftovers")
        return tmp

    def test_manager_init_sweeps_orphans(self, tmp_path):
        tmp = self._seed_tmp(str(tmp_path))
        CheckpointManager(str(tmp_path))
        assert not os.path.exists(tmp)

    def test_gc_sweeps_orphans(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tmp = self._seed_tmp(str(tmp_path), step=9)
        mgr.save(1, _tree(), blocking=True)
        assert not os.path.exists(tmp)
        assert latest_step(str(tmp_path)) == 1

    def test_stale_tmp_does_not_shadow_later_save(self, tmp_path):
        """A crashed writer's tmp dir for step N must not leak its files
        into a later successful save of the same step."""
        self._seed_tmp(str(tmp_path), step=5)
        save_checkpoint(str(tmp_path), 5, _tree())
        leaves = os.listdir(
            os.path.join(str(tmp_path), "step_00000005", "leaves"))
        assert "leaf_99999" not in leaves
        out = load_checkpoint(str(tmp_path), 5, _tree())
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(_tree()["w"]))


class TestCorruptionHandling:
    """Truncated shard, gutted manifest and missing commit marker each fail
    loudly, and restore_latest falls back to the newest committed step that
    still loads."""

    def _corrupt(self, directory, step, kind):
        path = os.path.join(directory, f"step_{step:08d}")
        if kind == "truncated_shard":
            leaf = os.path.join(path, "leaves", "leaf_00000")
            shard = os.path.join(leaf, "shards", "shard_00000.bin")
            with open(shard, "r+b") as f:
                f.truncate(3)
        elif kind == "missing_manifest_entry":
            leaf = os.path.join(path, "leaves", "leaf_00000")
            mpath = os.path.join(leaf, "MANIFEST.json")
            with open(mpath) as f:
                m = json.load(f)
            m["shards"] = []
            with open(mpath, "w") as f:
                json.dump(m, f)
        elif kind == "missing_commit":
            os.remove(os.path.join(path, ".COMMITTED"))
        else:
            raise AssertionError(kind)

    @settings(max_examples=10, deadline=None)
    @given(kind=st.sampled_from(["truncated_shard", "missing_manifest_entry",
                                 "missing_commit"]))
    def test_corruption_raises_and_restore_falls_back(self, tmp_path, kind):
        d = str(tmp_path)
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(), blocking=True)
        mgr.save(2, _tree(), blocking=True)
        self._corrupt(d, 2, kind)
        if kind == "missing_commit":
            assert latest_step(d) == 1       # uncommitted is invisible
        else:
            assert latest_step(d) == 2       # committed but unreadable
        with pytest.raises(StoreError):
            load_checkpoint(d, 2, _tree())
        step, tree = mgr.restore_latest(_tree())
        assert step == 1 and tree is not None
        np.testing.assert_array_equal(np.asarray(tree["w"]),
                                      np.asarray(_tree()["w"]))

    def test_error_messages_name_the_problem(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        self._corrupt(d, 1, "truncated_shard")
        with pytest.raises(StoreError, match="truncated"):
            load_checkpoint(d, 1, _tree())
        save_checkpoint(d, 2, _tree())
        self._corrupt(d, 2, "missing_commit")
        with pytest.raises(StoreError, match="uncommitted"):
            load_checkpoint(d, 2, _tree())

    def test_nothing_loadable_returns_none_with_warning(self, tmp_path):
        d = str(tmp_path)
        mgr = CheckpointManager(d)
        mgr.save(1, _tree(), blocking=True)
        self._corrupt(d, 1, "truncated_shard")
        with pytest.warns(RuntimeWarning, match="no committed checkpoint"):
            step, tree = mgr.restore_latest(_tree())
        assert step is None and tree is None

    def test_committed_steps_lists_only_committed(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        save_checkpoint(d, 3, _tree())
        self._corrupt(d, 3, "missing_commit")
        assert committed_steps(d) == [1]


class TestFaultTolerance:
    def test_resumable_reconstruction_survives_fault(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        step_fn = lambda acc, b: acc + (b + 1.0)  # noqa: E731
        r1 = ResumableReconstruction(step_fn, jnp.zeros((3,)), 8, mgr,
                                     checkpoint_every=2)
        with pytest.raises(RuntimeError):
            r1.run(fail_at=5)
        r2 = ResumableReconstruction(step_fn, jnp.zeros((3,)), 8, mgr,
                                     checkpoint_every=2)
        r2.resume()
        assert r2.state.cursor == 4  # resumed from the last committed batch
        out = r2.run()
        np.testing.assert_allclose(np.array(out), float(sum(range(1, 9))))

    def test_restart_loop_exact_result_after_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = restart_loop(
            lambda: {"x": np.float64(0.0)},
            lambda s, i: {"x": s["x"] + i},
            n_steps=20, manager=mgr, checkpoint_every=5, fail_at={7, 13},
        )
        assert state["x"] == float(sum(range(20)))

    def test_restart_loop_gives_up_after_max_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(RuntimeError):
            restart_loop(
                lambda: {"x": np.float64(0.0)},
                lambda s, i: (_ for _ in ()).throw(RuntimeError("boom")),
                n_steps=5, manager=mgr, max_failures=2,
            )

    def test_straggler_monitor(self):
        mon = StragglerMonitor(threshold=2.0)
        flags = [mon.record(t) for t in [1.0, 1.1, 0.9, 5.0, 1.0]]
        assert flags == [False, False, False, True, False]
        hint = mon.rebalance_hint(n_batches=4, n_ranks=8)
        assert hint["micro_batches"] >= 8
        assert hint["flagged_steps"][0][0] == 3

    def test_straggler_does_not_pollute_ema(self):
        mon = StragglerMonitor(threshold=2.0)
        for t in [1.0, 1.0, 10.0, 1.0, 1.0]:
            mon.record(t)
        assert mon.ema < 1.5


class TestElastic:
    def test_plan_remesh_full(self):
        plan = plan_remesh(list(range(512)), model_parallel=16, want_pods=2)
        assert plan.mesh_shape == (2, 16, 16)
        assert plan.dropped_devices == 0

    def test_plan_remesh_after_node_loss(self):
        plan = plan_remesh(list(range(508)), model_parallel=16, want_pods=2)
        assert plan.mesh_shape == (2, 15, 16)
        assert plan.dropped_devices == 508 - 2 * 15 * 16

    def test_plan_remesh_single_pod(self):
        plan = plan_remesh(list(range(100)), model_parallel=8)
        assert plan.mesh_shape == (12, 8)

    def test_insufficient_devices(self):
        with pytest.raises(ValueError):
            plan_remesh(list(range(4)), model_parallel=16)
