"""Distributed iFDK on a virtual 8-device mesh (subprocess: the device-count
flag must be set before jax initializes, and the main test process keeps the
real 1-device CPU view)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # multi-minute subprocess (8 virtual devices)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.mesh import make_mesh
from repro.core.geometry import default_geometry
from repro.core.phantom import forward_project
from repro.core.fdk import reconstruct
from repro.core.distributed import (
    make_distributed_fdk, input_sharding, choose_grid,
)
from repro.core.pipeline import make_pipelined_fdk

results = {}
g = default_geometry(16, n_proj=32)
proj = forward_project(g)
ref = np.array(reconstruct(g, proj, impl="factorized"))

# 1. distributed == single device, across mesh shapes and reduce modes
for shape, axes in [((2, 2, 2), ("pod", "data", "model")),
                    ((4, 2), ("data", "model")),
                    ((2, 4), ("data", "model"))]:
    mesh = make_mesh(shape, axes)
    for red in ("psum", "scatter"):
        fn = make_distributed_fdk(mesh, g, impl="factorized", reduce=red)
        out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
        results[f"dist/{shape}/{red}"] = float(np.max(np.abs(out - ref)))

# 2. pipelined == single device for several depths
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
for ns in (1, 2, 4):
    fn = make_pipelined_fdk(mesh, g, n_steps=ns)
    out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
    results[f"pipe/{ns}"] = float(np.max(np.abs(out - ref)))

# 3. kernel impl distributed
mesh = make_mesh((2, 2), ("data", "model"))
fn = make_distributed_fdk(mesh, g, impl="kernel")
out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
results["dist/kernel"] = float(np.max(np.abs(out - ref)))

# 4. paper's grid rule (R=32, C=8 for 4096^3 on 256 16GB GPUs)
grid = choose_grid(default_geometry(4096, n_proj=4096), 256)
results["grid"] = [grid.r, grid.c]

# 4b. precision policy: bf16-storage distributed/pipelined/chunked paths all
# match the bf16 single-device reconstruction (same storage dtype; only f32
# reassociation across ranks may differ)
from repro.core.pipeline import make_chunked_fdk
ref16 = np.array(reconstruct(g, proj, impl="factorized", precision="bf16"))
mesh = make_mesh((2, 2), ("data", "model"))
fn = make_distributed_fdk(mesh, g, impl="factorized", precision="bf16")
out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
results["prec/dist_bf16"] = float(np.max(np.abs(out - ref16)))
fn = make_pipelined_fdk(mesh, g, n_steps=2, precision="bf16")
out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
results["prec/pipe_bf16"] = float(np.max(np.abs(out - ref16)))
fn = make_chunked_fdk(mesh, g, n_steps=2, y_chunks=4, precision="bf16")
out = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
out = out.reshape(g.n_x, g.n_y, g.n_z)
results["prec/chunk_bf16"] = float(np.max(np.abs(out - ref16)))

# 5. LM train step on the mesh: one real step, finite loss
from repro.configs import get_smoke_config
from repro.parallel.sharding import ShardingRules
from repro.training import make_train_step, init_train_state
from repro.training.train_step import state_shardings
from repro.data import synthetic_batch
cfg = get_smoke_config("qwen2_1_5b")
rules = ShardingRules(mesh=mesh)
key = jax.random.PRNGKey(0)
state = init_train_state(cfg, key)
st_sh = state_shardings(cfg, rules)
state = jax.device_put(state, st_sh)
step = jax.jit(make_train_step(cfg, rules=rules, microbatches=2),
               in_shardings=(st_sh, None))
batch = synthetic_batch(cfg, 4, 32, key)
state, m = step(state, batch)
results["lm/loss_finite"] = bool(jnp.isfinite(m["loss"]))

print("RESULTS" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS")][0]
    return json.loads(line[len("RESULTS"):])


def test_distributed_matches_single_device(dist_results):
    for key, err in dist_results.items():
        if key.startswith("dist/") and key != "dist/kernel":
            assert err < 5e-6, f"{key}: {err}"


def test_pipelined_matches_single_device(dist_results):
    for ns in (1, 2, 4):
        assert dist_results[f"pipe/{ns}"] < 5e-6


def test_pallas_kernel_under_shard_map(dist_results):
    assert dist_results["dist/kernel"] < 5e-6


def test_bf16_storage_distributed_matches_single(dist_results):
    """All three distributed paths at bf16 storage reproduce the bf16
    single-device reconstruction (half-width AllGather, f32 accumulate)."""
    for key in ("prec/dist_bf16", "prec/pipe_bf16", "prec/chunk_bf16"):
        assert dist_results[key] < 5e-6, f"{key}: {dist_results[key]}"


def test_paper_grid_rule(dist_results):
    # paper §5.3: R=32 for 4096^3 with 8 GB sub-volumes on 16 GB GPUs
    assert dist_results["grid"] == [32, 8]


def test_lm_train_step_on_mesh(dist_results):
    assert dist_results["lm/loss_finite"] is True
