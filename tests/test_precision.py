"""Storage-precision policy (fp16/bf16 stream, f32 accumulate) and the
VMEM-budget kernel autotuner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backprojection import (
    backproject_factorized, backproject_reference,
)
from repro.core.distributed import input_sharding, make_distributed_fdk
from repro.core.fdk import reconstruct
from repro.core.filtering import filter_projections
from repro.core.geometry import default_geometry, projection_matrices
from repro.core.phantom import forward_project, shepp_logan_volume
from repro.core.precision import (
    Precision, default_storage, psnr, resolve_precision,
)
from repro.kernels.backproject import tune
from repro.kernels.backproject.kernel import vmem_bytes
from repro.kernels.backproject.ops import backproject_pallas
from repro.parallel.mesh import single_device_mesh

STORAGES = ("fp32", "bf16", "fp16")


@pytest.fixture(scope="module")
def case16():
    """The 16^3 default geometry with its fp32 factorized oracle."""
    g = default_geometry(16, n_proj=8)
    proj = forward_project(g)
    pm = jnp.asarray(projection_matrices(g))
    q32 = filter_projections(g, proj, out_dtype=jnp.float32)
    oracle = backproject_factorized(pm, q32, g.n_x, g.n_y, g.n_z)
    return g, proj, pm, oracle


class TestPrecisionPolicy:
    def test_storage_dtypes(self):
        assert Precision("fp32").storage_dtype == jnp.float32
        assert Precision("bf16").storage_dtype == jnp.bfloat16
        assert Precision("fp16").storage_dtype == jnp.float16

    def test_canonical_aliases(self):
        assert Precision("float16").storage == "fp16"
        assert Precision("bfloat16").storage == "bf16"
        assert Precision("f32").storage == "fp32"

    def test_unknown_storage_rejected(self):
        with pytest.raises(ValueError):
            Precision("int8")

    def test_resolve(self):
        assert resolve_precision("fp16") == Precision("fp16")
        p = Precision("bf16")
        assert resolve_precision(p) is p
        # None -> backend default: bf16 on CPU/TPU, fp16 on GPU
        assert resolve_precision(None).storage == default_storage()
        assert default_storage("cpu") == "bf16"
        assert default_storage("tpu") == "bf16"
        assert default_storage("gpu") == "fp16"

    def test_accumulation_always_f32(self):
        for s in STORAGES:
            assert Precision(s).accum_dtype == jnp.float32

    def test_halved_allgather_bytes(self):
        g = default_geometry(16, n_proj=8)
        full = Precision("fp32").allgather_bytes(g.n_proj, g.n_v, g.n_u)
        half = Precision("bf16").allgather_bytes(g.n_proj, g.n_v, g.n_u)
        assert half * 2 == full

    def test_tolerances_scale_with_eps(self):
        assert Precision("fp32").rmse_tol() == pytest.approx(1e-5)
        assert Precision("fp16").rmse_tol() > Precision("fp32").rmse_tol()
        assert Precision("bf16").rmse_tol() > Precision("fp16").rmse_tol()


class TestLowPrecisionBackprojection:
    """Oracle tests over {fp32, bf16, fp16} storage, tolerance from eps."""

    @pytest.mark.parametrize("storage", STORAGES)
    @pytest.mark.parametrize(
        "bp", [backproject_reference, backproject_factorized,
               backproject_pallas],
        ids=["reference", "factorized", "kernel"],
    )
    def test_matches_fp32_oracle(self, case16, bp, storage):
        g, proj, pm, oracle = case16
        p = Precision(storage)
        q = filter_projections(g, proj, out_dtype=p.storage_dtype)
        assert q.dtype == p.storage_dtype
        out = bp(pm, q, g.n_x, g.n_y, g.n_z)
        assert out.dtype == jnp.float32  # f32 accumulate, always
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        rmse = float(jnp.sqrt(jnp.mean((out - oracle) ** 2))) / scale
        mx = float(jnp.max(jnp.abs(out - oracle))) / scale
        assert rmse < p.rmse_tol(), f"{storage}: rmse {rmse:.3e}"
        assert mx < p.max_tol(), f"{storage}: max {mx:.3e}"

    @pytest.mark.parametrize("storage", ["bf16", "fp16"])
    def test_filtering_emits_storage_dtype(self, case16, storage):
        g, proj, _, _ = case16
        p = Precision(storage)
        q = filter_projections(g, proj, out_dtype=p.storage_dtype)
        assert q.dtype == p.storage_dtype
        assert q.nbytes * 2 == g.n_proj * g.n_v * g.n_u * 4

    @pytest.mark.parametrize("storage", STORAGES)
    def test_distributed_bitmatches_single_device(self, case16, storage):
        """The distributed path must be bit-identical to the single-device
        path at the same storage dtype (1x1 mesh: the collectives are
        identities, so any deviation is a precision-policy leak)."""
        g, proj, _, _ = case16
        mesh = single_device_mesh()
        fn = make_distributed_fdk(mesh, g, impl="factorized",
                                  precision=storage)
        dist = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
        single = np.array(
            reconstruct(g, proj, impl="factorized", precision=storage)
        )
        np.testing.assert_array_equal(dist, single)


class TestGoldenPSNR:
    """Regression floor: future kernel/precision work must not silently
    degrade Shepp-Logan reconstruction quality. Measured 15.9 dB for every
    (impl, precision) pair at 16^3/24 views; floor set 2 dB under."""

    FLOOR_DB = 13.9

    @pytest.fixture(scope="class")
    def golden_case(self):
        g = default_geometry(16, n_proj=24)
        return g, forward_project(g), shepp_logan_volume(g)

    @pytest.mark.parametrize("impl", ["reference", "factorized", "kernel"])
    @pytest.mark.parametrize("storage", STORAGES)
    def test_psnr_floor(self, golden_case, impl, storage):
        g, proj, ph = golden_case
        vol = reconstruct(g, proj, impl=impl, precision=storage)
        m = g.n_x // 5
        interior = (slice(m, g.n_x - m),) * 3
        got = psnr(np.array(vol[interior]), np.array(ph[interior]))
        assert got > self.FLOOR_DB, f"{impl}/{storage}: {got:.2f} dB"


class TestAutotuner:
    def test_candidates_tile_and_fit_budget(self):
        budget = 256 * 1024
        cands = tune.candidate_blocks(16, 16, 8, 24, 24, 8,
                                      jnp.float32, budget)
        assert cands
        for c in cands:
            assert 16 % c.bi == 0 and 16 % c.bj == 0
            assert c.vmem == vmem_bytes(c.bi, c.bj, c.bs, 24, 24, 8)
            assert c.vmem <= budget

    def test_low_precision_widens_feasible_set(self):
        """bf16 projections halve the qt VMEM term, so a tight budget
        admits strictly more (or larger-batch) candidates."""
        budget = vmem_bytes(8, 8, 8, 64, 64, 8, jnp.float32)
        n32 = len(tune.candidate_blocks(16, 16, 8, 64, 64, 8,
                                        jnp.float32, budget))
        n16 = len(tune.candidate_blocks(16, 16, 8, 64, 64, 8,
                                        jnp.float16, budget))
        assert n16 > n32

    def test_budget_too_small_raises(self):
        with pytest.raises(ValueError):
            tune.autotune(16, 16, 16, 8, 24, 24, budget=128, measure=False)

    def test_pick_is_cached(self):
        tune.clear_cache()
        a = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert len(tune.cache_info()) == 1
        b = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert a is b
        # a different storage dtype is a different cache entry
        tune.autotune(16, 16, 16, 8, 24, 24, qt_dtype=jnp.bfloat16,
                      measure=False)
        assert len(tune.cache_info()) == 2

    def test_measured_mode_times_survivors(self):
        tune.clear_cache()
        best = tune.autotune(16, 16, 16, 8, 24, 24, measure=True,
                             max_measure=2)
        assert best.elapsed > 0.0
        assert best.vmem <= tune.DEFAULT_VMEM_BUDGET

    def test_kernel_uses_tuned_blocks(self, case16):
        """backproject_pallas with a constrained budget still matches the
        oracle — the tuner only changes the tiling, never the math."""
        g, proj, pm, oracle = case16
        q = filter_projections(g, proj, out_dtype=jnp.float32)
        out = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z,
                                 vmem_budget=64 * 1024)
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        assert float(jnp.max(jnp.abs(out - oracle))) / scale < 1e-4

    def test_explicit_blocks_bypass_tuner(self, case16):
        g, proj, pm, oracle = case16
        q = filter_projections(g, proj, out_dtype=jnp.float32)
        out = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z,
                                 bi=4, bj=4, bs=4)
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        assert float(jnp.max(jnp.abs(out - oracle))) / scale < 1e-4


class TestFileBackedCache:
    """The tuner memo persists to a JSON file (REPRO_TUNE_CACHE) so tuning
    survives across processes."""

    def test_survives_in_process_memo_clear(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
        tune.clear_cache()
        hits0 = tune.file_cache_hits()
        a = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert (tmp_path / "tc.json").exists()
        tune.clear_cache()  # drop the memo; the file must refill it
        b = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert tune.file_cache_hits() == hits0 + 1
        assert b.as_tuple() == a.as_tuple()

    def test_disabled_by_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", "off")
        assert tune.cache_path() is None
        tune.clear_cache()
        tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert not list(tmp_path.iterdir())

    def test_corrupt_cache_file_is_ignored(self, tmp_path, monkeypatch):
        path = tmp_path / "tc.json"
        path.write_text("{not json")
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
        tune.clear_cache()
        cfg = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert cfg.vmem <= tune.DEFAULT_VMEM_BUDGET  # recomputed fine

    @pytest.mark.slow
    def test_second_process_hits_cache(self, tmp_path):
        """A second *process* serves the tuning key from the file cache."""
        import os
        import subprocess
        import sys
        script = (
            "from repro.kernels.backproject import tune\n"
            "cfg = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)\n"
            "print('OUT', cfg.as_tuple(), tune.file_cache_hits())\n"
        )
        env = dict(os.environ)
        env["REPRO_TUNE_CACHE"] = str(tmp_path / "tc.json")
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", script], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr[-2000:]
            outs.append([l for l in r.stdout.splitlines()
                         if l.startswith("OUT")][0])
        blocks1, hits1 = outs[0][4:].rsplit(" ", 1)
        blocks2, hits2 = outs[1][4:].rsplit(" ", 1)
        assert (hits1, hits2) == ("0", "1")  # second process: served from disk
        assert blocks1 == blocks2
