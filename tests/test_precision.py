"""Stream codecs (fp32/bf16/fp16/fp8 wire formats, f32 accumulate) and the
VMEM-budget kernel autotuner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backprojection import (
    backproject_factorized, backproject_reference,
)
from repro.core.distributed import IFDKGrid, input_sharding, \
    make_distributed_fdk
from repro.core.fdk import reconstruct
from repro.core.filtering import filter_projections
from repro.core.geometry import default_geometry, projection_matrices
from repro.core.phantom import forward_project, shepp_logan_volume
from repro.core.plan import ReconstructionPlan
from repro.core.precision import (
    CODECS, Precision, codec_for, default_storage, psnr, resolve_precision,
)
from repro.kernels.backproject import tune
from repro.kernels.backproject.kernel import vmem_bytes
from repro.kernels.backproject.ops import backproject_mxu, backproject_pallas
from repro.parallel.mesh import make_mesh, single_device_mesh

STORAGES = ("fp32", "bf16", "fp16")


@pytest.fixture(scope="module")
def case16():
    """The 16^3 default geometry with its fp32 factorized oracle."""
    g = default_geometry(16, n_proj=8)
    proj = forward_project(g)
    pm = jnp.asarray(projection_matrices(g))
    q32 = filter_projections(g, proj, out_dtype=jnp.float32)
    oracle = backproject_factorized(pm, q32, g.n_x, g.n_y, g.n_z)
    return g, proj, pm, oracle


class TestPrecisionPolicy:
    def test_storage_dtypes(self):
        assert Precision("fp32").storage_dtype == jnp.float32
        assert Precision("bf16").storage_dtype == jnp.bfloat16
        assert Precision("fp16").storage_dtype == jnp.float16

    def test_canonical_aliases(self):
        assert Precision("float16").storage == "fp16"
        assert Precision("bfloat16").storage == "bf16"
        assert Precision("f32").storage == "fp32"

    def test_unknown_storage_rejected(self):
        with pytest.raises(ValueError):
            Precision("int8")

    def test_resolve(self):
        assert resolve_precision("fp16") == Precision("fp16")
        p = Precision("bf16")
        assert resolve_precision(p) is p
        # None -> backend default: bf16 on CPU/TPU, fp16 on GPU
        assert resolve_precision(None).storage == default_storage()
        assert default_storage("cpu") == "bf16"
        assert default_storage("tpu") == "bf16"
        assert default_storage("gpu") == "fp16"

    def test_accumulation_always_f32(self):
        for s in STORAGES:
            assert Precision(s).accum_dtype == jnp.float32

    def test_halved_allgather_bytes(self):
        g = default_geometry(16, n_proj=8)
        full = Precision("fp32").allgather_bytes(g.n_proj, g.n_v, g.n_u)
        half = Precision("bf16").allgather_bytes(g.n_proj, g.n_v, g.n_u)
        assert half * 2 == full

    def test_tolerances_scale_with_eps(self):
        assert Precision("fp32").rmse_tol() == pytest.approx(1e-5)
        assert Precision("fp16").rmse_tol() > Precision("fp32").rmse_tol()
        assert Precision("bf16").rmse_tol() > Precision("fp16").rmse_tol()
        assert Precision("fp8_e4m3").rmse_tol() > Precision("bf16").rmse_tol()

    def test_fp8_aliases(self):
        for alias in ("fp8", "e4m3", "float8_e4m3fn"):
            assert Precision(alias).storage == "fp8_e4m3"
        assert Precision("fp8_e4m3").storage_dtype == jnp.float8_e4m3fn
        assert Precision("fp8_e4m3").storage_bytes == 1
        for alias in ("e5m2", "float8_e5m2"):
            assert Precision(alias).storage == "fp8_e5m2"
        assert Precision("fp8_e5m2").storage_dtype == jnp.float8_e5m2
        assert Precision("fp8_e5m2").storage_bytes == 1
        # two mantissa bits vs three: e5m2 quantizes twice as coarsely
        assert Precision("fp8_e5m2").eps() == 2 * Precision("fp8_e4m3").eps()


class TestStreamCodecs:
    """The codec layer itself: wire formats, scale sidecars, and the
    engine/cost-model agreement on wire bytes (ISSUE 5 acceptance)."""

    @pytest.fixture(scope="class")
    def q32(self):
        g = default_geometry(16, n_proj=8)
        return g, filter_projections(g, forward_project(g),
                                     out_dtype=jnp.float32)

    def test_registry(self):
        assert set(CODECS) == {"fp32", "bf16", "fp16", "fp8_e4m3",
                               "fp8_e5m2"}
        for name, codec in CODECS.items():
            assert codec is codec_for(name)
            assert codec is Precision(name).codec
            assert (codec.wire_bytes_per_sample
                    == jnp.dtype(codec.wire_dtype).itemsize)
        assert not CODECS["fp32"].has_scales
        assert not CODECS["bf16"].has_scales
        assert CODECS["fp16"].has_scales      # scale-on-overflow
        assert CODECS["fp8_e4m3"].has_scales  # normalizing
        assert CODECS["fp8_e5m2"].has_scales  # normalizing

    def test_scale_free_encode_bitmatches_cast(self, q32):
        """bf16 (and f32) codecs are byte-identical to the historical
        plain-cast policy."""
        _, q = q32
        for name in ("fp32", "bf16"):
            data, scales = CODECS[name].encode(q)
            assert scales is None
            assert data.dtype == CODECS[name].wire_dtype
            assert bool(jnp.all(data == q.astype(CODECS[name].wire_dtype)))

    def test_fp16_in_range_bitmatches_cast(self, q32):
        """In-range streams: fp16 scales are exactly 1.0 and the data bits
        equal the naive cast (the historical behaviour)."""
        _, q = q32
        data, scales = CODECS["fp16"].encode(q)
        assert bool(jnp.all(scales == 1.0))
        assert bool(jnp.all(data == q.astype(jnp.float16)))

    def test_fp16_scales_on_overflow(self, q32):
        """Beyond-65504 projections encode finite and decode accurately
        (the overflow hazard the old docstring only warned about)."""
        _, q = q32
        big = q.astype(jnp.float32) * 3e5   # max |q| >> fp16 max
        assert not bool(jnp.all(jnp.isfinite(big.astype(jnp.float16))))
        data, scales = CODECS["fp16"].encode(big)
        assert bool(jnp.all(jnp.isfinite(data.astype(jnp.float32))))
        assert bool(jnp.any(scales > 1.0))
        dec = CODECS["fp16"].decode(data, scales)
        err = float(jnp.max(jnp.abs(dec - big))) / float(jnp.max(jnp.abs(big)))
        assert err < 2 * Precision("fp16").eps()

    def test_fp8_roundtrip_error_bound(self, q32):
        """encode/decode is a per-projection-relative quantization: each tap
        is recovered within eps/2 of the projection's max-abs."""
        _, q = q32
        codec = CODECS["fp8_e4m3"]
        data, scales = codec.encode(q)
        assert data.dtype == jnp.float8_e4m3fn
        assert scales.shape == (q.shape[0],) and scales.dtype == jnp.float32
        dec = codec.decode(data, scales)
        amax = jnp.max(jnp.abs(q.astype(jnp.float32)), axis=(-2, -1))
        per_proj = jnp.max(jnp.abs(dec - q), axis=(-2, -1)) / amax
        assert float(jnp.max(per_proj)) <= 0.5 * Precision("fp8_e4m3").eps()

    def test_fp8_e5m2_roundtrip_error_bound(self, q32):
        """Same normalizing contract as e4m3 at e5m2's coarser eps — and a
        wider exponent: the normalized stream never needs the sidecar to
        rescue range, only precision."""
        _, q = q32
        codec = CODECS["fp8_e5m2"]
        data, scales = codec.encode(q)
        assert data.dtype == jnp.float8_e5m2
        assert scales.shape == (q.shape[0],) and scales.dtype == jnp.float32
        dec = codec.decode(data, scales)
        amax = jnp.max(jnp.abs(q.astype(jnp.float32)), axis=(-2, -1))
        per_proj = jnp.max(jnp.abs(dec - q), axis=(-2, -1)) / amax
        assert float(jnp.max(per_proj)) <= 0.5 * Precision("fp8_e5m2").eps()

    def test_fp8_zero_projection_is_exact(self):
        codec = CODECS["fp8_e4m3"]
        data, scales = codec.encode(jnp.zeros((3, 4, 4), jnp.float32))
        assert bool(jnp.all(scales == 1.0))
        assert bool(jnp.all(codec.decode(data, scales) == 0.0))

    def test_decode_requires_sidecar(self):
        with pytest.raises(ValueError, match="scale"):
            CODECS["fp8_e4m3"].decode(
                jnp.zeros((2, 4, 4), jnp.float8_e4m3fn))

    def test_fp8_wire_bytes_quarter_of_f32(self, q32):
        """ISSUE 5 acceptance: the cost model and the engine agree that fp8
        AllGather wire bytes are 1/4 of f32 plus the scale sidecar — the
        encoded arrays, `Precision.wire_bytes`, and the planner's AllGather
        accounting are the same number."""
        from repro.planner.cost import allgather_wire_bytes, PlanPoint
        g, q = q32
        fp8 = Precision("fp8_e4m3")
        enc = fp8.codec.encode(q)
        n, v, u = g.n_proj, g.n_v, g.n_u
        # engine side: actual encoded bytes
        assert enc.nbytes == n * v * u + 4 * n
        # policy side: one formula
        assert fp8.wire_bytes(n, v, u) == enc.nbytes
        assert (fp8.wire_bytes(n, v, u)
                == Precision("fp32").wire_bytes(n, v, u) // 4 + 4 * n)
        assert fp8.allgather_bytes(n, v, u) == fp8.wire_bytes(n, v, u)
        # cost-model side: the AllGather accounting prices the same bytes
        grid = IFDKGrid(r=2, c=1)
        ag8 = allgather_wire_bytes(g, PlanPoint(grid=grid,
                                                precision="fp8_e4m3"))
        ag32 = allgather_wire_bytes(g, PlanPoint(grid=grid,
                                                 precision="fp32"))
        n_ranks, moved = grid.n_ranks, (grid.r - 1) / grid.r
        assert ag8 == int(n_ranks * moved * fp8.wire_bytes(n, v, u))
        assert ag8 == ag32 // 4 + int(n_ranks * moved * 4 * n)

    @pytest.mark.parametrize(
        "bp", [backproject_reference, backproject_factorized,
               backproject_pallas, backproject_mxu],
        ids=["reference", "factorized", "kernel", "mxu"],
    )
    def test_every_backprojector_dequantizes_fp8(self, case16, bp):
        """All four implementations decode the fp8 stream via the scale
        sidecar (taps dequantize before the f32 FMA) and agree with the f32
        oracle within the fp8 tolerance."""
        g, proj, pm, oracle = case16
        q = filter_projections(g, proj, out_dtype=jnp.float32)
        data, scales = CODECS["fp8_e4m3"].encode(q)
        out = bp(pm, data, g.n_x, g.n_y, g.n_z, scales=scales)
        assert out.dtype == jnp.float32
        p = Precision("fp8_e4m3")
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        rmse = float(jnp.sqrt(jnp.mean((out - oracle) ** 2))) / scale
        assert rmse < p.rmse_tol(), f"fp8 rmse {rmse:.3e}"


class TestFp16OverflowRegression:
    """ISSUE 5 satellite: ramp-filtered projections of a high-contrast scan
    exceed fp16's 65504 — the naive cast poisons the volume with inf/nan,
    the fp16 codec's scale-on-overflow keeps full fp16 accuracy."""

    def test_high_contrast_phantom(self, case16):
        g, proj, _, _ = case16
        big = proj * np.float32(1e6)        # filtered stream peaks ~ 1e6
        q = filter_projections(g, big, out_dtype=jnp.float32)
        assert float(jnp.max(jnp.abs(q))) > 65504.0  # genuinely overflows
        naive = q.astype(jnp.float16)
        assert not bool(jnp.all(jnp.isfinite(naive.astype(jnp.float32))))
        oracle = np.asarray(ReconstructionPlan(geometry=g).build()(big))
        out = np.asarray(
            ReconstructionPlan(geometry=g, precision="fp16").build()(big))
        assert np.all(np.isfinite(out))
        p = Precision("fp16")
        scale = float(np.max(np.abs(oracle))) + 1e-12
        rmse = float(np.sqrt(np.mean((out - oracle) ** 2))) / scale
        assert rmse < p.rmse_tol(), f"overflow rmse {rmse:.3e}"


class TestLowPrecisionBackprojection:
    """Oracle tests over {fp32, bf16, fp16} storage, tolerance from eps."""

    @pytest.mark.parametrize("storage", STORAGES)
    @pytest.mark.parametrize(
        "bp", [backproject_reference, backproject_factorized,
               backproject_pallas],
        ids=["reference", "factorized", "kernel"],
    )
    def test_matches_fp32_oracle(self, case16, bp, storage):
        g, proj, pm, oracle = case16
        p = Precision(storage)
        q = filter_projections(g, proj, out_dtype=p.storage_dtype)
        assert q.dtype == p.storage_dtype
        out = bp(pm, q, g.n_x, g.n_y, g.n_z)
        assert out.dtype == jnp.float32  # f32 accumulate, always
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        rmse = float(jnp.sqrt(jnp.mean((out - oracle) ** 2))) / scale
        mx = float(jnp.max(jnp.abs(out - oracle))) / scale
        assert rmse < p.rmse_tol(), f"{storage}: rmse {rmse:.3e}"
        assert mx < p.max_tol(), f"{storage}: max {mx:.3e}"

    @pytest.mark.parametrize("storage", ["bf16", "fp16"])
    def test_filtering_emits_storage_dtype(self, case16, storage):
        g, proj, _, _ = case16
        p = Precision(storage)
        q = filter_projections(g, proj, out_dtype=p.storage_dtype)
        assert q.dtype == p.storage_dtype
        assert q.nbytes * 2 == g.n_proj * g.n_v * g.n_u * 4

    @pytest.mark.parametrize("storage", STORAGES)
    def test_distributed_bitmatches_single_device(self, case16, storage):
        """The distributed path must be bit-identical to the single-device
        path at the same storage dtype (1x1 mesh: the collectives are
        identities, so any deviation is a precision-policy leak)."""
        g, proj, _, _ = case16
        mesh = single_device_mesh()
        fn = make_distributed_fdk(mesh, g, impl="factorized",
                                  precision=storage)
        dist = np.array(fn(jax.device_put(proj, input_sharding(mesh))))
        single = np.array(
            reconstruct(g, proj, impl="factorized", precision=storage)
        )
        np.testing.assert_array_equal(dist, single)


class TestGoldenPSNR:
    """Regression floor: future kernel/precision work must not silently
    degrade Shepp-Logan reconstruction quality. Measured 15.9 dB for every
    (impl, precision) pair at 16^3/24 views; floor set 2 dB under."""

    FLOOR_DB = 13.9

    @pytest.fixture(scope="class")
    def golden_case(self):
        g = default_geometry(16, n_proj=24)
        return g, forward_project(g), shepp_logan_volume(g)

    @pytest.mark.parametrize("impl", ["reference", "factorized", "kernel"])
    @pytest.mark.parametrize("storage", STORAGES)
    def test_psnr_floor(self, golden_case, impl, storage):
        g, proj, ph = golden_case
        vol = reconstruct(g, proj, impl=impl, precision=storage)
        m = g.n_x // 5
        interior = (slice(m, g.n_x - m),) * 3
        got = psnr(np.array(vol[interior]), np.array(ph[interior]))
        assert got > self.FLOOR_DB, f"{impl}/{storage}: {got:.2f} dB"


class TestQuantizationStudy:
    """ISSUE 5 satellite: PSNR sweep of the codec ladder against the f32
    Shepp-Logan oracle (the f32 reconstruction, 16^3 / 24 views).

    Measured on this geometry: bf16 ~76 dB, fp16 ~94 dB, fp8_e4m3 ~52 dB,
    fp8_e5m2 ~46 dB (the ~6 dB cost of trading a mantissa bit for
    exponent range). Each *_FLOOR_DB is the documented regression floor (a
    few dB under the measured value, the same convention as
    TestGoldenPSNR.FLOOR_DB); the ordering assertion pins the physics:
    narrower mantissa can only lose fidelity — fp32 >= bf16 >= e4m3 >=
    e5m2 on a normalized (in-range) stream.
    """

    FP8_FLOOR_DB = 48.0
    E5M2_FLOOR_DB = 42.0
    BF16_FLOOR_DB = 70.0

    @pytest.fixture(scope="class")
    def sweep(self):
        g = default_geometry(16, n_proj=24)
        proj = forward_project(g)
        mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
        oracle = np.asarray(ReconstructionPlan(geometry=g).build()(proj))
        vols = {}
        for storage in ("fp32", "bf16", "fp8_e4m3", "fp8_e5m2"):
            # the 1x1x1-mesh engine: the fp8 acceptance path of ISSUE 5
            plan = ReconstructionPlan(geometry=g, mesh=mesh,
                                      precision=storage)
            vols[storage] = np.asarray(plan.build()(
                jax.device_put(proj, input_sharding(mesh))))
        return oracle, vols

    def test_psnr_ordering(self, sweep):
        oracle, vols = sweep
        db = {s: psnr(v, oracle) for s, v in vols.items()}
        assert (db["fp32"] >= db["bf16"] >= db["fp8_e4m3"]
                >= db["fp8_e5m2"]), db

    def test_fp8_engine_clears_documented_floor(self, sweep):
        oracle, vols = sweep
        got = psnr(vols["fp8_e4m3"], oracle)
        assert got > self.FP8_FLOOR_DB, f"fp8: {got:.2f} dB"

    def test_fp8_e5m2_engine_clears_documented_floor(self, sweep):
        oracle, vols = sweep
        got = psnr(vols["fp8_e5m2"], oracle)
        assert got > self.E5M2_FLOOR_DB, f"e5m2: {got:.2f} dB"

    def test_bf16_engine_clears_documented_floor(self, sweep):
        oracle, vols = sweep
        got = psnr(vols["bf16"], oracle)
        assert got > self.BF16_FLOOR_DB, f"bf16: {got:.2f} dB"


class TestAutotuner:
    def test_candidates_tile_and_fit_budget(self):
        budget = 256 * 1024
        cands = tune.candidate_blocks(16, 16, 8, 24, 24, 8,
                                      jnp.float32, budget)
        assert cands
        for c in cands:
            assert 16 % c.bi == 0 and 16 % c.bj == 0
            assert c.vmem == vmem_bytes(c.bi, c.bj, c.bs, 24, 24, 8)
            assert c.vmem <= budget

    def test_low_precision_widens_feasible_set(self):
        """bf16 projections halve the qt VMEM term, so a tight budget
        admits strictly more (or larger-batch) candidates."""
        budget = vmem_bytes(8, 8, 8, 64, 64, 8, jnp.float32)
        n32 = len(tune.candidate_blocks(16, 16, 8, 64, 64, 8,
                                        jnp.float32, budget))
        n16 = len(tune.candidate_blocks(16, 16, 8, 64, 64, 8,
                                        jnp.float16, budget))
        assert n16 > n32

    def test_budget_too_small_raises(self):
        with pytest.raises(ValueError):
            tune.autotune(16, 16, 16, 8, 24, 24, budget=128, measure=False)

    def test_pick_is_cached(self):
        tune.clear_cache()
        a = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert len(tune.cache_info()) == 1
        b = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert a is b
        # a different storage dtype is a different cache entry
        tune.autotune(16, 16, 16, 8, 24, 24, qt_dtype=jnp.bfloat16,
                      measure=False)
        assert len(tune.cache_info()) == 2

    def test_measured_mode_times_survivors(self):
        tune.clear_cache()
        best = tune.autotune(16, 16, 16, 8, 24, 24, measure=True,
                             max_measure=2)
        assert best.elapsed > 0.0
        assert best.vmem <= tune.DEFAULT_VMEM_BUDGET

    def test_kernel_uses_tuned_blocks(self, case16):
        """backproject_pallas with a constrained budget still matches the
        oracle — the tuner only changes the tiling, never the math."""
        g, proj, pm, oracle = case16
        q = filter_projections(g, proj, out_dtype=jnp.float32)
        out = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z,
                                 vmem_budget=64 * 1024)
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        assert float(jnp.max(jnp.abs(out - oracle))) / scale < 1e-4

    def test_explicit_blocks_bypass_tuner(self, case16):
        g, proj, pm, oracle = case16
        q = filter_projections(g, proj, out_dtype=jnp.float32)
        out = backproject_pallas(pm, q, g.n_x, g.n_y, g.n_z,
                                 bi=4, bj=4, bs=4)
        scale = float(jnp.max(jnp.abs(oracle))) + 1e-12
        assert float(jnp.max(jnp.abs(out - oracle))) / scale < 1e-4


class TestFileBackedCache:
    """The tuner memo persists to a JSON file (REPRO_TUNE_CACHE) so tuning
    survives across processes."""

    def test_survives_in_process_memo_clear(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
        tune.clear_cache()
        hits0 = tune.file_cache_hits()
        a = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert (tmp_path / "tc.json").exists()
        tune.clear_cache()  # drop the memo; the file must refill it
        b = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert tune.file_cache_hits() == hits0 + 1
        assert b.as_tuple() == a.as_tuple()

    def test_disabled_by_env(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", "off")
        assert tune.cache_path() is None
        tune.clear_cache()
        tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert not list(tmp_path.iterdir())

    def test_corrupt_cache_file_is_ignored(self, tmp_path, monkeypatch):
        path = tmp_path / "tc.json"
        path.write_text("{not json")
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(path))
        tune.clear_cache()
        cfg = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)
        assert cfg.vmem <= tune.DEFAULT_VMEM_BUDGET  # recomputed fine

    @pytest.mark.slow
    def test_second_process_hits_cache(self, tmp_path):
        """A second *process* serves the tuning key from the file cache."""
        import os
        import subprocess
        import sys
        script = (
            "from repro.kernels.backproject import tune\n"
            "cfg = tune.autotune(16, 16, 16, 8, 24, 24, measure=False)\n"
            "print('OUT', cfg.as_tuple(), tune.file_cache_hits())\n"
        )
        env = dict(os.environ)
        env["REPRO_TUNE_CACHE"] = str(tmp_path / "tc.json")
        env["PYTHONPATH"] = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "src"))
        outs = []
        for _ in range(2):
            r = subprocess.run([sys.executable, "-c", script], env=env,
                               capture_output=True, text=True, timeout=300)
            assert r.returncode == 0, r.stderr[-2000:]
            outs.append([l for l in r.stdout.splitlines()
                         if l.startswith("OUT")][0])
        blocks1, hits1 = outs[0][4:].rsplit(" ", 1)
        blocks2, hits2 = outs[1][4:].rsplit(" ", 1)
        assert (hits1, hits2) == ("0", "1")  # second process: served from disk
        assert blocks1 == blocks2
