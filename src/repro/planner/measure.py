"""Measured refinement: time the top-k proposals' built engines.

The cost model ranks the whole space; this closes the loop on the few
survivors the way the autotuner does for kernel tiles — build each
mesh-backed proposal, run it on synthetic projections of the true shape,
and re-rank by wall clock. Timings are memoized in-process and in a
file-backed JSON cache so a planning session pays for each (geometry,
engine, backend) once across processes.

Knobs:
  REPRO_PLAN_CACHE   path of the measurement cache (JSON). Default
                     ~/.cache/repro/plan_measure_cache.json; "off"/"0"/""
                     disables persistence (same convention as
                     REPRO_TUNE_CACHE — shared machinery,
                     repro/filecache.py).
"""
from __future__ import annotations

import json
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.geometry import CBCTGeometry
from repro.filecache import JsonFileCache
from repro.obs.trace import get_tracer

from .search import PlanProposal

_CACHE: Dict[tuple, float] = {}
_FILE_CACHE = JsonFileCache("REPRO_PLAN_CACHE", "plan_measure_cache.json")


def clear_cache() -> None:
    """Drop the in-process memo (the file cache, if any, is untouched)."""
    _CACHE.clear()


def file_cache_hits() -> int:
    """How many timings this process served from the file cache."""
    return _FILE_CACHE.hits


def cache_path():
    """Resolved file-cache path, or None when persistence is disabled."""
    return _FILE_CACHE.path()


def _measure_key(g: CBCTGeometry, proposal: PlanProposal,
                 iters: int) -> tuple:
    # plan.describe() is the full engine identity (schedule/impl/precision/
    # grid/steps/chunks/reduce/window AND the resolved kernel blocks — two
    # vmem budgets that tune to different tiles get different keys); the
    # data-axis extent disambiguates meshes that share an (R, C) grid but
    # split C differently between pod and data (different scatter layout).
    plan = proposal.plan
    desc = json.dumps(plan.describe(), sort_keys=True, default=list)
    return (g.n_proj, g.n_u, g.n_v, g.n_x, g.n_y, g.n_z, desc,
            plan._data_size, jax.default_backend(), jax.device_count(),
            iters)


def measure_proposal(g: CBCTGeometry, proposal: PlanProposal,
                     iters: int = 2) -> float:
    """Seconds per reconstruction of the proposal's built engine on
    synthetic projections (zeros — back-projection work is shape-driven,
    not value-driven). Requires a mesh-backed proposal (`plan` set)."""
    if proposal.plan is None:
        raise ValueError(
            "cannot measure a grid-only proposal (no mesh to build on); "
            "use search_plans / auto_plan for measured refinement")
    key = _measure_key(g, proposal, iters)
    hit = _CACHE.get(key)
    if hit is None:
        entry = _FILE_CACHE.get(key)
        if isinstance(entry, (int, float)):
            _FILE_CACHE.hits += 1
            hit = _CACHE[key] = float(entry)
    if hit is not None:
        return hit

    plan = proposal.plan
    fn = plan.build()
    proj = jnp.zeros(g.proj_shape(), jnp.float32)
    if plan.mesh is not None:
        from repro.core.distributed import input_sharding
        proj = jax.device_put(proj, input_sharding(plan.mesh))
    jax.block_until_ready(fn(proj))  # compile + warm up
    # timed=True: the span measures even with tracing disabled (this IS the
    # measurement); with tracing enabled the refinement runs also land in
    # the exported trace, attributable per proposal via the spec attr.
    with get_tracer().span("planner.measure", timed=True, iters=iters,
                           spec=plan.describe().get("schedule")) as sp:
        for _ in range(iters):
            jax.block_until_ready(fn(proj))
    seconds = sp.duration_s / iters
    _CACHE[key] = seconds
    _FILE_CACHE.put(key, seconds)
    # One measurement path, two consumers: the same timing that re-ranks
    # this search also feeds the calibration store (planner/calibrate.py),
    # so refinement runs accumulate into the fitted overlay instead of
    # being discarded after ranking. Cached hits above do NOT re-record —
    # each wall-clock measurement is one sample.
    from .calibrate import record_engine_measurement
    record_engine_measurement(g, proposal.point, seconds)
    return seconds


def refine(g: CBCTGeometry, proposals: List[PlanProposal],
           top_k: int = 3, iters: int = 2) -> List[PlanProposal]:
    """Re-rank the first `top_k` proposals by measured seconds/call; the
    unmeasured tail keeps its model order behind them."""
    import dataclasses

    head = [
        dataclasses.replace(p, measured=measure_proposal(g, p, iters))
        for p in proposals[:top_k]
    ]
    head.sort(key=lambda p: p.measured)
    return head + list(proposals[top_k:])
