"""Trace-calibrated cost constants: close the predicted->measured loop.

`planner/cost.py` prices plans with hand-set ABCI-era constants (per-impl
GUPS factors, step overhead, FFT/collective throughputs), so on any other
host the ranking can be wrong. PR 8's `obs.attribution.compare` already
measures per-stage model error on traced runs; this module feeds it back:

  CalibrationStore      a persistent sample store (repro/filecache.py,
                        env ``REPRO_CALIB_CACHE``) accumulating
                        (predicted, measured) stage samples from every
                        traced run — `build_traced` engines, traced
                        `IncrementalSession`s, `export_trace.py`, and the
                        planner's own measured refinement
                        (planner/measure.py deposits its engine timings).
                        Keys: (system, stage, impl, schedule, reduce,
                        precision, problem-size bucket).
  MachineCalibration    the robust least-squares fit of those samples: a
                        per-stage time-scale overlay on a `MachineSpec`
                        (filter/AllGather/reduce throughputs, PFS
                        read/write), per-impl back-projection scales (the
                        measured replacement for `IMPL_GUPS_FACTOR`), and
                        a per-step dispatch overhead fitted from
                        fused-vs-pipelined engine pairs. Outliers are
                        MAD-rejected on log-ratios and every constant is
                        min-sample gated, so one noisy span cannot skew
                        rankings; unfitted constants fall back to stock.

`auto_plan(..., calibration="auto")` (the `plan_from_spec(g, "auto")`
default) resolves the overlay from the default store when enough samples
exist and ranks with it — including admitting `impl="kernel"` into the
searched space on non-TPU backends once its FITTED factor beats
reference's (the measured retirement of the hard CPU-only guard).

``REPRO_CALIB_CACHE`` names the store file ("off"/"0"/""/"none" disables
both accumulation and the auto overlay; unset falls back to
~/.cache/repro/calibration_store.json — the REPRO_TUNE_CACHE convention).
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.perf_model import ABCI, MachineSpec
from repro.filecache import JsonFileCache
from repro.obs.attribution import STAGE_FIELDS

from .cost import IMPL_GUPS_FACTOR, STEP_OVERHEAD_S, PlanPoint, \
    point_from_plan, predict_point

__all__ = [
    "MIN_SAMPLES", "MachineCalibration", "CalibrationStore",
    "default_store", "set_default_store", "default_calibration",
    "resolve_calibration", "record_traced_run", "record_engine_measurement",
    "robust_scale", "size_bucket",
]

# A constant is only trusted once this many samples survive outlier
# rejection — below the gate the stock value stands.
MIN_SAMPLES = 3
# Per-key ring: newest samples win (drift tracks the machine, not history).
MAX_SAMPLES_PER_KEY = 64
# MachineSpec throughput/bandwidth overlays, keyed by PerfBreakdown field.
# t_bp is NOT here: back-projection calibrates per impl (bp_scales).
_FIELD_OVERLAY_KW = {
    "t_flt": "flt_scale",
    "t_allgather": "allgather_scale",
    "t_reduce": "reduce_scale",
    "t_read": "read_scale",
    "t_write": "write_scale",
}


def size_bucket(g, grid) -> int:
    """Coarse problem-size key: log2 of back-projection updates per rank.
    Buckets bound per-key sample counts; the fit pools across them
    (time-weighted, so big runs dominate anyway)."""
    updates = g.n_x * g.n_y * g.n_z * g.n_proj / max(1, grid.n_ranks)
    return int(round(math.log2(max(2.0, updates))))


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def robust_scale(samples: Sequence[Tuple[float, float]],
                 min_samples: int = MIN_SAMPLES
                 ) -> Tuple[Optional[float], int, int]:
    """(scale, n_used, n_rejected): time-weighted least squares through the
    origin for measured ~ scale * predicted, after MAD outlier rejection
    on log-ratios.

    Rejection: a sample whose log(m/p) sits more than 3 MAD + 0.2 from the
    median ratio is dropped (the floor keeps a zero-spread cluster from
    rejecting everything but exact duplicates). Weights are the measured
    seconds, so a 2 s run outvotes twenty 1 ms dispatch-noise runs.
    Returns (None, 0, n_rejected) when fewer than `min_samples` survive —
    the caller falls back to the stock constant.
    """
    pts = [(float(p), float(m)) for p, m in samples if p > 0 and m > 0]
    if len(pts) < min_samples:
        return None, 0, 0
    logr = [math.log(m / p) for p, m in pts]
    med = _median(logr)
    mad = _median([abs(l - med) for l in logr])
    tol = 3.0 * mad + 0.2
    keep = [pt for pt, l in zip(pts, logr) if abs(l - med) <= tol]
    rejected = len(pts) - len(keep)
    if len(keep) < min_samples:
        return None, 0, rejected
    num = sum(m * p * m for p, m in keep)
    den = sum(m * p * p for p, m in keep)
    if den <= 0:
        return None, 0, rejected
    return num / den, len(keep), rejected


@dataclasses.dataclass(frozen=True)
class MachineCalibration:
    """The fitted overlay: measured/predicted TIME scales per constant.

    `stage_scales` maps PerfBreakdown fields (t_flt, t_allgather, t_reduce,
    t_read, t_write) to their fitted scale; `bp_scales` maps impls to the
    scale of the whole Eq. 12 back-projection term (the measured view of
    `IMPL_GUPS_FACTOR`: fitted factor = stock factor / bp_scale);
    `step_overhead_s` replaces STEP_OVERHEAD_S when fitted. Absent keys
    mean "not enough samples — stock constant stands".
    """

    base: str                               # MachineSpec.name fitted against
    stage_scales: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    bp_scales: Mapping[str, float] = dataclasses.field(default_factory=dict)
    step_overhead_s: Optional[float] = None
    n_samples: int = 0
    n_rejected: int = 0

    @property
    def is_empty(self) -> bool:
        return (not self.stage_scales and not self.bp_scales
                and self.step_overhead_s is None)

    def scale(self, field: str) -> float:
        return float(self.stage_scales.get(field, 1.0))

    def bp_scale(self, impl: str) -> Optional[float]:
        s = self.bp_scales.get(impl)
        return None if s is None else float(s)

    def step_overhead(self) -> float:
        return (STEP_OVERHEAD_S if self.step_overhead_s is None
                else self.step_overhead_s)

    def apply(self, system: MachineSpec) -> MachineSpec:
        """`system` with every fitted stage scale folded into its
        throughput/bandwidth constants (MachineSpec.with_overlay)."""
        kw = {_FIELD_OVERLAY_KW[f]: s for f, s in self.stage_scales.items()
              if f in _FIELD_OVERLAY_KW}
        return system.with_overlay(**kw) if kw else system

    def impl_gups_factor(self, impl: str) -> Optional[float]:
        """The measured counterpart of IMPL_GUPS_FACTOR[impl]: the stock
        factor corrected by the fitted back-projection scale. None when
        the impl has no fitted evidence."""
        s = self.bp_scale(impl)
        if s is None or s <= 0:
            return None
        return IMPL_GUPS_FACTOR.get(impl, 1.0) / s

    def admits_impl(self, impl: str) -> bool:
        """Measured-evidence gate for the search space: `impl` competes
        once its fitted factor exists and beats reference's (fitted when
        available, stock otherwise). This is what retires the hard
        CPU-only kernel guard in auto_plan."""
        f = self.impl_gups_factor(impl)
        if f is None:
            return False
        ref = self.impl_gups_factor("reference")
        if ref is None:
            ref = IMPL_GUPS_FACTOR["reference"]
        return f > ref

    def to_dict(self) -> dict:
        return {
            "base": self.base,
            "stage_scales": dict(self.stage_scales),
            "bp_scales": dict(self.bp_scales),
            "step_overhead_s": self.step_overhead_s,
            "n_samples": self.n_samples,
            "n_rejected": self.n_rejected,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "MachineCalibration":
        return cls(
            base=str(d.get("base", "")),
            stage_scales={str(k): float(v)
                          for k, v in (d.get("stage_scales") or {}).items()},
            bp_scales={str(k): float(v)
                       for k, v in (d.get("bp_scales") or {}).items()},
            step_overhead_s=(None if d.get("step_overhead_s") is None
                             else float(d["step_overhead_s"])),
            n_samples=int(d.get("n_samples", 0)),
            n_rejected=int(d.get("n_rejected", 0)),
        )

    def summary(self) -> str:
        parts = [f"base={self.base}", f"samples={self.n_samples}",
                 f"rejected={self.n_rejected}"]
        for f in sorted(self.stage_scales):
            parts.append(f"{f}x{self.stage_scales[f]:.3g}")
        for impl in sorted(self.bp_scales):
            parts.append(f"bp[{impl}]x{self.bp_scales[impl]:.3g}")
        if self.step_overhead_s is not None:
            parts.append(f"step_overhead={self.step_overhead_s * 1e6:.0f}us")
        return " ".join(parts)


class CalibrationStore:
    """Accumulates (predicted, measured) samples and fits the overlay.

    Persistence rides `repro.filecache.JsonFileCache` (read-modify-write
    with atomic replace, best-effort on read-only filesystems), so traced
    runs in different processes — CI steps, bench CLIs, test subprocesses
    — accumulate into one file and any of them can fit. With persistence
    disabled (env "off" or a path-less cache) the store still works
    in-memory for the lifetime of the process.
    """

    _KEY_TAG = "cal"

    def __init__(self, cache: Optional[JsonFileCache] = None):
        self._cache = cache if cache is not None else JsonFileCache(
            "REPRO_CALIB_CACHE", "calibration_store.json")
        self._mem: Dict[tuple, List[dict]] = {}
        self._lock = threading.Lock()

    @property
    def persistent(self) -> bool:
        return self._cache.path() is not None

    def path(self) -> Optional[str]:
        return self._cache.path()

    # -- recording -----------------------------------------------------------

    def _key(self, system: str, stage: str, impl: str, schedule: str,
             reduce: str, precision: str, bucket: int) -> tuple:
        return (self._KEY_TAG, system, stage, impl, schedule, reduce,
                precision, int(bucket))

    def record(self, *, system: str, stage: str, impl: str, schedule: str,
               reduce: str, precision: str, bucket: int,
               predicted_s: float, measured_s: float,
               n_steps: Optional[int] = None,
               updates: Optional[float] = None) -> None:
        """Append one (predicted, measured) sample. Zero/negative sides are
        dropped (nothing to fit against)."""
        if measured_s <= 0 or predicted_s <= 0:
            return
        sample: dict = {"p": float(predicted_s), "m": float(measured_s)}
        if n_steps is not None:
            sample["k"] = int(n_steps)
        if updates is not None:
            sample["sz"] = float(updates)
        key = self._key(system, stage, impl, schedule, reduce, precision,
                        bucket)
        with self._lock:
            if self.persistent:
                cur = self._cache.get(key)
                cur = list(cur) if isinstance(cur, list) else []
                cur.append(sample)
                self._cache.put(key, cur[-MAX_SAMPLES_PER_KEY:])
            else:
                cur = self._mem.setdefault(key, [])
                cur.append(sample)
                del cur[:-MAX_SAMPLES_PER_KEY]

    def record_traced_run(self, plan, stage_seconds: Mapping[str, float],
                          system: MachineSpec = ABCI) -> None:
        """Deposit one traced run's per-stage wall times, predicted against
        what the traced engine actually EXECUTED: `build_traced` always
        runs the fused stage decomposition regardless of the plan's
        schedule, so batch plans record against their fused projection;
        a traced `IncrementalSession` records against the incremental
        point itself (whose cost already carries the per-delta terms)."""
        point = point_from_plan(plan)
        if point.schedule != "incremental":
            point = dataclasses.replace(point, schedule="fused", n_steps=1,
                                        y_chunks=None)
        g = plan.geometry
        bd = predict_point(g, point, system)
        bucket = size_bucket(g, point.grid)
        for stage, field in STAGE_FIELDS.items():
            measured = float(stage_seconds.get(stage, 0.0))
            if measured <= 0.0:
                continue
            predicted = float(getattr(bd, field))
            if stage == "stage.backproject":
                # The fitted bp scale multiplies ONLY the update-rate part
                # of Eq. 12 (`predict_point` rescales t_bp - t_h2d; the H2D
                # term is traffic, priced by bw_load) — record against the
                # same basis or the fit and its application disagree by
                # t_bp / (t_bp - t_h2d).
                predicted -= float(bd.t_h2d)
            self.record(
                system=system.name, stage=stage, impl=point.impl,
                schedule=point.schedule, reduce=point.reduce,
                precision=point.precision, bucket=bucket,
                predicted_s=predicted, measured_s=measured)

    def record_engine(self, g, point: PlanPoint, measured_s: float,
                      system: MachineSpec = ABCI) -> None:
        """Deposit one whole-engine measurement (planner/measure.py's
        refinement timings — one measurement path, two consumers). Engine
        rows feed the per-step dispatch-overhead fit: a pipelined run at
        n_steps=k against a fused run of the SAME problem isolates
        k * overhead."""
        bd = predict_point(g, point, system)
        self.record(
            system=system.name, stage="engine", impl=point.impl,
            schedule=point.schedule, reduce=point.reduce,
            precision=point.precision,
            bucket=size_bucket(g, point.grid),
            predicted_s=float(bd.t_runtime), measured_s=float(measured_s),
            n_steps=point.n_steps,
            updates=float(g.n_x) * g.n_y * g.n_z * g.n_proj)

    # -- reading / fitting ---------------------------------------------------

    def samples(self) -> Dict[tuple, List[dict]]:
        """All samples, file entries merged under in-memory ones."""
        out: Dict[tuple, List[dict]] = {}
        for key_str, entry in self._cache.entries().items():
            if not isinstance(entry, list):
                continue
            try:
                key = tuple(json.loads(key_str))
            except ValueError:
                continue
            if len(key) == 8 and key[0] == self._KEY_TAG:
                out[key] = [s for s in entry if isinstance(s, dict)]
        with self._lock:
            for key, entry in self._mem.items():
                out.setdefault(key, []).extend(entry)
        return out

    def n_samples(self, system: Optional[str] = None) -> int:
        return sum(len(v) for k, v in self.samples().items()
                   if system is None or k[1] == system)

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            path = self._cache.path()
        if path is not None:
            import os
            try:
                os.remove(path)
            except OSError:
                pass

    def fit(self, system: MachineSpec = ABCI,
            min_samples: int = MIN_SAMPLES) -> MachineCalibration:
        """Fit the overlay from every sample recorded against `system`'s
        constants. Stage constants pool across impl/schedule/precision
        keys (time-weighted); back-projection fits PER IMPL (that is the
        fitted GUPS factor); step overhead fits from fused-vs-stepped
        engine pairs on identical problems. Every constant is
        independently gated at `min_samples` survivors."""
        stage_pts: Dict[str, List[Tuple[float, float]]] = {}
        bp_pts: Dict[str, List[Tuple[float, float]]] = {}
        eng: Dict[tuple, Dict[str, list]] = {}
        for key, samples in self.samples().items():
            _, sysname, stage, impl, schedule, reduce, precision, _b = key
            if sysname != system.name:
                continue
            if stage == "engine":
                for s in samples:
                    sz = s.get("sz")
                    if sz is None:
                        continue
                    grp = eng.setdefault((impl, precision, reduce, sz),
                                         {"fused": [], "stepped": []})
                    k = int(s.get("k", 1))
                    if schedule == "fused" or k <= 1:
                        grp["fused"].append(s["m"])
                    else:
                        grp["stepped"].append((s["m"], k))
            elif stage == "stage.backproject":
                bp_pts.setdefault(impl, []).extend(
                    (s["p"], s["m"]) for s in samples)
            elif stage in STAGE_FIELDS:
                stage_pts.setdefault(STAGE_FIELDS[stage], []).extend(
                    (s["p"], s["m"]) for s in samples)

        stage_scales: Dict[str, float] = {}
        bp_scales: Dict[str, float] = {}
        n_used = n_rej = 0
        for field, pts in stage_pts.items():
            scale, used, rej = robust_scale(pts, min_samples)
            n_rej += rej
            if scale is not None:
                stage_scales[field] = scale
                n_used += used
        for impl, pts in bp_pts.items():
            scale, used, rej = robust_scale(pts, min_samples)
            n_rej += rej
            if scale is not None:
                bp_scales[impl] = scale
                n_used += used

        # per-step dispatch overhead: (stepped - fused) / k on the same
        # (impl, precision, reduce, problem) — the model term the analytic
        # STEP_OVERHEAD_S stands in for. Median over pairs, clipped >= 0.
        ests: List[float] = []
        for grp in eng.values():
            if not grp["fused"] or not grp["stepped"]:
                continue
            base = _median(grp["fused"])
            for m, k in grp["stepped"]:
                ests.append(max(0.0, (m - base) / k))
        step = _median(ests) if len(ests) >= min_samples else None

        return MachineCalibration(
            base=system.name, stage_scales=stage_scales,
            bp_scales=bp_scales, step_overhead_s=step,
            n_samples=n_used, n_rejected=n_rej)


# ---------------------------------------------------------------------------
# Process-default store: traced engines, sessions and the measured
# refinement record through this so one env var governs the whole loop.
# ---------------------------------------------------------------------------

_DEFAULT: Optional[CalibrationStore] = None
_EXPLICIT = False
_DEFAULT_LOCK = threading.Lock()


def default_store() -> CalibrationStore:
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = CalibrationStore()
        return _DEFAULT


def set_default_store(store: Optional[CalibrationStore]
                      ) -> Optional[CalibrationStore]:
    """Swap the process-default store (tests install a fresh one); returns
    the previous store. None resets to a lazily re-created default. An
    explicitly installed store records even without persistence (in-memory
    only) — the env off-switch governs only the implicit default."""
    global _DEFAULT, _EXPLICIT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, store
        _EXPLICIT = store is not None
        return prev


def _recording_enabled() -> bool:
    # An explicitly installed store (tests, CLIs) always records; the
    # lazily created default records only when REPRO_CALIB_CACHE gives it
    # a file (the env off-switch).
    return _EXPLICIT or default_store().persistent


def record_traced_run(plan, stage_seconds: Mapping[str, float],
                      system: MachineSpec = ABCI) -> None:
    """Default-store hook `build_traced` / traced sessions call after a
    run. No-op when REPRO_CALIB_CACHE disables the store."""
    if _recording_enabled():
        default_store().record_traced_run(plan, stage_seconds, system)


def record_engine_measurement(g, point: PlanPoint, measured_s: float,
                              system: MachineSpec = ABCI) -> None:
    """Default-store hook for planner/measure.py engine timings."""
    if _recording_enabled():
        default_store().record_engine(g, point, measured_s, system)


def default_calibration(system: MachineSpec = ABCI,
                        min_samples: int = MIN_SAMPLES
                        ) -> Optional[MachineCalibration]:
    """The default store's fitted overlay, or None when the store is
    disabled or no constant passed the sample gate (stock constants
    stand)."""
    store = default_store()
    if not store.persistent and not store._mem:
        return None
    cal = store.fit(system, min_samples)
    return None if cal.is_empty else cal


def resolve_calibration(calibration, system: MachineSpec
                        ) -> Tuple[Optional[MachineCalibration], MachineSpec]:
    """Normalize `auto_plan`'s calibration argument to (overlay, system).

    None         -> stock constants.
    "auto"       -> the default store's fit when enough samples exist.
    MachineCalibration -> used as given.
    MachineSpec  -> the caller already fitted constants: use them AS the
                    system, no overlay.
    """
    if calibration is None:
        return None, system
    if isinstance(calibration, MachineCalibration):
        return calibration, system
    if isinstance(calibration, MachineSpec):
        return None, calibration
    if calibration == "auto":
        return default_calibration(system), system
    raise ValueError(
        f"calibration must be None, 'auto', a MachineCalibration or a "
        f"MachineSpec; got {calibration!r}")
