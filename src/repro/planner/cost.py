"""Plan-aware cost model: Eqs. 8-19 specialized to a plan point.

`core/perf_model.predict` is the paper's model verbatim — f32 data, the
pipelined overlap of Eq. 17 baked in. A `ReconstructionPlan` moves every one
of those assumptions into a knob, so the planner's cost function re-derives
the terms per plan point:

  stream codec    load/AllGather/H2D bytes scale with the codec's wire
                  itemsize (perf_model's `storage_bytes`) plus the
                  per-projection scale sidecar of scaled codecs (fp8:
                  `sidecar_bytes`) — the SAME `Precision.wire_bytes`
                  formula the engine encodes with, so model and engine
                  agree on every wire byte.
  schedule        fused      — no overlap: T_compute is the SUM of the stage
                               times (one gather, one back-projection, no
                               Fig. 4 pipeline to hide anything behind);
                  pipelined  — Eq. 17 verbatim: T_compute = max(stages),
                               plus a per-micro-batch launch overhead so the
                               model does not ask for n_steps -> infinity;
                  chunked    — pipelined, plus the back-projection re-streams
                               the gathered projection batch once per y-chunk
                               (the Q^T tile is re-read for every output
                               chunk), an HBM-traffic term on T_bp.
                  incremental — the streaming session (build_incremental):
                               n_steps deltas arrive from OUTSIDE the
                               pipeline, so there is no intra-pipeline
                               overlap to model (overlap=False); the
                               scatter reduces run once PER DELTA (the
                               resident accumulator stays scattered),
                               multiplying the reduce term by n_steps,
                               while psum defers its one reduce to
                               finalize(). What the mode buys is latency,
                               not throughput — `time_from_last_delta`
                               below prices it.
  reduce          psum (allreduce) moves ~2x the bytes of psum_scatter per
                  rank (2(C-1)/C vs (C-1)/C ring traffic) — the volume
                  Reduce term sees the mode — and scatter_bf16 halves the
                  scatter bytes again (bf16 slabs on the wire, perf_model's
                  `reduce_bytes`). The mode also sets the PFS *writer*
                  count for T_write (Eq. 16, the shard store's
                  slice-per-rank files): the scatter modes leave the volume
                  sharded over R x data ranks that all stream their own
                  file, psum leaves one slab owner per row — R writers.
                  Visible only when `MachineSpec.bw_rank_io` caps per-rank
                  PFS links; with the paper's aggregate-bandwidth
                  assumption both modes saturate the filesystem equally.
  impl            relative back-projection throughput factors: the reference
                  projects full (u, v, w) coordinates per voxel (~8x the
                  factorized work, Alg. 2 vs Alg. 4); the Pallas kernel's
                  dual-slab streaming buys a modest margin over the
                  factorized einsum path.

All constants still come from `SystemConstants`; this module only decides
how the plan combines them.
"""
from __future__ import annotations

import dataclasses

from repro.core.distributed import (
    IFDKGrid, REDUCE_WIRE_ITEMSIZE, SCATTER_REDUCES,
)
from repro.core.geometry import CBCTGeometry
from repro.core.perf_model import (
    ABCI, MachineSpec, PerfBreakdown, predict,
)
from repro.core.precision import resolve_precision

# Back-projection throughput relative to `gups_bp` (measured for the
# factorized path). Ratios follow the repo's own roofline notes (Alg. 2
# recomputes the full projection per voxel; the dual-slab kernel halves the
# k-loop via Theorem 1) — they order the impls, they are not measurements.
IMPL_GUPS_FACTOR = {
    "reference": 0.125,
    "factorized": 1.0,
    "kernel": 1.25,
}

# Fixed cost per pipeline micro-batch (collective launch + scan-step
# overhead). Keeps the modeled optimum at a finite n_steps.
STEP_OVERHEAD_S = 2e-4


@dataclasses.dataclass(frozen=True)
class PlanPoint:
    """The planner's search coordinates: every plan knob the cost model and
    the feasibility model read, plus the rank grid it would run on.

    Decoupled from `ReconstructionPlan` so the planner can cost hypothetical
    deployments (a 2048-device grid) without building a mesh; `search.py`
    attaches a real plan when the mesh exists.

    `data_size` is the extent of the mesh's `data` axis — the axis
    reduce="scatter" actually shards over (the engine leaves the pod axis
    replicated). None means "unknown mesh": the feasibility model then
    assumes all C columns scatter, the single-pod case.
    """

    grid: IFDKGrid
    schedule: str = "fused"
    n_steps: int = 1
    y_chunks: int | None = None
    reduce: str = "psum"
    precision: str = "fp32"
    impl: str = "factorized"
    data_size: int | None = None

    def spec(self) -> str:
        """The `plan_from_spec` string reproducing this point."""
        items = [f"schedule={self.schedule}"]
        if self.schedule != "fused":
            items.append(f"n_steps={self.n_steps}")
        if self.y_chunks is not None:
            items.append(f"y_chunks={self.y_chunks}")
        items += [f"reduce={self.reduce}", f"precision={self.precision}",
                  f"impl={self.impl}"]
        return ",".join(items)


def point_from_plan(plan) -> PlanPoint:
    """Project a ReconstructionPlan onto the planner's search coordinates."""
    return PlanPoint(
        grid=plan.grid, schedule=plan.schedule, n_steps=plan.n_steps,
        y_chunks=plan.y_chunks, reduce=plan.reduce,
        precision=plan.resolved_precision().storage, impl=plan.impl,
        data_size=plan._data_size if plan.mesh is not None else None,
    )


def io_writers(point: PlanPoint) -> int:
    """Concurrent PFS writers of the volume under this plan: with a scatter
    reduce every rank of the R x data grid holds (and streams) its own
    disjoint piece; with psum the slab is replicated across the column, so
    one owner per row — R writers."""
    grid = point.grid
    if point.reduce in SCATTER_REDUCES:
        return grid.r * (point.data_size or grid.c)
    return grid.r


def allgather_wire_bytes(g: CBCTGeometry, point: PlanPoint) -> int:
    """Total bytes the column AllGather RECEIVES across all ranks under
    this plan: each of the R*C ranks ends up holding its column's N_p/C
    projections, (R-1)/R of which arrive over the wire, in the stream
    codec's format (quantized data + scale sidecar). Zero on a 1-rank
    column (nothing to gather). The engine-side counterpart is
    `EncodedStream.nbytes` of the gathered batches — one formula
    (`Precision.wire_bytes`) serves both."""
    grid = point.grid
    if grid.r == 1:
        return 0
    prec = resolve_precision(point.precision)
    per_rank = prec.wire_bytes(g.n_proj // grid.c, g.n_v, g.n_u)
    return grid.n_ranks * per_rank * (grid.r - 1) // grid.r


def reduce_wire_bytes(g: CBCTGeometry, point: PlanPoint) -> int:
    """Total bytes the row Reduce moves across all ranks under this plan.

    The accounting mirrors the engine's reduce_slab epilogue, which runs
    PER AXIS: psum is a full-slab f32 allreduce over the data axis and
    then over the pods (2(D-1)/D + 2(P-1)/P slab bytes per rank); the
    scatter modes psum_scatter over the DATA axis only — (D-1)/D slab
    bytes per rank at the mode's wire width (bf16 for scatter_bf16) —
    followed, on multi-pod grids, by an f32 psum of the already
    1/D-scattered slab across the C/D pods. `data_size=None` (unknown
    mesh) assumes the whole column is the data axis, the same convention
    as `io_writers`."""
    grid = point.grid
    if grid.c == 1:
        return 0
    slab4 = (g.n_x // grid.r) * g.n_y * g.n_z * 4
    d = point.data_size or grid.c
    pods = grid.c // d
    if point.reduce == "psum":
        per_rank = 2 * slab4 * (d - 1) // d
        if pods > 1:
            per_rank += 2 * slab4 * (pods - 1) // pods
        return grid.n_ranks * per_rank
    wire = slab4 * REDUCE_WIRE_ITEMSIZE[point.reduce] // 4
    per_rank = wire * (d - 1) // d
    if point.schedule == "incremental":
        # the resident accumulator stays scattered: every delta
        # psum_scatters its full-width partial slab — n_steps scatters
        # instead of one (the price of bounded streaming state).
        per_rank *= max(1, point.n_steps)
    if pods > 1:     # f32 cross-pod finish on the scattered slab
        per_rank += 2 * (slab4 // d) * (pods - 1) // pods
    return grid.n_ranks * per_rank


def predict_point(g: CBCTGeometry, point: PlanPoint,
                  system: MachineSpec = ABCI,
                  calibration=None) -> PerfBreakdown:
    """Plan-aware Eqs. 8-19: the paper model with the plan's knobs applied.

    `calibration` (a planner.calibrate.MachineCalibration, or None) anchors
    the constants to this host's measured stage times: the stage-scale
    overlay re-derives the filter/AllGather/reduce/PFS constants
    (MachineSpec.with_overlay), the per-impl back-projection scale corrects
    the analytic IMPL_GUPS_FACTOR ordering with fitted evidence, and the
    fitted per-step dispatch overhead replaces STEP_OVERHEAD_S. Unfitted
    constants keep their stock values, so calibration=None reproduces the
    uncalibrated model bit-for-bit."""
    step_overhead = STEP_OVERHEAD_S
    if calibration is not None:
        system = calibration.apply(system)
        step_overhead = calibration.step_overhead()
    prec = resolve_precision(point.precision)
    sb = float(prec.storage_bytes)
    grid = point.grid
    base = predict(
        g, grid, system, storage_bytes=sb,
        sidecar_bytes=float(prec.sidecar_bytes(g.n_proj)),
        reduce_bytes=float(REDUCE_WIRE_ITEMSIZE[point.reduce]))

    # impl-aware back-projection: rescale the update-rate part of Eq. 12
    # (t_bp = t_h2d + updates/gups); the H2D part is traffic, not compute.
    factor = IMPL_GUPS_FACTOR.get(point.impl)
    if factor is None:
        raise ValueError(
            f"unknown impl {point.impl!r}; choose from "
            f"{sorted(IMPL_GUPS_FACTOR)}")
    t_update = (base.t_bp - base.t_h2d) / factor
    if calibration is not None:
        bp_scale = calibration.bp_scale(point.impl)
        if bp_scale is not None:
            t_update *= bp_scale
    t_bp = base.t_h2d + t_update

    # chunked: the gathered Q^T batch is re-streamed from HBM once per
    # y-chunk (each output chunk reads every projection of the batch), so
    # (y_chunks - 1) extra passes over the per-column projection bytes.
    if point.schedule == "chunked":
        y_chunks = point.y_chunks or 1
        qt_bytes = sb * g.n_u * g.n_v * (g.n_proj / grid.c)
        t_bp += (y_chunks - 1) * qt_bytes / (system.bw_hd
                                             * system.n_hd_links)

    # pipelined/chunked: per-micro-batch launch overhead (finite n_steps).
    if point.schedule != "fused":
        t_bp += point.n_steps * step_overhead

    # reduce-mode-aware volume traffic: ring allreduce (psum) moves
    # 2(C-1)/C x the slab bytes per rank, reduce-scatter (C-1)/C x.
    c = grid.c
    if c == 1:
        t_reduce = 0.0
    else:
        ring = (c - 1) / c
        t_reduce = base.t_reduce * ring * (2.0 if point.reduce == "psum"
                                           else 1.0)
        if (point.schedule == "incremental"
                and point.reduce in SCATTER_REDUCES):
            # one full-width psum_scatter per delta (reduce_wire_bytes).
            t_reduce *= max(1, point.n_steps)

    # T_write (Eq. 16) with the plan's writer count: the shard store's
    # slice-per-rank files mean the scatter epilogue brings R*C_data
    # concurrent writers to the PFS, psum only R. Only bites when per-rank
    # links are the bottleneck (bw_rank_io set); under the paper's
    # aggregate assumption base.t_store already has the R-writer price.
    t_store = (4.0 * g.n_x * g.n_y * g.n_z
               / system.agg_write_bw(io_writers(point)))

    # Overlap needs something to overlap WITH: a pipelined/chunked schedule
    # at n_steps=1 degenerates to one gather + one back-projection (the
    # engine's scan has zero steps), so Eq. 17's max only applies when the
    # stream is actually micro-batched. The incremental schedule never
    # overlaps internally — its deltas arrive from outside the pipeline.
    return dataclasses.replace(
        base, t_bp=t_bp, t_reduce=t_reduce, t_store=t_store,
        overlap=(point.schedule in ("pipelined", "chunked")
                 and point.n_steps > 1),
    )


def time_from_last_delta(g: CBCTGeometry, point: PlanPoint,
                         system: MachineSpec = ABCI,
                         calibration=None) -> float:
    """Modeled seconds from the LAST projection landing to the finished
    volume under an incremental plan — the streaming mode's figure of merit
    (benchmarks/bench_streaming.py measures it). The arrival-side stages of
    the final delta (filter + encode + AllGather — per-projection
    independent, `IncrementalSession.stage`) overlap the tail of
    acquisition, so the modeled tail is one delta's back-projection fold,
    plus the finalize epilogue (the per-delta psum_scatter under the
    scatter reduces; the single deferred reduce under psum) and the store.
    The batch counterpart is the full plan's `t_runtime` — streaming wins
    when this is ~1/n_steps of that."""
    if point.schedule != "incremental":
        raise ValueError(
            f"time_from_last_delta prices schedule='incremental' points, "
            f"got {point.schedule!r}")
    bd = predict_point(g, point, system, calibration)
    step_overhead = (STEP_OVERHEAD_S if calibration is None
                     else calibration.step_overhead())
    n = max(1, point.n_steps)
    # one delta's fold: the per-delta slice of the BP stage (+ the one
    # per-micro-batch overhead predict_point charged n times). The staged
    # arrival work (t_flt, t_allgather, t_h2d) rode along with acquisition.
    per_delta = ((bd.t_bp - bd.t_h2d - n * step_overhead) / n
                 + step_overhead)
    if point.reduce in SCATTER_REDUCES:
        finalize = bd.t_reduce / n          # the last delta's scatter
    else:
        finalize = bd.t_reduce              # psum deferred to finalize()
    return per_delta + finalize + bd.t_d2h + bd.t_store


def predict_plan(plan, system: MachineSpec = ABCI,
                 calibration=None) -> PerfBreakdown:
    """Plan-aware cost of a concrete ReconstructionPlan."""
    return predict_point(plan.geometry, point_from_plan(plan), system,
                         calibration)
