"""Per-device memory-footprint model + feasibility pruning.

The paper sizes its grid from memory first (Eq. 5-7: R is the smallest slab
count whose sub-volume fits a GPU) and only then optimizes time. This module
is that first stage for the full plan space: a byte model of what ONE device
holds live at the peak of each schedule, checked against an HBM budget, plus
the kernel-level VMEM fit (tune.vmem_bytes) for impl="kernel".

Footprint terms (per device, peak):

  proj_shard  raw f32 input shard, N_p/(R*C) projections (Eq. 5 load split).
  gathered    the post-AllGather filtered column batch in the stream
              codec's WIRE format — quantized data plus the per-projection
              scale sidecar of scaled codecs (fp8), the same
              `Precision.wire_bytes` the engine gathers:
              N_p/(C*n_steps) projections — double-buffered under the
              pipelined/chunked schedules (batch s gathers while s-1
              back-projects, Fig. 4).
  slab        live volume accumulator state (f32):
                fused      one (N_x/R, N_y, N_z) slab (the BP output);
                pipelined  2x — the scan carry accumulator plus the current
                           batch's BP output before the add;
                chunked    the accumulator (scattered over the data axis
                           under the scatter reduces — the whole point of
                           the schedule) plus 2 chunk-sized partials; the
                           compensated reduce (scatter_bf16) additionally
                           carries a full-slab f32 error-feedback buffer.
                incremental the RESIDENT session state (core/plan.py
                           IncrementalSession) — old + new accumulator
                           live across the fold (no donation): 2x the
                           full slab under psum, 2x the 1/data-scattered
                           slab plus one full-width per-delta partial
                           under the scatter reduces; scatter_bf16 adds
                           the full-slab f32 error-feedback carry.
  temps       filter workspace: the per-step local batch at f32 plus its
              FFT pad (~2x).

The model is deliberately coarse — it decides FEASIBILITY (can this plan
run at all), not allocation; a ~1.5x XLA workspace margin is the caller's
business via the budget it passes.
"""
from __future__ import annotations

import dataclasses

from repro.core.distributed import SCATTER_REDUCES
from repro.core.geometry import CBCTGeometry
from repro.core.precision import resolve_precision

from .cost import PlanPoint

# Default per-device HBM budget: 16 GiB (v5e chip / paper's V100).
DEFAULT_HBM_BYTES = 16 * 2**30


@dataclasses.dataclass(frozen=True)
class MemoryFootprint:
    """Peak live bytes on one device, by pipeline stage."""

    proj_shard: int
    gathered: int
    slab: int
    temps: int

    @property
    def total(self) -> int:
        return self.proj_shard + self.gathered + self.slab + self.temps


def plan_footprint(g: CBCTGeometry, point: PlanPoint) -> MemoryFootprint:
    grid = point.grid
    prec = resolve_precision(point.precision)
    pix = g.n_u * g.n_v
    scatter = point.reduce in SCATTER_REDUCES

    np_local = g.n_proj // grid.n_ranks          # loaded per rank (Eq. 5)
    proj_shard = np_local * pix * 4

    np_step_col = g.n_proj // (grid.c * point.n_steps)   # gathered per step
    # fused gathers once; pipelined/chunked double-buffer (batch s gathers
    # while s-1 back-projects); incremental holds one delta at a time (its
    # deltas arrive from outside — nothing to overlap with).
    buffers = 1 if point.schedule in ("fused", "incremental") else 2
    # Wire format: quantized data + scale sidecar (the same bytes the
    # engine's gather_batch holds after the AllGather).
    gathered = buffers * prec.wire_bytes(np_step_col, g.n_v, g.n_u)

    nx_slab = g.n_x // grid.r
    slab_f32 = nx_slab * g.n_y * g.n_z * 4
    if point.schedule == "fused":
        slab = slab_f32
    elif point.schedule == "pipelined":
        slab = 2 * slab_f32
    elif point.schedule == "incremental":
        # Resident session state: the fold returns a NEW accumulator while
        # the old one is still live (no donation), so 2x the resident acc;
        # the scatter modes keep the acc 1/data-scattered but materialize
        # one full-width partial per delta before its psum_scatter.
        scatter_div = (point.data_size or grid.c) if scatter else 1
        slab = 2 * slab_f32 // scatter_div
        if scatter:
            slab += slab_f32
    else:  # chunked
        y_chunks = point.y_chunks or 1
        # The engine's accumulator is scattered over the DATA axis only
        # (the pod axis finishes with a replicated psum) — grid.c is the
        # right divisor only when the whole column group is the data axis.
        scatter_div = (point.data_size or grid.c) if scatter else 1
        chunk = nx_slab * (g.n_y // y_chunks) * g.n_z * 4
        slab = slab_f32 // scatter_div + 2 * chunk
    if point.reduce == "scatter_bf16":
        # The half-width reduce is not free in memory: chunked (and the
        # incremental session, which turns the same carry along the time
        # axis) holds the full-slab f32 error-feedback buffer;
        # fused/pipelined materialize a bf16 copy of the slab for the wire.
        slab += (slab_f32 if point.schedule in ("chunked", "incremental")
                 else slab_f32 // 2)

    temps = 2 * (np_local // max(1, point.n_steps)) * pix * 4
    return MemoryFootprint(proj_shard, gathered, slab, temps)


def check_feasible(g: CBCTGeometry, point: PlanPoint,
                   hbm_bytes: int = DEFAULT_HBM_BYTES,
                   vmem_budget: int | None = None) -> tuple[bool, str]:
    """(feasible, reason). reason is "" when feasible, else human-readable.

    Checks the HBM footprint model and, for impl="kernel", whether ANY
    (bi, bj, bs) tiling of the per-call back-projection fits the VMEM
    budget (kernels/backproject/tune.py working-set model).
    """
    fp = plan_footprint(g, point)
    if fp.total > hbm_bytes:
        return False, (
            f"footprint {fp.total / 2**30:.2f} GiB exceeds the HBM budget "
            f"of {hbm_bytes / 2**30:.2f} GiB (proj {fp.proj_shard >> 20} MiB"
            f" + gathered {fp.gathered >> 20} MiB + slab {fp.slab >> 20} MiB"
            f" + temps {fp.temps >> 20} MiB)")
    if point.impl == "kernel":
        if g.n_z % 2:
            return False, f"impl='kernel' requires even N_z, got {g.n_z}"
        from repro.core.plan import bp_call_shape
        from repro.kernels.backproject import tune
        grid = point.grid
        nx_call, ny_call, np_call = bp_call_shape(
            g, grid.r, grid.c, point.schedule, point.n_steps,
            point.y_chunks)
        prec = resolve_precision(point.precision)
        budget = tune.DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
        need = tune.min_vmem_bytes(nx_call, ny_call, np_call, g.n_u, g.n_v,
                                   g.n_z // 2, qt_dtype=prec.storage_dtype)
        if need > budget:
            return False, (
                f"no kernel tiling of ({nx_call}, {ny_call}, Np={np_call}) "
                f"fits VMEM: minimal working set {need} B > budget "
                f"{budget} B")
    return True, ""
