"""Plan search: enumerate, prune, rank — return top-k PlanProposals.

The search space is the cross-product the plan layer exposes:

  grid       R x C factorizations (core/distributed.grid_candidates) when
             searching over a device count; fixed by the mesh otherwise.
  schedule   fused | pipelined | chunked (+ n_steps, y_chunks candidates).
             The streaming "incremental" schedule is priced and rankable
             but only enumerated when PINNED (schedule="incremental"):
             its plans build stateful sessions (`build_incremental()`),
             not batch callables, so the default search must never hand
             one to a caller expecting `plan.build()` — and its figure of
             merit is latency (cost.time_from_last_delta), which the
             throughput ranking below does not capture.
  reduce     psum | scatter | scatter_bf16 (half-width compensated scatter)
  precision  fp32 | bf16 | fp16 | fp8_e4m3 | fp8_e5m2 (quarter-width +
             scale sidecar; e5m2 trades one mantissa bit for range)
  impl       factorized | kernel (| reference)

Candidates that violate the pipeline's divisibility rules are skipped (for
mesh-backed searches `ReconstructionPlan.validate()` is the authority);
survivors are priced by the plan-aware cost model (cost.py), pruned by the
per-device memory model (feasibility.py), and ranked by modeled runtime
quantized to ~1% buckets (the model's resolution — see
`_quantized_predicted`). Ties (the overlap model is a max — plans off the
bottleneck cost the same — and anything within a percent counts as tied)
break toward accuracy and simplicity: wider storage first, then
fused < pipelined < chunked, fewer micro-batches, psum before scatter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

from repro.core.distributed import IFDKGrid, SCATTER_REDUCES, grid_candidates
from repro.core.geometry import CBCTGeometry
from repro.core.perf_model import (
    ABCI, MachineSpec, PerfBreakdown, gups_end_to_end,
)
from repro.core.precision import resolve_precision

from .cost import PlanPoint, predict_point
from .feasibility import DEFAULT_HBM_BYTES, MemoryFootprint, check_feasible, \
    plan_footprint

_SCHEDULE_ORDER = ("fused", "pipelined", "chunked")
# Ranking knows every schedule, including the pin-only streaming one.
_RANK_SCHEDULE_ORDER = _SCHEDULE_ORDER + ("incremental",)
_REDUCE_ORDER = ("psum", "scatter", "scatter_bf16")
# Tie-break order within equal wire width: e4m3 before e5m2 (one extra
# mantissa bit ~= 6 dB PSNR at the same bytes; e5m2 wins only when pinned
# for its exponent range).
_PRECISION_ORDER = ("fp32", "bf16", "fp16", "fp8_e4m3", "fp8_e5m2")

DEFAULT_N_STEPS = (1, 2, 4, 8)
DEFAULT_Y_CHUNKS = (2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class PlanProposal:
    """One ranked search result: the plan point, its modeled cost and
    footprint, and — when the search had a mesh — a buildable plan."""

    point: PlanPoint
    breakdown: PerfBreakdown
    footprint: MemoryFootprint
    feasible: bool
    reason: str = ""
    plan: Optional[object] = None       # ReconstructionPlan when mesh-backed
    measured: Optional[float] = None    # seconds/call (planner/measure.py)

    @property
    def predicted(self) -> float:
        return self.breakdown.t_runtime

    def spec(self) -> str:
        return self.point.spec()

    def predicted_gups(self, g: CBCTGeometry) -> float:
        return gups_end_to_end(g, self.breakdown)


def _quantized_predicted(seconds: float) -> float:
    """Predicted runtime rounded to ~1% log-buckets for ranking.

    The cost model's resolution is no better than a percent or so — the
    overlap model is a max over stages, calibration fits carry residuals
    around 5-10%, and real codec/dispatch overheads are unmodeled.
    Ranking on raw floats lets sub-noise differences (e.g. a calibrated
    overlay shaving 0.3% off an fp8 candidate's allgather) outvote the
    deterministic tie-breaks that prefer wider storage and simpler
    impls — exactly the candidates whose unmodeled overheads bite.
    Bucketing the predicted term means "within ~1%" ranks as a tie and
    falls through to those stable preferences.
    """
    if seconds <= 0.0:
        return float("-inf")
    return round(math.log(seconds, 1.01))


def _rank_key(p: PlanProposal):
    pt = p.point
    return (
        not p.feasible,
        _quantized_predicted(p.predicted),
        -resolve_precision(pt.precision).storage_bytes,
        _PRECISION_ORDER.index(pt.precision),
        _RANK_SCHEDULE_ORDER.index(pt.schedule),
        pt.n_steps,
        pt.y_chunks or 0,
        _REDUCE_ORDER.index(pt.reduce),
        {"factorized": 0, "kernel": 1, "reference": 2}.get(pt.impl, 3),
        pt.grid.r,
    )


def enumerate_points(g: CBCTGeometry, grid: IFDKGrid, *,
                     schedules: Sequence[str] = _SCHEDULE_ORDER,
                     reduces: Sequence[str] = _REDUCE_ORDER,
                     precisions: Sequence[str] = _PRECISION_ORDER,
                     impls: Sequence[str] = ("factorized", "kernel"),
                     n_steps_candidates: Sequence[int] = DEFAULT_N_STEPS,
                     y_chunks_candidates: Sequence[int] = DEFAULT_Y_CHUNKS,
                     data_size: int | None = None,
                     ) -> Iterable[PlanPoint]:
    """All divisibility-valid plan points on one grid. `data_size` stamps
    the mesh's `data` axis extent onto the points (see PlanPoint)."""
    if g.n_proj % grid.n_ranks or g.n_x % grid.r:
        return
    np_local = g.n_proj // grid.n_ranks
    for schedule in schedules:
        steps = ([1] if schedule == "fused" else
                 [s for s in n_steps_candidates if np_local % s == 0])
        chunk_opts = ([None] if schedule != "chunked" else
                      [y for y in y_chunks_candidates if g.n_y % y == 0])
        for n_steps in steps:
            for y_chunks in chunk_opts:
                for reduce in reduces:
                    if reduce in SCATTER_REDUCES and grid.c == 1:
                        continue  # nothing to scatter over
                    for precision in precisions:
                        for impl in impls:
                            if impl == "kernel" and g.n_z % 2:
                                continue
                            yield PlanPoint(
                                grid=grid, schedule=schedule,
                                n_steps=n_steps, y_chunks=y_chunks,
                                reduce=reduce, precision=precision,
                                impl=impl, data_size=data_size)


def _propose(g: CBCTGeometry, point: PlanPoint,
             system: MachineSpec, hbm_bytes: int,
             vmem_budget: int | None, plan=None,
             calibration=None) -> PlanProposal:
    feasible, reason = check_feasible(g, point, hbm_bytes, vmem_budget)
    return PlanProposal(
        point=point,
        breakdown=predict_point(g, point, system, calibration),
        footprint=plan_footprint(g, point), feasible=feasible,
        reason=reason, plan=plan)


def search_grids(g: CBCTGeometry, n_devices: int, *,
                 system: MachineSpec = ABCI,
                 hbm_bytes: int = DEFAULT_HBM_BYTES,
                 vmem_budget: int | None = None,
                 top_k: int | None = 8, include_infeasible: bool = False,
                 calibration=None,
                 **enumerate_kwargs) -> list[PlanProposal]:
    """Rank the full (grid x plan) space for a hypothetical deployment of
    `n_devices` — no mesh is built, so proposals carry no buildable plan
    (this is the dry-run CLI path, benchmarks/plan_search.py)."""
    grids = grid_candidates(g, n_devices)
    if not grids:
        raise ValueError(
            f"no rectangular R x C deployment of {n_devices} ranks tiles "
            f"this geometry: need {n_devices} | N_p={g.n_proj} and some "
            f"divisor R of {n_devices} with R | N_x={g.n_x}")
    proposals = []
    for grid in grids:
        for point in enumerate_points(g, grid, **enumerate_kwargs):
            proposals.append(
                _propose(g, point, system, hbm_bytes, vmem_budget,
                         calibration=calibration))
    proposals.sort(key=_rank_key)
    if not include_infeasible:
        proposals = [p for p in proposals if p.feasible]
    return proposals[:top_k]


def search_plans(g: CBCTGeometry, mesh=None, *,
                 system: MachineSpec = ABCI,
                 hbm_bytes: int = DEFAULT_HBM_BYTES,
                 vmem_budget: int | None = None,
                 top_k: int | None = 8, include_infeasible: bool = False,
                 window: str = "ramlak", calibration=None,
                 **enumerate_kwargs) -> list[PlanProposal]:
    """Rank buildable plans on a concrete mesh (or single device).

    Every proposal's `plan` is a `ReconstructionPlan` that has passed
    `validate()`; candidates validate() rejects (scatter without a data
    axis, chunk extents that do not divide over it, ...) are dropped.
    """
    from repro.core.plan import ReconstructionPlan
    from repro.parallel.mesh import AXIS_DATA, axis_size

    if mesh is None or AXIS_DATA not in mesh.axis_names:
        enumerate_kwargs.setdefault("reduces", ("psum",))
    else:
        enumerate_kwargs.setdefault("data_size",
                                    axis_size(mesh, AXIS_DATA))
    grid = ReconstructionPlan(geometry=g, mesh=mesh).grid

    proposals = []
    for point in enumerate_points(g, grid, **enumerate_kwargs):
        plan = ReconstructionPlan(
            geometry=g, mesh=mesh, impl=point.impl, window=window,
            precision=point.precision, schedule=point.schedule,
            n_steps=point.n_steps, y_chunks=point.y_chunks,
            reduce=point.reduce, vmem_budget=vmem_budget)
        try:
            plan.validate()
        except ValueError:
            continue
        proposals.append(
            _propose(g, point, system, hbm_bytes, vmem_budget, plan=plan,
                     calibration=calibration))
    proposals.sort(key=_rank_key)
    if not include_infeasible:
        proposals = [p for p in proposals if p.feasible]
    return proposals[:top_k]


def admitted_impls(calibration=None) -> tuple[str, ...]:
    """The impl axis auto selection ranks on THIS backend.

    On TPU both deployment impls compete on their analytic factors. Off
    TPU, interpret-mode Pallas is not a deployment target, so the
    analytic kernel factor (tuned for TPU) must not rank it — but
    measured evidence overrides the prior: once the calibration store
    has fitted a kernel factor that beats reference's on this host, the
    kernel competes on its fitted number (pin impl="kernel" to force it
    regardless). Callers replicating auto_plan's search (e.g.
    benchmarks/plan_search.py's ranking-quality rows) should use this
    instead of the raw enumerate default, or an unfitted impl can win a
    calibrated ranking on pure stock optimism.
    """
    import jax

    if jax.default_backend() == "tpu":
        return ("factorized", "kernel")
    impls = ["factorized"]
    if calibration is not None and calibration.admits_impl("kernel"):
        impls.append("kernel")
    return tuple(impls)


def auto_plan(g: CBCTGeometry, mesh=None, *,
              system: MachineSpec = ABCI,
              hbm_bytes: int = DEFAULT_HBM_BYTES,
              vmem_budget: int | None = None,
              measure: bool = False, top_k: int = 8,
              window: str = "ramlak", calibration="auto", **pins):
    """The `plan_from_spec(g, "auto")` resolver: best feasible plan for
    (geometry, mesh, HBM budget) under the model — optionally refined by
    timing the top-k built engines (planner/measure.py).

    `calibration` anchors the cost constants to this host:
      "auto" (default)     — the calibration store's fitted overlay when
                             enough traced samples exist (planner/
                             calibrate.py), stock constants otherwise;
      a MachineCalibration — used as given;
      a MachineSpec        — caller-supplied constants, no overlay;
      None                 — stock constants, calibration off.

    `pins` fix search dimensions the caller chose (e.g. precision="bf16"
    restricts the precision axis; n_steps=4 the micro-batching). Raises
    ValueError when no candidate is both valid and feasible.
    """
    from .calibrate import resolve_calibration

    cal, system = resolve_calibration(calibration, system)

    kw = {}
    schedule = pins.pop("schedule", None)
    if "reduce" in pins:
        kw["reduces"] = (pins.pop("reduce"),)
    if "precision" in pins:
        prec = resolve_precision(pins.pop("precision"))
        kw["precisions"] = (prec.storage,)
    if "impl" in pins:
        kw["impls"] = (pins.pop("impl"),)
    else:
        kw["impls"] = admitted_impls(cal)
    # n_steps/y_chunks pins also constrain the SCHEDULE axis — a schedule
    # that ignores the knob (fused has no micro-batching, only chunked has
    # y-chunks) must not compete and silently win with the pin dropped.
    n_steps = pins.pop("n_steps", None)
    y_chunks = pins.pop("y_chunks", None)
    if n_steps is not None:
        kw["n_steps_candidates"] = (n_steps,)
        if n_steps > 1:
            if schedule == "fused":
                raise ValueError(
                    "auto-plan pins conflict: the fused schedule has no "
                    f"micro-batching to pin n_steps={n_steps} to")
            schedule_pool = (schedule,) if schedule else ("pipelined",
                                                          "chunked")
            kw["schedules"] = schedule_pool
    if y_chunks is not None:
        if schedule not in (None, "chunked"):
            raise ValueError(
                "auto-plan pins conflict: y_chunks only applies to the "
                f"chunked schedule, not {schedule!r}")
        kw["y_chunks_candidates"] = (y_chunks,)
        kw["schedules"] = ("chunked",)
    if schedule is not None and "schedules" not in kw:
        kw["schedules"] = (schedule,)
    if pins:
        raise ValueError(
            f"auto-plan cannot pin {sorted(pins)}; pinnable dimensions: "
            "schedule, reduce, precision, impl, n_steps, y_chunks")

    candidates = search_plans(
        g, mesh, system=system, hbm_bytes=hbm_bytes,
        vmem_budget=vmem_budget, top_k=None, include_infeasible=True,
        window=window, calibration=cal, **kw)
    if not candidates:
        raise ValueError(
            "auto-plan found no valid candidate for this (geometry, mesh) "
            "under the pinned dimensions — check the pipeline divisibility "
            f"rules (N_p={g.n_proj} over the ranks and n_steps, "
            f"N_y={g.n_y} over y_chunks, scatter needs a data axis) "
            "and loosen the pins")
    feasible = [p for p in candidates if p.feasible]
    if not feasible:
        worst = candidates[0]
        raise ValueError(
            f"all {len(candidates)} candidate plans exceed the memory "
            f"budget (HBM = {hbm_bytes / 2**30:.2f} GiB) — best-ranked "
            f"[{worst.spec()}]: {worst.reason}; raise the budget or loosen "
            "the pinned dimensions")
    proposals = feasible[:top_k]
    if measure and schedule != "incremental":
        # incremental plans build sessions, not batch callables — there is
        # no single engine call for refine() to time.
        from .measure import refine
        proposals = refine(g, proposals)
    return proposals[0].plan
