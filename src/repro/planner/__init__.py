"""Auto-planner: perf-model-driven plan search (ROADMAP "Auto-scheduling").

Turns the paper's §4.2 performance model into a decision engine: enumerate
the grid x schedule x reduce x precision x impl space a
`ReconstructionPlan` exposes, prune what cannot fit in device memory, rank
the survivors by modeled runtime (Eq. 17-19, plan-aware), and optionally
refine the top-k by timing the built engines.

    from repro.planner import auto_plan, search_plans, search_grids
    plan = auto_plan(geometry, mesh)            # best feasible plan
    table = search_grids(geometry, n_devices=256, include_infeasible=True)

or, one string from anywhere the plan API reaches:

    plan = plan_from_spec(geometry, "auto", mesh=mesh)
    plan = plan_from_spec(geometry, "auto,precision=bf16")   # pinned axis
"""
from .calibrate import CalibrationStore, MachineCalibration, \
    default_calibration, default_store, record_traced_run, \
    resolve_calibration, set_default_store
from .cost import IMPL_GUPS_FACTOR, PlanPoint, point_from_plan, \
    predict_plan, predict_point
from .feasibility import DEFAULT_HBM_BYTES, MemoryFootprint, \
    check_feasible, plan_footprint
from .measure import measure_proposal, refine
from .search import PlanProposal, admitted_impls, auto_plan, \
    enumerate_points, search_grids, search_plans

__all__ = [
    "CalibrationStore", "MachineCalibration", "default_calibration",
    "default_store", "record_traced_run", "resolve_calibration",
    "set_default_store",
    "IMPL_GUPS_FACTOR", "PlanPoint", "point_from_plan", "predict_plan",
    "predict_point", "DEFAULT_HBM_BYTES", "MemoryFootprint",
    "check_feasible", "plan_footprint", "measure_proposal", "refine",
    "PlanProposal", "admitted_impls", "auto_plan", "enumerate_points",
    "search_grids", "search_plans",
]
