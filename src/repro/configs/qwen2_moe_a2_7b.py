"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed top-4 + 4 shared.

Shared experts are modelled as 4 swiglu experts of d_ff 1408 merged into one
5632-wide dense MLP (hf: shared_expert_intermediate_size = 5632), with the
routed experts at d_ff_expert = 1408.
"""
from repro.models.config import ModelConfig, MoEConfig, SubLayer

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                       # per-expert (assignment convention)
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pattern=(SubLayer(kind="attn", ffn="moe"),),
    moe=MoEConfig(
        num_experts=60, top_k=4, d_ff_expert=1408,
        num_shared_experts=4, d_ff_shared=1408,
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=4, d_ff_expert=32,
                      num_shared_experts=2, d_ff_shared=32,
                      capacity_factor=8.0),
    )
