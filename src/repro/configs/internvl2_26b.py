"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT frontend + InternLM2-20B.

Backbone only, per the assignment: the InternViT-6B encoder is a STUB;
input_specs() delivers precomputed patch embeddings (256 tokens x 3200 after
pixel-shuffle) and the trained 2-layer MLP projector maps them into the LLM.
"""
from repro.models.config import FrontendConfig, ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    frontend=FrontendConfig(modality="vision", d_frontend=3200,
                            num_positions=256),
    source="arXiv:2404.16821; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        frontend=FrontendConfig(modality="vision", d_frontend=48,
                                num_positions=8),
    )
