"""InternLM2-20B [arXiv:2403.17297; hf] — dense, GQA kv=8."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    source="arXiv:2403.17297; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=4,
        d_ff=128, vocab_size=256,
    )
