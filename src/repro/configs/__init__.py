"""Assigned architecture configs (+ the paper's FDK problem configs).

Each module exposes CONFIG (the exact published configuration) and
smoke_config() (a reduced same-family config for CPU smoke tests).
`get_config(name)` / `list_archs()` are the registry used by --arch.
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

ARCHS = [
    "qwen2_1_5b",
    "deepseek_coder_33b",
    "yi_6b",
    "internlm2_20b",
    "qwen2_moe_a2_7b",
    "mixtral_8x7b",
    "jamba_1_5_large",
    "mamba2_130m",
    "internvl2_26b",
    "musicgen_large",
]

_ALIASES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-6b": "yi_6b",
    "internlm2-20b": "internlm2_20b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-130m": "mamba2_130m",
    "internvl2-26b": "internvl2_26b",
    "musicgen-large": "musicgen_large",
}


def _module(name: str):
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(ARCHS)
