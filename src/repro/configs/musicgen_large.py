"""MusicGen-Large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

Backbone only: the EnCodec tokenizer and T5 text conditioning are stubs.
The 4 RVQ codebooks are summed at input (4 embedding tables) and predicted
with 4 output heads over the 2048-entry codebook (delay pattern handled by
the data pipeline, not the backbone).
"""
from repro.models.config import FrontendConfig, ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,                  # MHA
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    frontend=FrontendConfig(modality="audio", num_positions=4),
    source="arXiv:2306.05284; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=64,
        frontend=FrontendConfig(modality="audio", num_positions=4),
    )
