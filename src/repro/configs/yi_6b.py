"""Yi-6B [arXiv:2403.04652; hf] — llama-arch dense, GQA kv=4."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    source="arXiv:2403.04652; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=4,
        d_ff=128, vocab_size=256,
    )
