"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-arch dense, GQA kv=8."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    source="arXiv:2401.14196; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=160, vocab_size=256,
    )
