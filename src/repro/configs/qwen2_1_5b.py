"""Qwen2-1.5B [arXiv:2407.10671; hf] — dense, GQA (kv=2), QKV bias."""
from repro.models.config import ModelConfig, SubLayer

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pattern=(SubLayer(kind="attn", ffn="mlp"),),
    tie_embeddings=True,           # Qwen2-1.5B ties embeddings
    source="arXiv:2407.10671; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
    )
