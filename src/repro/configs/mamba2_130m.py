"""Mamba2-130M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.models.config import ModelConfig, SSMConfig, SubLayer

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,                      # attention-free
    num_kv_heads=0,
    d_ff=0,                           # Mamba blocks have no separate MLP
    vocab_size=50280,
    tie_embeddings=True,
    pattern=(SubLayer(kind="ssm", ffn="none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060; unverified",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
    )
