"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8 experts top-2, sliding window."""
from repro.models.config import ModelConfig, MoEConfig, SubLayer

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,                      # per-expert
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,             # SWA -> sub-quadratic, runs long_500k
    pattern=(SubLayer(kind="attn", ffn="moe"),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    source="arXiv:2401.04088; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256, sliding_window=32,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=8.0),
    )
