"""Jamba-1.5-Large (398B) [arXiv:2403.19887; hf] — hybrid Mamba+attention.

72 sub-layers = 9 Jamba blocks of 8: attention at in-block index 4 (1:7
attn:mamba interleave), MoE (16 experts, top-2) on odd indices (every other
layer), Mamba elsewhere.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig, SubLayer


def _jamba_pattern():
    subs = []
    for i in range(8):
        kind = "attn" if i == 4 else "ssm"
        ffn = "moe" if i % 2 == 1 else "mlp"
        subs.append(SubLayer(kind=kind, ffn=ffn))
    return tuple(subs)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,                      # dense-MLP layers
    vocab_size=65536,
    rope_theta=1_000_000.0,
    pattern=_jamba_pattern(),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2403.19887; hf",
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=8.0),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
    )
