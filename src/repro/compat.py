"""JAX version compatibility shims.

The codebase targets the modern public API (``jax.shard_map`` with
``check_vma``, ``jax.tree.flatten_with_path``); this module backfills those
names on older JAX (0.4.x) so every call site imports from here instead of
probing versions locally:

  * ``shard_map``       — ``jax.shard_map`` when present, otherwise
                          ``jax.experimental.shard_map.shard_map`` with the
                          ``check_vma`` keyword mapped to its old name
                          ``check_rep``.
  * ``tree_flatten_with_path`` / ``tree_map`` — ``jax.tree.*`` when present,
                          ``jax.tree_util.*`` otherwise.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["shard_map", "tree_flatten", "tree_flatten_with_path",
           "tree_map", "tree_unflatten"]


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    @functools.wraps(_legacy_shard_map)
    def shard_map(f=None, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return functools.partial(shard_map, **kwargs)
        return _legacy_shard_map(f, **kwargs)


# jax.tree itself only exists from 0.4.25; getattr keeps the shim importable
# on anything older, falling back to jax.tree_util throughout.
_tree = getattr(jax, "tree", None)

if _tree is not None and hasattr(_tree, "flatten_with_path"):
    tree_flatten_with_path = _tree.flatten_with_path
else:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path

if _tree is not None and hasattr(_tree, "map"):
    tree_map = _tree.map
else:
    tree_map = jax.tree_util.tree_map

if _tree is not None and hasattr(_tree, "flatten"):
    tree_flatten = _tree.flatten
    tree_unflatten = _tree.unflatten
else:
    tree_flatten = jax.tree_util.tree_flatten
    tree_unflatten = jax.tree_util.tree_unflatten
