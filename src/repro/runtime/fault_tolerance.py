"""Fault tolerance & elasticity for long-running jobs (DESIGN.md §7).

Three mechanisms, all exercised by tests:

1. `ResumableReconstruction` — the CT pipeline checkpoints its partial-volume
   accumulator plus the projection cursor, so a reconstruction killed at any
   micro-batch boundary restarts mid-stream (the FDK accumulation is a plain
   sum over projection batches -> resumable by construction).

2. `restart_loop` — generic supervised execution: run a step function,
   checkpoint every K steps, and on failure restore the latest committed
   checkpoint and continue; tolerates a bounded number of failures per
   window (crash-loop guard).

3. `StragglerMonitor` — EMA of per-step wall time; steps slower than
   `threshold` x EMA are flagged. In an SPMD job a persistent straggler is
   indistinguishable from a slow step on *every* rank (lock-step), so the
   mitigation is topological: the monitor recommends re-slicing the
   over-decomposed projection/microbatch axis (cheap, no state movement) or
   excluding a failed slice of the mesh at the next restart boundary
   (elastic re-mesh via checkpoint/io's mesh-agnostic restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager

Array = jax.Array


@dataclasses.dataclass
class ReconState:
    """Checkpointable reconstruction progress."""

    cursor: int            # next projection micro-batch index
    accumulator: Array     # partial (unscaled) volume, rank-local layout


class ResumableReconstruction:
    """Drives a distributed FDK in resumable micro-batch chunks.

    `step_fn(acc, batch_index)` must add the batch's back-projection into
    `acc` (pure, jit-able); `n_batches` is the over-decomposition factor.
    """

    def __init__(self, step_fn: Callable[[Array, int], Array],
                 init_acc: Array, n_batches: int,
                 manager: Optional[CheckpointManager] = None,
                 checkpoint_every: int = 0):
        self.step_fn = step_fn
        self.n_batches = n_batches
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.state = ReconState(cursor=0, accumulator=init_acc)

    def resume(self) -> None:
        if self.manager is None:
            return
        like = {"cursor": np.int64(0), "acc": self.state.accumulator}
        step, tree = self.manager.restore_latest(like)
        if tree is not None:
            self.state = ReconState(
                cursor=int(tree["cursor"]), accumulator=tree["acc"]
            )

    def run(self, fail_at: Optional[int] = None) -> Array:
        """Process remaining batches; `fail_at` injects a fault (tests)."""
        while self.state.cursor < self.n_batches:
            b = self.state.cursor
            if fail_at is not None and b == fail_at:
                raise RuntimeError(f"injected failure at batch {b}")
            acc = self.step_fn(self.state.accumulator, b)
            self.state = ReconState(cursor=b + 1, accumulator=acc)
            if (self.manager is not None and self.checkpoint_every
                    and (b + 1) % self.checkpoint_every == 0):
                self.manager.save(
                    b + 1,
                    {"cursor": np.int64(b + 1), "acc": acc},
                    blocking=True,
                )
        return self.state.accumulator


def restart_loop(make_state, step_fn, n_steps: int,
                 manager: CheckpointManager,
                 checkpoint_every: int = 10,
                 max_failures: int = 3,
                 fail_at: Optional[set] = None):
    """Supervised train loop with checkpoint/restart.

    make_state() -> state pytree; step_fn(state, step) -> state.
    `fail_at` is a set of (step) fault injections consumed once each.
    """
    fail_at = set(fail_at or ())
    failures = 0
    state = make_state()
    restored, tree = manager.restore_latest(state)
    start = 0
    if tree is not None:
        state, start = tree, restored
    step = start
    while step < n_steps:
        try:
            if step in fail_at:
                fail_at.discard(step)
                raise RuntimeError(f"injected failure at step {step}")
            state = step_fn(state, step)
            step += 1
            if step % checkpoint_every == 0:
                manager.save(step, state, blocking=True)
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            restored, tree = manager.restore_latest(state)
            if tree is None:
                state, step = make_state(), 0
            else:
                state, step = tree, restored
    manager.save(n_steps, state, blocking=True)
    return state


class StragglerMonitor:
    """Flags slow steps and recommends re-balancing (see module docstring)."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.flagged: list[tuple[int, float]] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        straggler = False
        if self.ema is not None and seconds > self.threshold * self.ema:
            self.flagged.append((self._step, seconds))
            straggler = True
            # do not pollute the EMA with outliers
        else:
            self.ema = (seconds if self.ema is None
                        else self.alpha * seconds + (1 - self.alpha) * self.ema)
        self._step += 1
        return straggler

    def timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        return out, self.record(time.perf_counter() - t0)

    def rebalance_hint(self, n_batches: int, n_ranks: int) -> dict:
        """Suggested over-decomposition after observed stragglers."""
        factor = 2 if self.flagged else 1
        return {
            "micro_batches": min(n_batches * factor, max(n_batches, n_ranks * 4)),
            "flagged_steps": list(self.flagged),
        }
