from .fault_tolerance import (
    ResumableReconstruction, StragglerMonitor, restart_loop,
)
from .elastic import ElasticPlan, plan_remesh, build_mesh
