"""Elastic scaling: resume a job on a different mesh (DESIGN.md §7).

The checkpoint manifest stores logical PartitionSpecs, not device ids, so a
restore onto any mesh with the same axis *names* re-shards automatically
(checkpoint/io.load_checkpoint). This module adds the policy layer: given the
devices that survived, build the largest well-formed mesh and re-derive the
dependent run parameters (per-rank batch, iFDK grid).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
from jax.sharding import Mesh

from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    dropped_devices: int


def plan_remesh(devices: Sequence, model_parallel: int,
                want_pods: Optional[int] = None) -> ElasticPlan:
    """Largest (pod?, data, model) mesh from surviving devices.

    model_parallel is fixed by memory footprint (e.g. iFDK's R, or TP size);
    the data axis absorbs the loss. E.g. 512 devices with model=16 -> data=32;
    after losing a node of 4, 508 devices -> data=31 (496 used, 12 idle).
    """
    n = len(devices)
    if model_parallel > n:
        raise ValueError("not enough devices for the model-parallel degree")
    data = n // model_parallel
    if want_pods and want_pods > 1:
        # keep pods balanced: shrink data until divisible
        while data % want_pods and data > 1:
            data -= 1
        shape = (want_pods, data // want_pods, model_parallel)
        names = (AXIS_POD, AXIS_DATA, AXIS_MODEL)
    else:
        shape = (data, model_parallel)
        names = (AXIS_DATA, AXIS_MODEL)
    used = int(np.prod(shape))
    return ElasticPlan(shape, names, n - used)


def build_mesh(devices: Sequence, plan: ElasticPlan) -> Mesh:
    used = int(np.prod(plan.mesh_shape))
    devs = np.asarray(devices[:used]).reshape(plan.mesh_shape)
    return Mesh(devs, plan.axis_names)
