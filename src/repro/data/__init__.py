from .pipeline import synthetic_batch, batch_specs, SyntheticTokens, ProjectionSource
