"""Data pipelines: synthetic LM token streams and CT projection sources.

`batch_specs(cfg, batch, seq)` is the single source of truth for model input
shapes — the dry run (ShapeDtypeStructs), the smoke tests (random data of the
same specs) and the example drivers all derive from it, so the 40 dry-run
cells and the tests can never drift apart.

The CT `ProjectionSource` mimics the paper's PFS loading: projections are
delivered in per-rank slices (Eq. 5: N_p/(C*R) each) in micro-batches, with
an injectable-latency hook used by the straggler tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training-batch ShapeDtypeStructs for an architecture."""
    specs = {}
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        k = cfg.frontend.num_positions
        specs["tokens"] = jax.ShapeDtypeStruct((batch, k, seq), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, k, seq), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.frontend is not None and cfg.frontend.modality == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend.num_positions, cfg.frontend.d_frontend),
            jnp.bfloat16,
        )
    return specs


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int,
                    key: jax.Array) -> Dict[str, jax.Array]:
    """Random batch matching batch_specs (smoke tests / example drivers)."""
    specs = batch_specs(cfg, batch, seq)
    ks = jax.random.split(key, len(specs))
    out = {}
    for (name, spec), k in zip(sorted(specs.items()), ks):
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(
                k, spec.shape, 0, cfg.vocab_size, dtype=jnp.int32
            )
        else:
            out[name] = jax.random.normal(k, spec.shape, jnp.float32).astype(
                spec.dtype
            )
    return out


class SyntheticTokens:
    """Deterministic, restartable synthetic LM stream (seeded per step).

    Restartability matters for checkpoint/restart tests: batch(step) is a
    pure function of (seed, step), so a resumed job sees the identical
    stream (the data-pipeline half of reproducible recovery)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return synthetic_batch(self.cfg, self.batch, self.seq, key)


@dataclasses.dataclass
class ProjectionSource:
    """Streams projection micro-batches (the paper's PFS read path)."""

    projections: np.ndarray          # (N_p, N_v, N_u)
    micro_batch: int
    latency_s: float = 0.0           # injectable per-batch latency (tests)

    def __post_init__(self):
        if self.projections.shape[0] % self.micro_batch:
            raise ValueError("N_p must divide by the micro batch")

    @property
    def n_batches(self) -> int:
        return self.projections.shape[0] // self.micro_batch

    def batch(self, idx: int) -> np.ndarray:
        if self.latency_s:
            import time
            time.sleep(self.latency_s)
        lo = idx * self.micro_batch
        return self.projections[lo:lo + self.micro_batch]

    def __iter__(self) -> Iterator[np.ndarray]:
        for i in range(self.n_batches):
            yield self.batch(i)
