"""Topology-aware collective helpers.

`hierarchical_psum`: two-phase reduction for multi-pod meshes — reduce-scatter
inside the pod (fast ICI), all-reduce of the scattered shards across pods
(slow DCN, 1/N of the bytes), then all-gather inside the pod. This moves
`(pods-1)/pods` of the cross-pod traffic off DCN compared to a flat psum over
("pod", "data") and is the standard DCN-aware schedule for 1000+ node jobs.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax import lax

from .mesh import AXIS_DATA, AXIS_POD

Array = jax.Array


def hierarchical_psum(x: Array, *, pod_axis: str = AXIS_POD,
                      inner_axis: str = AXIS_DATA,
                      scatter_dim: int = 0,
                      have_pod: bool = True) -> Array:
    """psum over (pod, inner) with pod traffic reduced by 1/|inner|."""
    if not have_pod:
        return lax.psum(x, inner_axis)
    # Phase 1: reduce-scatter along the fast intra-pod axis.
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    # Phase 2: all-reduce only the shard across pods (DCN).
    shard = lax.psum(shard, pod_axis)
    # Phase 3: all-gather back along the fast axis.
    return lax.all_gather(shard, inner_axis, axis=scatter_dim, tiled=True)


def hierarchical_psum_scatter(x: Array, *, pod_axis: str = AXIS_POD,
                              inner_axis: str = AXIS_DATA,
                              scatter_dim: int = 0,
                              have_pod: bool = True) -> Array:
    """reduce-scatter over (pod, inner), pod phase on the scattered shard."""
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    if have_pod:
        shard = lax.psum(shard, pod_axis)
    return shard


def psum_tree(tree, axes: Sequence[str]):
    """Sum-reduce a pytree over the given mesh axes (grads, metrics)."""
    def _psum(g):
        for a in axes:
            g = lax.psum(g, a)
        return g
    return jax.tree.map(_psum, tree)
