"""Sharding rules for the LM substrate (pjit/GSPMD style).

Logical dim names are mapped to mesh axes:

  fsdp -> ("pod", "data")   parameter sharding (ZeRO-3 style; XLA inserts
                            the all-gather at use / reduce-scatter at grad)
  tp   -> "model"           tensor parallel (heads / d_ff / vocab / experts)
  dp   -> ("pod", "data")   batch dim of activations
  sp   -> "model"           sequence dim for long-context activations
                            (sequence parallelism on the norm/residual path)

GSPMD tolerates non-divisible shardings (it pads), so archs whose head count
doesn't divide the model axis (qwen2's 12 q-heads on model=16) still compile;
the roofline accounting uses the padded tile sizes XLA reports.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple  # noqa: F401

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD

LOGICAL = {
    "fsdp": (AXIS_POD, AXIS_DATA),
    "dp": (AXIS_POD, AXIS_DATA),
    "tp": (AXIS_MODEL,),
    "sp": (AXIS_MODEL,),
    None: (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolves logical dim names against a concrete mesh (or none)."""

    mesh: Optional[Mesh] = None
    # Disable FSDP for small models where replication is cheaper.
    fsdp: bool = True
    # Shard long sequences over the model axis on the residual path.
    sequence_parallel: bool = False
    # Axes backing "fsdp"/"dp". The optimized small/mid-dense-model strategy
    # folds the model axis in as extra data parallelism (EXPERIMENTS.md
    # §Perf): fsdp_axes=("pod", "data", "model").
    fsdp_axes: Tuple[str, ...] = (AXIS_POD, AXIS_DATA)
    # ZeRO-3 gather-at-use. True is right for training (activations >>
    # weights); False is right for tiny-batch decode, where GSPMD's
    # partial-sum all-reduce of the (KB-sized) activations beats streaming
    # the gathered weights (EXPERIMENTS.md §Perf cell B).
    zero3_gather: bool = True
    # Gather MoE expert weights at use. False = expert parallelism: experts
    # stay sharded over the model axis and tokens move (all-to-all) instead
    # of the (much larger) expert weights (EXPERIMENTS.md §Perf cell A).
    gather_moe_experts: bool = False
    # Shard the decode residual stream's FEATURE dim over the data axes, so
    # d-sharded weight contractions resolve as tiny activation partial-sums
    # instead of 50MB weight gathers (EXPERIMENTS.md §Perf cell B iter 2).
    decode_feature_shard: bool = False

    def axes(self, logical: Optional[str]):
        if logical == "fsdp" and not self.fsdp:
            return None
        if logical == "sp" and not self.sequence_parallel:
            return None
        if self.mesh is None:
            return None
        if logical in ("fsdp", "dp"):
            pool = self.fsdp_axes
        else:
            pool = LOGICAL[logical]
        axes = tuple(a for a in pool if a in self.mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, *logical) -> P:
        return P(*(self.axes(l) for l in logical))

    def sharding(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def _axis_size(self, axes) -> int:
        if axes is None or axes == ():
            return 1
        if isinstance(axes, str):
            return self.mesh.shape[axes]
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def spec_for_shape(self, shape, *logical) -> P:
        """Shape-aware spec: jit in_shardings demand divisibility, so a
        logical axis that doesn't divide its dim is DROPPED (replicated) —
        e.g. qwen2's 12 q-heads on model=16 leave attention un-TP'd while
        d_ff/vocab still shard. Moving the axis to another dim is never done:
        landing on a contraction dim turns every matmul into a partial-sum
        all-reduce (measured: 1.6 GB score all-reduces per layer,
        EXPERIMENTS.md §Perf iteration 0)."""
        if self.mesh is None:
            return P(*(None,) * len(shape))
        entries = [self.axes(l) for l in logical]
        out = [None] * len(shape)
        used = set()
        for i, ax in enumerate(entries):
            if ax is None:
                continue
            cand = (ax,) if isinstance(ax, str) else tuple(ax)
            # never reuse a mesh axis across dims (fsdp_axes may overlap tp)
            cand = tuple(a for a in cand if a not in used)
            # progressively drop trailing axes until the dim divides
            while cand and shape[i] % self._axis_size(cand) != 0:
                cand = cand[:-1]
            if not cand:
                continue
            out[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
        return P(*out)

    def sharding_for_shape(self, shape, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for_shape(shape, *logical))

    def constrain(self, x, *logical):
        """Activation sharding constraint; no-op without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec_for_shape(x.shape, *logical))
        )

    def constrain_p(self, x, spec: P):
        """Explicit-PartitionSpec constraint (MoE all-to-all reshard)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec)
        )

    def tp_size(self) -> int:
        ax = self.axes("tp") if self.mesh is not None else None
        return self._axis_size(ax) if ax is not None else 1


def tree_shardings(rules: ShardingRules, def_tree):
    """Map a pytree of ParamDef-like (shape, spec) to NamedShardings."""
    def leaf(d):
        return rules.sharding_for_shape(d.shape, *d.spec)
    return jax.tree.map(
        leaf, def_tree,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "spec"),
    )
