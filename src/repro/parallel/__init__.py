from .mesh import (
    AXIS_DATA, AXIS_MODEL, AXIS_POD, axis_size, dp_axes, make_mesh,
    named, single_device_mesh,
)
