"""Mesh axes and helpers shared by the CT pipeline and the LM substrate.

Axis conventions (DESIGN.md §4):
  pod   : cross-pod data parallelism (DCN). iFDK: extra projection groups.
  data  : intra-pod data parallelism (ICI). iFDK: projection groups (paper C).
  model : tensor/expert parallelism   (ICI). iFDK: volume slabs (paper R).

`make_mesh` is a thin wrapper so importing this module never touches device
state; meshes are always built explicitly by launchers.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"

# Axes over which data-parallel reductions run (pod present only multi-pod).
def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices=None) -> Mesh:
    if devices is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    devs = np.asarray(devices).reshape(tuple(shape))
    return Mesh(devs, tuple(axes))


def single_device_mesh() -> Mesh:
    """1x1 mesh over the default device — lets every shard_map program run
    unchanged on one chip (tests, smoke runs)."""
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, (AXIS_DATA, AXIS_MODEL))


def named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def axis_size(mesh: Mesh, *names: str) -> int:
    n = 1
    for name in names:
        if name in mesh.axis_names:
            n *= mesh.shape[name]
    return n
