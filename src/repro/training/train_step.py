"""Training step: loss -> grads -> AdamW, with microbatched grad accumulation.

Microbatching is a lax.scan over microbatch slices; the gradient
reduce(-scatter) of microbatch m overlaps the compute of m+1 exactly like
the iFDK projection pipeline (DESIGN.md §5: the same gather-compute-reduce
schedule drives both the CT reconstruction and training).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import tree_map
from repro.models.config import ModelConfig
from repro.models.transformer import (
    abstract_params, init_params, loss_fn, param_shardings,
)
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.parallel.sharding import ShardingRules

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: OptState


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params))


def make_abstract_state(cfg: ModelConfig) -> TrainState:
    params = abstract_params(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    return TrainState(
        params=params,
        opt=OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=tree_map(f32, params),
            nu=tree_map(f32, params),
        ),
    )


def state_shardings(cfg: ModelConfig, rules: ShardingRules) -> TrainState:
    ps = param_shardings(cfg, rules)
    return TrainState(
        params=ps,
        opt=OptState(
            step=rules.sharding() if rules.mesh is not None else None,
            mu=ps, nu=ps,
        ),
    )


def make_train_step(cfg: ModelConfig,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    rules: Optional[ShardingRules] = None,
                    microbatches: int = 1,
                    warmup: int = 100, total_steps: int = 10_000,
                    remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, rules, remat)[0]
        )(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state.params
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mbs = tree_map(slice_mb, batch)

            def mb_step(acc, mb):
                loss_acc, grad_acc = acc
                loss, grads = grads_of(params, mb)
                grad_acc = tree_map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero_g = tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), zero_g), mbs
            )
            loss = loss / microbatches
            grads = tree_map(lambda g: g / microbatches, grads)

        lr_scale = cosine_schedule(state.opt.step + 1, warmup, total_steps)
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, state.opt, params, lr_scale
        )
        metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                   "lr_scale": lr_scale}
        return TrainState(new_params, new_opt), metrics

    return train_step
