from .train_step import TrainState, make_train_step, init_train_state, make_abstract_state
