"""Shared file-backed JSON memo (autotuner + planner measurement caches).

One convention, two users (kernels/backproject/tune.py, planner/measure.py):
an env var names the cache file ("off"/"0"/""/"none" disables persistence,
unset falls back to a default under ~/.cache/repro), entries live under a
versioned envelope ({"version": N, "entries": {json(key): entry}}), writes
are read-modify-write with an atomic os.replace and best-effort on failure
(read-only filesystems just skip persistence).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional


class JsonFileCache:
    """File half of a two-level memo: callers keep their own in-process
    dict and decide what counts as a usable hit; this object only moves
    JSON-able entries to and from disk. `hits` is a public counter the
    caller increments when a disk entry is actually served
    (observability/tests)."""

    def __init__(self, env_var: str, default_filename: str,
                 version: int = 1, path: Optional[str] = None):
        self.env_var = env_var
        self.default_filename = default_filename
        self.version = version
        self.hits = 0
        # Explicit path wins over env resolution — callers that manage
        # their own file (tests, the calibration store's save/load CLI)
        # bypass the env switch entirely.
        self._path_override = path

    def path(self) -> Optional[str]:
        """Resolved cache path, or None when persistence is disabled."""
        if self._path_override is not None:
            return self._path_override
        env = os.environ.get(self.env_var)
        if env is not None:
            if env.strip().lower() in ("", "0", "off", "none"):
                return None
            return env
        return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            self.default_filename)

    @staticmethod
    def key_str(key: tuple) -> str:
        return json.dumps(list(key))

    def _load(self, path: str) -> dict:
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(data, dict) or data.get("version") != self.version:
            return {}  # stale schema: ignore, will be rewritten
        entries = data.get("entries")
        return entries if isinstance(entries, dict) else {}

    def get(self, key: tuple) -> Any:
        """The stored entry for `key`, or None. Does NOT bump `hits` —
        the caller counts only entries it accepts."""
        path = self.path()
        if path is None:
            return None
        return self._load(path).get(self.key_str(key))

    def entries(self) -> dict:
        """All stored entries, `{key_str: entry}` — the bulk-read view the
        calibration store fits from (planner/calibrate.py). Empty dict when
        persistence is disabled or the file is missing/stale."""
        path = self.path()
        if path is None:
            return {}
        return self._load(path)

    def put(self, key: tuple, entry: Any) -> None:
        path = self.path()
        if path is None:
            return
        entries = self._load(path)
        entries[self.key_str(key)] = entry
        try:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump({"version": self.version, "entries": entries}, f)
            os.replace(tmp, path)
        except OSError:
            pass  # best-effort: a missing cache is never an error
