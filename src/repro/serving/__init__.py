from .engine import make_prefill, make_decode_step, greedy_generate
