"""Serving: batched prefill + single-token decode over a sharded KV cache.

decode_* dry-run shapes lower `decode_step` (one new token against a
seq_len-deep cache); prefill_* shapes lower `prefill`. SSM/hybrid archs carry
O(1) recurrent state instead of a growing KV cache (the long_500k story).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.parallel.sharding import ShardingRules

PyTree = Any


def make_prefill(cfg: ModelConfig, rules: Optional[ShardingRules] = None):
    def prefill_fn(params, batch: Dict[str, jax.Array]):
        return T.prefill(params, cfg, batch, rules)
    return prefill_fn


def make_decode_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None):
    def decode_fn(params, cache, tokens, cur_len):
        return T.decode_step(params, cfg, cache, tokens, cur_len, rules)
    return decode_fn


def greedy_generate(cfg: ModelConfig, params, prompt: Dict[str, jax.Array],
                    steps: int, s_max: int,
                    rules: Optional[ShardingRules] = None):
    """Prefill the prompt then greedily decode `steps` tokens (examples)."""
    tokens = prompt["tokens"]
    audio = cfg.frontend is not None and cfg.frontend.modality == "audio"
    b = tokens.shape[0]
    s0 = tokens.shape[-1]
    logits, cache = T.prefill(params, cfg, prompt, rules)

    # Re-home the prefill cache into a larger decode cache.
    full = T.init_cache(cfg, b, s_max)
    def place(big, small):
        if small.ndim >= 3 and small.shape[2] == s0 and big.shape[2] == s_max:
            return jax.lax.dynamic_update_slice_in_dim(big, small.astype(big.dtype), 0, axis=2)
        return small.astype(big.dtype)
    cache = jax.tree.map(place, full, cache)

    out = []
    cur = jnp.argmax(logits, -1)  # (B,) or (B,K)
    for t in range(steps):
        out.append(cur)
        tok = cur[..., None].astype(jnp.int32)
        logits, cache = T.decode_step(params, cfg, cache, tok,
                                      jnp.int32(s0 + t), rules)
        cur = jnp.argmax(logits, -1)
    out.append(cur)
    axis = -1 if not audio else -1
    return jnp.stack(out, axis=axis)
