"""Sharded checkpoint I/O (no external deps): per-leaf .npy + JSON manifest.

Layout of a checkpoint directory:

  step_000100/
    MANIFEST.json        {step, leaf paths, shapes, dtypes, mesh, specs}
    leaves/<name>.npy    one file per pytree leaf (full array)
    .COMMITTED           written last -> atomic visibility

Design notes for scale (DESIGN.md §7):
  * On a multi-host system each host writes only the shards it owns
    (`array.addressable_shards`), mirroring the paper's slice-per-rank PFS
    store; this container is single-host so the full-array path is taken.
  * Restore is *mesh-agnostic*: the manifest stores the logical
    PartitionSpec, and `load_checkpoint` re-shards onto whatever mesh the
    restarted job has — the elastic-scaling path (512 -> 448 chips) is the
    same code path as a plain restart.
  * `CheckpointManager` runs saves on a background thread (async
    checkpointing), keeps the newest K checkpoints and never deletes the
    last committed one.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.compat import (
    tree_flatten, tree_flatten_with_path, tree_map, tree_unflatten,
)

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _spec_to_json(spec: PartitionSpec) -> list:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def _spec_from_json(spec) -> PartitionSpec:
    parts = []
    for e in spec:
        if isinstance(e, list):
            parts.append(tuple(e))
        else:
            parts.append(e)
    return PartitionSpec(*parts)


def _leaf_spec(leaf) -> list:
    sharding = getattr(leaf, "sharding", None)
    if isinstance(sharding, NamedSharding):
        return _spec_to_json(sharding.spec)
    return []


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Write a committed checkpoint for `tree` at `step`. Returns its path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    leaves_dir = os.path.join(tmp, "leaves")
    os.makedirs(leaves_dir, exist_ok=True)
    flat, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    for idx, (keypath, leaf) in enumerate(flat):
        name = f"leaf_{idx:05d}"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(leaves_dir, name + ".npy"), arr)
        manifest["leaves"].append(
            {
                "name": name,
                "key": jax.tree_util.keystr(keypath),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "spec": _leaf_spec(leaf),
            }
        )
    manifest["treedef"] = str(treedef)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    open(os.path.join(tmp, ".COMMITTED"), "w").close()
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, ".COMMITTED")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: PyTree,
                    mesh: Optional[Mesh] = None) -> PyTree:
    """Restore into the structure of `like`, re-sharded for `mesh`.

    `like` provides the pytree structure (e.g. from `jax.eval_shape` of the
    init fn); the manifest's PartitionSpecs are re-applied on `mesh`, which
    may differ in shape from the mesh that wrote the checkpoint (elastic
    restart).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    flat, treedef = tree_flatten(like)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected {len(flat)}"
        )
    out = []
    for leaf_like, meta in zip(flat, manifest["leaves"]):
        arr = np.load(os.path.join(path, "leaves", meta["name"] + ".npy"))
        if list(arr.shape) != list(np.shape(leaf_like)):
            raise ValueError(
                f"{meta['key']}: checkpoint shape {arr.shape} != expected "
                f"{np.shape(leaf_like)}"
            )
        if mesh is not None and meta["spec"] is not None:
            sharding = NamedSharding(mesh, _spec_from_json(meta["spec"]))
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.device_put(arr))
    return tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpointing with retention (DESIGN.md §7)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        # Snapshot to host memory synchronously (cheap), write async.
        host_tree = tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def _write():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: PyTree, mesh: Optional[Mesh] = None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, like, mesh)

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for m in (_STEP_RE.match(n) for n in os.listdir(self.directory))
            if m
        )
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
