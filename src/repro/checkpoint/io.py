"""Sharded checkpoint I/O on the shard-store core (repro/io, DESIGN.md §7).

Layout of a checkpoint directory:

  step_000100/
    MANIFEST.json              {step, leaf keys/shapes/dtypes/specs, treedef}
    leaves/leaf_00000/         one shard STORE per pytree leaf:
      MANIFEST.json              shard index -> global slice
      shards/shard_00000.bin     one file per addressable device shard
    .COMMITTED                 written last -> atomic visibility

Semantics:
  * Each host writes only the shards it owns (`array.addressable_shards`),
    mirroring the paper's slice-per-rank PFS store — the global array is
    never gathered to one host.
  * Restore is *mesh-agnostic*: the manifest stores the logical
    PartitionSpec (None when the saved leaf recorded no spec — a host array
    or default placement; an empty list is a real, fully-replicated spec),
    and `load_checkpoint` scatter-reads each leaf onto whatever mesh the
    restarted job has, opening only the shard files its target regions
    intersect — the elastic-scaling path (512 -> 448 chips) is the same
    code path as a plain restart.
  * Corruption fails loudly: a truncated shard file, a missing manifest
    entry and a missing `.COMMITTED` marker each raise `StoreError` naming
    the offending path, and `CheckpointManager.restore_latest` falls back
    to the newest step that does load.
  * `CheckpointManager` runs saves on a background thread (async
    checkpointing, via per-shard host snapshots — `shard_store.snapshot`),
    keeps the newest K checkpoints, never deletes the last committed one,
    and sweeps `step_*.tmp` directories orphaned by a crashed writer.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.compat import (
    tree_flatten, tree_flatten_with_path, tree_map, tree_unflatten,
)
from repro.io import shard_store
from repro.io.shard_store import StoreError

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^step_(\d+)\.tmp$")


def _spec_from_json(spec) -> PartitionSpec:
    parts = []
    for e in spec:
        if isinstance(e, list):
            parts.append(tuple(e))
        else:
            parts.append(e)
    return PartitionSpec(*parts)


def _leaf_spec(leaf) -> Optional[list]:
    """JSON PartitionSpec of a leaf, or None when none is recorded. The
    None/[] distinction is real: [] is PartitionSpec() (fully replicated,
    re-apply it on restore), None means the saved leaf had no spec at all
    (host array / default placement — restore with default placement)."""
    if isinstance(leaf, shard_store.HostShardedArray):
        return leaf.spec
    return shard_store.leaf_spec_json(leaf)


def _sweep_orphaned_tmp(directory: str) -> List[str]:
    """Remove `step_*.tmp` directories a crashed writer left behind. They
    must neither accumulate nor shadow a later save of the same step (a
    stale tmp would leak its leaf files into the renamed checkpoint)."""
    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in os.listdir(directory):
        if _TMP_RE.match(name):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
            removed.append(name)
    return removed


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Write a committed checkpoint for `tree` at `step`. Returns its path.

    Leaves may be jax Arrays (each host writes its addressable shards),
    host numpy values, or `shard_store.HostShardedArray` snapshots (the
    async manager path).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):  # stale writer: do not inherit its files
        shutil.rmtree(tmp)
    leaves_dir = os.path.join(tmp, "leaves")
    os.makedirs(leaves_dir, exist_ok=True)
    flat, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "format": "shard-store-v1", "leaves": []}
    for idx, (keypath, leaf) in enumerate(flat):
        name = f"leaf_{idx:05d}"
        shard_store.save_array(os.path.join(leaves_dir, name), leaf)
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        dtype = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
        manifest["leaves"].append(
            {
                "name": name,
                "key": jax.tree_util.keystr(keypath),
                "shape": list(shape),
                "dtype": str(np.dtype(dtype)),
                "spec": _leaf_spec(leaf),
            }
        )
    manifest["treedef"] = str(treedef)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    open(os.path.join(tmp, ".COMMITTED"), "w").close()
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def committed_steps(directory: str) -> List[int]:
    """All committed step numbers, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, ".COMMITTED")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, step: int, like: PyTree,
                    mesh: Optional[Mesh] = None) -> PyTree:
    """Restore into the structure of `like`, re-sharded for `mesh`.

    `like` provides the pytree structure (e.g. from `jax.eval_shape` of the
    init fn); the manifest's PartitionSpecs are re-applied on `mesh`, which
    may differ in shape from the mesh that wrote the checkpoint (elastic
    restart) — each leaf is scatter-read: only the shard files overlapping
    this host's target regions are opened.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    mpath = os.path.join(path, "MANIFEST.json")
    if not os.path.exists(mpath):
        raise StoreError(f"no checkpoint manifest at {mpath!r}")
    if not os.path.exists(os.path.join(path, ".COMMITTED")):
        raise StoreError(
            f"checkpoint {path!r} is uncommitted (no .COMMITTED marker): "
            "the writer crashed mid-save; restore an earlier step")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise StoreError(f"unreadable checkpoint manifest {mpath!r}: {e}"
                         ) from e
    flat, treedef = tree_flatten(like)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected {len(flat)}"
        )
    out = []
    for leaf_like, meta in zip(flat, manifest["leaves"]):
        leaf_dir = os.path.join(path, "leaves", meta["name"])
        if list(meta["shape"]) != list(np.shape(leaf_like)):
            raise ValueError(
                f"{meta['key']}: checkpoint shape {tuple(meta['shape'])} != "
                f"expected {np.shape(leaf_like)}"
            )
        if mesh is not None and meta["spec"] is not None:
            sharding = NamedSharding(mesh, _spec_from_json(meta["spec"]))
            out.append(shard_store.load_array(leaf_dir, sharding))
        else:
            out.append(jax.device_put(shard_store.load_array(leaf_dir)))
    return tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpointing with retention + orphan sweep (DESIGN.md §7)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        _sweep_orphaned_tmp(directory)  # crashed-writer leftovers

    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        # Snapshot shard-by-shard to host memory synchronously (cheap, and
        # keeps each shard's global index + the leaf's PartitionSpec for
        # the per-shard files), write async.
        host_tree = tree_map(shard_store.snapshot, tree)
        self.wait()

        def _write():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: PyTree, mesh: Optional[Mesh] = None):
        """(step, tree) from the newest loadable committed checkpoint.

        A corrupted newest step (truncated shard, gutted manifest — any
        StoreError) is skipped with the next-newest tried instead, so one
        bad write never strands a restart; (None, None) when nothing
        committed loads.
        """
        self.wait()
        last_err: Optional[StoreError] = None
        for step in reversed(committed_steps(self.directory)):
            try:
                return step, load_checkpoint(self.directory, step, like, mesh)
            except StoreError as e:
                last_err = e
                continue
        if last_err is not None:
            import warnings

            warnings.warn(f"no committed checkpoint loads cleanly; last "
                          f"error: {last_err}", RuntimeWarning)
        return None, None

    def _gc(self) -> None:
        _sweep_orphaned_tmp(self.directory)
        steps = committed_steps(self.directory)
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )
