from .io import (  # noqa: F401
    CheckpointManager, StoreError, committed_steps, latest_step,
    load_checkpoint, save_checkpoint,
)
