from .io import save_checkpoint, load_checkpoint, latest_step, CheckpointManager
