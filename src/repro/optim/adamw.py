"""AdamW with global-norm clipping (pure JAX, pytree-native).

Optimizer moments inherit each parameter's sharding (ZeRO-1: the m/v trees
are sharded exactly like the FSDP-sharded params — XLA keeps the update
fully local, no optimizer-state gather).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adamw_init(params: PyTree) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: OptState,
                 params: PyTree, lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}
