from .adamw import AdamWConfig, adamw_init, adamw_update, OptState
from .schedule import cosine_schedule
