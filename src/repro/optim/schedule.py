"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup then cosine decay to `floor` of peak. Returns a scale."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
