"""Pure-jnp oracle for the Pallas back-projection kernel.

Semantics: the factorized Alg. 4 with dual-slab output layout
(nx, ny, 2, nz/2), zero-outside bilinear interpolation, f32 accumulation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def _bilinear_zero(img: Array, rows: Array, cols: Array) -> Array:
    """Bilinear sample of img (R, C); out-of-range taps contribute zero."""
    nr, nc = img.shape
    r0 = jnp.floor(rows)
    c0 = jnp.floor(cols)
    dr = rows - r0
    dc = cols - c0
    r0i = r0.astype(jnp.int32)
    c0i = c0.astype(jnp.int32)

    def tap(ri, ci, wgt):
        valid = (ri >= 0) & (ri < nr) & (ci >= 0) & (ci < nc)
        return jnp.where(
            valid, img[jnp.clip(ri, 0, nr - 1), jnp.clip(ci, 0, nc - 1)] * wgt, 0.0
        )

    return (
        tap(r0i, c0i, (1 - dr) * (1 - dc))
        + tap(r0i, c0i + 1, (1 - dr) * dc)
        + tap(r0i + 1, c0i, dr * (1 - dc))
        + tap(r0i + 1, c0i + 1, dr * dc)
    )


@partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def backproject_dual_ref(pmats: Array, qt: Array,
                         nx: int, ny: int, nz: int) -> Array:
    """Oracle: pmats (Np, 3, 4) f32, qt (Np, Nu, Nv) transposed projections.

    Returns the dual-slab volume (nx, ny, 2, nz//2) float32:
      out[..., 0, k] = volume[..., k]          (front half)
      out[..., 1, k] = volume[..., nz - 1 - k] (mirrored back half)
    """
    assert nz % 2 == 0
    nzh = nz // 2
    n_v = qt.shape[-1]
    i = jnp.arange(nx, dtype=jnp.float32)[:, None]
    j = jnp.arange(ny, dtype=jnp.float32)[None, :]
    k = jnp.arange(nzh, dtype=jnp.float32)

    def body(acc, sp):
        p, q = sp
        q = q.astype(jnp.float32)
        x0 = p[0, 0] * i + p[0, 1] * j + p[0, 3]
        y0 = p[1, 0] * i + p[1, 1] * j + p[1, 3]
        z = p[2, 0] * i + p[2, 1] * j + p[2, 3]
        f = 1.0 / z
        u = x0 * f
        w = f * f
        v = (y0[..., None] + p[1, 2] * k) * f[..., None]
        ub = jnp.broadcast_to(u[..., None], v.shape)
        front = w[..., None] * _bilinear_zero(q, ub, v)
        back = w[..., None] * _bilinear_zero(q, ub, (n_v - 1.0) - v)
        return acc + jnp.stack([front, back], axis=-2), None

    init = jnp.zeros((nx, ny, 2, nzh), jnp.float32)
    out, _ = jax.lax.scan(body, init, (pmats.astype(jnp.float32), qt))
    return out
