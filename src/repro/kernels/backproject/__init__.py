from .ops import backproject_pallas, backproject_mxu
from .ref import backproject_dual_ref
