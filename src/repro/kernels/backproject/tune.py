"""VMEM-budget-aware block autotuner for the Pallas back-projection kernel.

Replaces the naive largest-divisor-<=8 block choice: the (bi, bj, bs) tile
shape determines both the VMEM working set (kernel.vmem_bytes) and the HBM
traffic — the projection batch is re-streamed once per (gi, gj) output tile,
so total Q^T traffic is (nx/bi)*(ny/bj) * Np*Nu*Nv*itemsize. The tuner

  1. enumerates candidates that tile the problem (bi | nx, bj | ny, bs a
     power of two — ops.py pads the projection axis),
  2. prunes them against a configurable VMEM budget with the kernel's own
     vmem_bytes() model (storage dtype aware: bf16/fp16 projections double
     the feasible batch),
  3. ranks the survivors by the traffic model, and — in measured mode —
     times the few best with the real kernel once per (geometry, dtype),
     memoized in an in-process cache.

Knobs:
  REPRO_BP_VMEM_BUDGET   VMEM budget in bytes (default 8 MiB — half of a
                         TPU core's ~16 MiB, leaving room for double
                         buffering and spills).
  REPRO_BP_AUTOTUNE      "time" to measure survivors on every first use of
                         a geometry (default: model-ranked pick, no timing
                         — interpret-mode timing is python-speed).
  REPRO_TUNE_CACHE       path of the file-backed tuning cache (JSON),
                         keyed by the full tuning key (geometry tile,
                         dtype, vmem budget, mode flags) so tuning
                         survives across processes. Default
                         ~/.cache/repro/bp_tune_cache.json; "off"/"0"/""
                         disables persistence.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.filecache import JsonFileCache

from .kernel import backproject_dual_pallas, vmem_bytes

DEFAULT_VMEM_BUDGET = int(os.environ.get("REPRO_BP_VMEM_BUDGET", 8 * 2**20))
_BLOCK_CAP = 64  # largest tile edge / projection batch considered


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One kernel tiling: output tile (bi, bj), projection batch bs."""

    bi: int
    bj: int
    bs: int
    vmem: int            # working-set bytes under kernel.vmem_bytes()
    elapsed: float = 0.0  # measured seconds/call (0.0 = model-ranked only)

    def as_tuple(self) -> Tuple[int, int, int]:
        return self.bi, self.bj, self.bs


_CACHE: Dict[tuple, BlockConfig] = {}

# File-backed persistence (tuning survives across processes): shared
# machinery with the planner's measurement cache (repro/filecache.py).
_FILE_CACHE = JsonFileCache("REPRO_TUNE_CACHE", "bp_tune_cache.json")


def clear_cache() -> None:
    """Drop the in-process memo (the file cache, if any, is untouched)."""
    _CACHE.clear()


def cache_info() -> Dict[tuple, BlockConfig]:
    return dict(_CACHE)


def file_cache_hits() -> int:
    """How many tuning keys this process served from the file cache."""
    return _FILE_CACHE.hits


def cache_path() -> Optional[str]:
    """Resolved file-cache path, or None when persistence is disabled."""
    return _FILE_CACHE.path()


def _file_cache_get(key: tuple) -> Optional[BlockConfig]:
    entry = _FILE_CACHE.get(key)
    if entry is None:
        return None
    try:
        return BlockConfig(**entry)
    except TypeError:
        return None


def _file_cache_put(key: tuple, cfg: BlockConfig) -> None:
    _FILE_CACHE.put(key, dataclasses.asdict(cfg))


def _divisors(n: int, cap: int) -> List[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def _pow2_leq(n: int, cap: int) -> List[int]:
    out, b = [], 1
    while b <= min(n, cap):
        out.append(b)
        b *= 2
    return out


def candidate_blocks(nx: int, ny: int, n_p: int, nu: int, nv: int, nzh: int,
                     qt_dtype=jnp.float32, budget: int | None = None,
                     fix_bi: int | None = None, fix_bj: int | None = None,
                     fix_bs: int | None = None) -> List[BlockConfig]:
    """All (bi, bj, bs) that tile the problem and fit the VMEM budget.

    fix_* pins a dimension the caller chose explicitly; the remaining
    dimensions are tuned around it so the joint config still fits.
    """
    budget = DEFAULT_VMEM_BUDGET if budget is None else budget
    bis = [fix_bi] if fix_bi else _divisors(nx, _BLOCK_CAP)
    bjs = [fix_bj] if fix_bj else _divisors(ny, _BLOCK_CAP)
    bss = [fix_bs] if fix_bs else _pow2_leq(n_p, _BLOCK_CAP)
    out = []
    for bi in bis:
        for bj in bjs:
            for bs in bss:
                vm = vmem_bytes(bi, bj, bs, nu, nv, nzh, qt_dtype)
                if vm <= budget:
                    out.append(BlockConfig(bi, bj, bs, vm))
    return out


@functools.lru_cache(maxsize=None)
def min_vmem_bytes(nx: int, ny: int, n_p: int, nu: int, nv: int, nzh: int,
                   qt_dtype=jnp.float32) -> int:
    """Smallest achievable working set over all candidate tilings — the
    kernel-level feasibility floor (planner/feasibility.py): if even this
    exceeds the VMEM budget, no block choice can make the kernel fit.
    Memoized: the planner asks for the same per-call shape once per
    (reduce, precision-of-equal-width, grid) candidate."""
    cands = candidate_blocks(nx, ny, n_p, nu, nv, nzh, qt_dtype,
                             budget=2**62)
    return min(c.vmem for c in cands)


def _traffic_score(c: BlockConfig, n_p: int) -> tuple:
    """Rank key, larger = better: minimize Q^T re-streaming (maximize the
    output tile), then minimize padded projection work (ops.py zero-pads
    n_p up to a bs multiple — wasted back-projection per tile), then
    amortize per-batch overhead (maximize bs)."""
    padded = -(-n_p // c.bs) * c.bs
    return (c.bi * c.bj, -padded, c.bs, -c.vmem)


def _time_candidate(c: BlockConfig, nx: int, ny: int, nz: int, n_p: int,
                    nu: int, nv: int, qt_dtype, interpret: bool,
                    iters: int) -> float:
    n_pad = -(-n_p // c.bs) * c.bs  # padding overhead is part of the cost
    pm = np.zeros((n_pad, 12), np.float32)
    pm[:, 11] = 1.0  # z == 1: no division hazard on synthetic data
    pm = jnp.asarray(pm)
    qt = jnp.zeros((n_pad, nu, nv), qt_dtype)
    run = lambda: backproject_dual_pallas(  # noqa: E731
        pm, qt, nx, ny, nz, bi=c.bi, bj=c.bj, bs=c.bs, interpret=interpret
    )
    jax.block_until_ready(run())  # compile / warm up
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(run())
    return (time.perf_counter() - t0) / iters


def autotune(nx: int, ny: int, nz: int, n_p: int, nu: int, nv: int,
             qt_dtype=jnp.float32, budget: int | None = None,
             interpret: bool | None = None, measure: bool = True,
             max_measure: int = 4, iters: int = 1,
             fix_bi: int | None = None, fix_bj: int | None = None,
             fix_bs: int | None = None, strict: bool = True) -> BlockConfig:
    """Best block config for one (geometry, dtype), memoized in-process and
    in the file-backed cache (REPRO_TUNE_CACHE) keyed by the tuning inputs.

    With measure=True the top-`max_measure` model-ranked survivors are each
    timed once with the real kernel on synthetic data of the true shape;
    measure=False returns the model-ranked winner without running anything —
    unless a measured winner for the same inputs is already cached, which is
    always preferred (measured timings outrank the traffic model).

    strict=True raises when nothing fits the budget; strict=False falls
    back to the minimal-working-set tiling with a warning (a detector so
    wide that even bs=1 overflows should still reconstruct, just slowly).
    """
    if nz % 2:
        raise ValueError("back-projection kernel requires even N_z")
    budget = DEFAULT_VMEM_BUDGET if budget is None else budget
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt_dtype = jnp.dtype(qt_dtype)
    # The key is the tuning *problem*, not the tuning mode: a measured
    # winner (elapsed > 0) satisfies both measured and model-ranked
    # requests, so an expensive REPRO_BP_AUTOTUNE=time run is reused by
    # later default-mode calls (in-process and via the file cache). An
    # unmeasured entry only satisfies unmeasured requests — a measured
    # request upgrades it in place.
    key = (nx, ny, nz, n_p, nu, nv, qt_dtype.str, budget, interpret,
           fix_bi, fix_bj, fix_bs, strict)
    hit = _CACHE.get(key)
    from_file = False
    if hit is None:
        hit = _file_cache_get(key)
        from_file = hit is not None
    if hit is not None and (not measure or hit.elapsed > 0.0):
        if from_file:
            _FILE_CACHE.hits += 1
        _CACHE[key] = hit
        return hit

    cands = candidate_blocks(nx, ny, n_p, nu, nv, nz // 2, qt_dtype, budget,
                             fix_bi, fix_bj, fix_bs)
    if not cands:
        if strict:
            raise ValueError(
                f"no (bi, bj, bs) tiling of ({nx}, {ny}, Np={n_p}) fits the "
                f"VMEM budget of {budget} bytes (detector {nu}x{nv}); "
                "raise REPRO_BP_VMEM_BUDGET or shrink the detector batch"
            )
        # The qt batch is what overflowed (it already does at bs=1): keep it
        # minimal and tune the rest normally, rather than refusing to run.
        unbounded = candidate_blocks(nx, ny, n_p, nu, nv, nz // 2, qt_dtype,
                                     2**62, fix_bi, fix_bj, fix_bs)
        bs_min = min(c.bs for c in unbounded)
        pool = [c for c in unbounded if c.bs == bs_min]
        best = max(pool, key=lambda c: _traffic_score(c, n_p))
        warnings.warn(
            f"back-projection working set exceeds the VMEM budget of "
            f"{budget} bytes even at bs={bs_min} (detector {nu}x{nv}); "
            f"proceeding with {best.as_tuple()} ({best.vmem} bytes)"
        )
        _CACHE[key] = best
        _file_cache_put(key, best)
        return best
    ranked = sorted(cands, key=lambda c: _traffic_score(c, n_p),
                    reverse=True)
    if measure and len(ranked) > 1:
        timed = [
            dataclasses.replace(
                c, elapsed=_time_candidate(c, nx, ny, nz, n_p, nu, nv,
                                           qt_dtype, interpret, iters)
            )
            for c in ranked[:max_measure]
        ]
        best = min(timed, key=lambda c: c.elapsed)
    else:
        best = ranked[0]
    _CACHE[key] = best
    _file_cache_put(key, best)
    return best


def pick_blocks(nx: int, ny: int, nz: int, n_p: int, nu: int, nv: int,
                qt_dtype=jnp.float32, budget: int | None = None,
                interpret: bool | None = None,
                measure: bool | None = None,
                fix_bi: int | None = None, fix_bj: int | None = None,
                fix_bs: int | None = None) -> Tuple[int, int, int]:
    """ops.py entry point: (bi, bj, bs) under the VMEM budget.

    measure=None defers to REPRO_BP_AUTOTUNE ("time" enables measured
    tuning); the default model-ranked pick costs one table scan, so it is
    safe on every call path (results are cached either way). fix_* pins
    dimensions the caller specified so the tuned remainder still respects
    the budget jointly.
    """
    if measure is None:
        measure = os.environ.get("REPRO_BP_AUTOTUNE", "") == "time"
    # An explicitly passed budget is a hard constraint; the env/default
    # budget degrades to minimal blocks + warning so oversized detectors
    # still reconstruct (the pre-autotuner behaviour).
    return autotune(nx, ny, nz, n_p, nu, nv, qt_dtype=qt_dtype,
                    budget=budget, interpret=interpret, measure=measure,
                    fix_bi=fix_bi, fix_bj=fix_bj, fix_bs=fix_bs,
                    strict=budget is not None).as_tuple()
