"""jit'd public wrappers around the back-projection kernel.

  backproject_pallas : drop-in replacement for core.backprojection.*
                       (handles layout, padding, block selection)
  backproject_mxu    : gather-free MXU formulation — bilinear interpolation
                       recast as two small matmuls with relu-hat weight
                       matrices (texture fetch -> systolic array; see
                       DESIGN.md §2). Exact same math, no dynamic indexing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.backprojection import _stream_scales, from_dual_slab
from .kernel import backproject_dual_pallas
from . import tune

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def backproject_pallas(pmats: Array, proj: Array,
                       nx: int, ny: int, nz: int,
                       bi: int | None = None, bj: int | None = None,
                       bs: int | None = None,
                       interpret: bool | None = None,
                       vmem_budget: int | None = None,
                       scales: Array | None = None) -> Array:
    """Alg. 4 via the Pallas kernel. Same signature/result as the oracles.

    pmats: (Np, 3, 4); proj: (Np, N_v, N_u) filtered projections (row = v),
    in any wire dtype (fp32/bf16/fp16/fp8 — the stream codec's output);
    taps are upcast inside the kernel, `scales` (the codec's per-projection
    sidecar, None = unscaled) rides as column 12 of the parameter row and
    dequantizes at the accumulation weight, and accumulation is always f32.
    Returns (nx, ny, nz) float32.

    Block shapes not given explicitly come from the VMEM-budget autotuner
    (tune.pick_blocks): candidates that tile the problem, pruned against
    `vmem_budget` (default REPRO_BP_VMEM_BUDGET), model-ranked — or timed
    once per (geometry, dtype) when REPRO_BP_AUTOTUNE=time.
    """
    n_p = proj.shape[0]
    if interpret is None:
        interpret = not _on_tpu()
    qt = jnp.swapaxes(proj, -1, -2)  # (Np, Nu, Nv): v contiguous
    nu, nv = qt.shape[1], qt.shape[2]
    if bi is None or bj is None or bs is None:
        bi, bj, bs = tune.pick_blocks(
            nx, ny, nz, n_p, nu, nv, qt_dtype=qt.dtype,
            budget=vmem_budget, interpret=interpret,
            fix_bi=bi, fix_bj=bj, fix_bs=bs,
        )
    pm = pmats.reshape(n_p, 12).astype(jnp.float32)
    sc = (jnp.ones((n_p, 1), jnp.float32) if scales is None
          else scales.reshape(n_p, 1).astype(jnp.float32))
    pm = jnp.concatenate([pm, sc], axis=1)
    if n_p % bs:
        pad = bs - n_p % bs
        qt = jnp.pad(qt, ((0, pad), (0, 0), (0, 0)))
        pm = jnp.pad(pm, ((0, pad), (0, 0)), constant_values=1.0)
    dual = backproject_dual_pallas(
        pm, qt, nx, ny, nz, bi=bi, bj=bj, bs=bs, interpret=interpret
    )
    return from_dual_slab(dual)


@functools.partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def backproject_mxu(pmats: Array, proj: Array,
                    nx: int, ny: int, nz: int,
                    scales: Array | None = None) -> Array:
    """Gather-free back-projection: interpolation as relu-hat matmuls.

    For a voxel column (i,j):  val(k) = sum_{a,b} A[ij,a] * B[ij,k,b] * Q^T[a,b]
    with A[ij,a] = hat(a - u_ij), B[ij,k,b] = hat(b - v_ijk) and
    hat(t) = max(0, 1-|t|). Out-of-range coordinates get zero weight for free
    (no masking needed). Two einsums per projection:
        rows = A @ Q^T          (columns, N_v)   <- MXU
        val  = sum_b B * rows   (columns, nzh)   <- VPU reduction
    FLOP cost is ~N_u/4 + N_v/4 times the gather variant, but it maps onto
    the MXU and needs no dynamic addressing — the fallback documented in
    DESIGN.md for targets whose gather lowering is unavailable.
    """
    if nz % 2 != 0:
        raise ValueError("requires even N_z")
    nzh = nz // 2
    n_p, n_v, n_u = proj.shape
    qt = jnp.swapaxes(proj, -1, -2).astype(jnp.float32)  # (Np, Nu, Nv)
    i = jnp.arange(nx, dtype=jnp.float32)[:, None]
    j = jnp.arange(ny, dtype=jnp.float32)[None, :]
    k = jnp.arange(nzh, dtype=jnp.float32)
    ua = jnp.arange(n_u, dtype=jnp.float32)
    va = jnp.arange(n_v, dtype=jnp.float32)

    def hat(t):
        return jnp.maximum(0.0, 1.0 - jnp.abs(t))

    def body(acc, sp):
        p, q, s = sp
        x0 = p[0, 0] * i + p[0, 1] * j + p[0, 3]
        y0 = p[1, 0] * i + p[1, 1] * j + p[1, 3]
        z = p[2, 0] * i + p[2, 1] * j + p[2, 3]
        f = 1.0 / z
        u = x0 * f
        w = f * f * s                   # codec decode folded into the weight
        v = (y0[..., None] + p[1, 2] * k) * f[..., None]      # (nx, ny, nzh)
        a = hat(ua[None, None, :] - u[..., None])             # (nx, ny, Nu)
        rows = jnp.einsum("xyu,uv->xyv", a, q)                # MXU matmul
        b = hat(va[None, None, None, :] - v[..., None])       # (nx,ny,nzh,Nv)
        bm = hat(va[None, None, None, :] - ((n_v - 1.0) - v)[..., None])
        front = w[..., None] * jnp.einsum("xykv,xyv->xyk", b, rows)
        back = w[..., None] * jnp.einsum("xykv,xyv->xyk", bm, rows)
        return acc + jnp.stack([front, back], axis=-2), None

    init = jnp.zeros((nx, ny, 2, nzh), jnp.float32)
    dual, _ = jax.lax.scan(body, init, (pmats.astype(jnp.float32), qt,
                                        _stream_scales(proj, scales)))
    return from_dual_slab(dual)
