"""Pallas TPU back-projection kernel (the paper's shflBP, TPU-adapted).

Design (see DESIGN.md §2 for the CUDA->TPU mapping):

  * Volume is produced in the *dual-slab* layout (nx, ny, 2, nz/2): slab 0 is
    the front half of z, slab 1 the z-reversed back half, so a Theorem-1
    mirror pair shares one index. z runs along the TPU **lane** dimension.
  * Grid = (nx/Bi, ny/Bj, Np/Bs). The output tile (Bi, Bj, 2, nzh) stays
    resident in VMEM across the innermost (projection-batch) grid dimension —
    the TPU analogue of the paper's "batch of 32 projections per kernel
    launch" that amortizes volume traffic (global memory there, HBM here).
  * Per (i, j) column: u and w = 1/z^2 are computed once (Theorems 2/3) and
    broadcast along lanes; v is the affine ramp (y0 + k*dy) * f.
  * Bilinear interpolation is explicit arithmetic on 4 gathered taps of the
    transposed projection Q^T (Nu, Nv) — v (the fast-varying coordinate)
    indexes the contiguous minor dimension, the paper's "L1-Tran" layout.
  * The symmetric (Theorem-1) half reuses u, w, and the gathered rows with
    v~ = (Nv-1) - v.

VMEM working set per grid step:
    out tile   Bi*Bj*2*nzh*4 B
  + qt batch   Bs*Nu*Nv*{1,2,4} B
  + pmats      Bs*13*4 B   (12 matrix entries + the codec's per-projection
                            decode scale)
`vmem_bytes()` is the budgeting model the autotuner (tune.py) prunes block
candidates with. The projection batch may arrive in bf16/fp16/fp8 (the
stream codec's wire dtype — halving or quartering the qt term); taps are
upcast to f32 at the gather, the codec's per-projection scale (parameter
row column 12, 1.0 for scale-free codecs) multiplies the accumulation
weight — dequantization before the f32 FMA — and the accumulator tile is
always f32.

This container is CPU-only: the kernel is exercised with interpret=True
(Python semantics of the same body). On real TPU hardware the flat `take`
gather lowers via Mosaic's dynamic-gather on the minor dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array


def _bilinear_flat(qflat: Array, nu: int, nv: int,
                   rows: Array, cols: Array) -> Array:
    """4-tap bilinear gather from the flattened (nu*nv,) projection."""
    r0 = jnp.floor(rows)
    c0 = jnp.floor(cols)
    dr = rows - r0
    dc = cols - c0
    r0i = r0.astype(jnp.int32)
    c0i = c0.astype(jnp.int32)

    def tap(ri, ci, wgt):
        valid = (ri >= 0) & (ri < nu) & (ci >= 0) & (ci < nv)
        idx = jnp.clip(ri, 0, nu - 1) * nv + jnp.clip(ci, 0, nv - 1)
        return jnp.where(valid, jnp.take(qflat, idx) * wgt, 0.0)

    return (
        tap(r0i, c0i, (1 - dr) * (1 - dc))
        + tap(r0i, c0i + 1, (1 - dr) * dc)
        + tap(r0i + 1, c0i, dr * (1 - dc))
        + tap(r0i + 1, c0i + 1, dr * dc)
    )


def _bp_kernel(pm_ref, qt_ref, out_ref, *, bs: int, nzh: int, n_v: int):
    gi = pl.program_id(0)
    gj = pl.program_id(1)
    gs = pl.program_id(2)
    bi, bj = out_ref.shape[0], out_ref.shape[1]
    nu, nv = qt_ref.shape[1], qt_ref.shape[2]

    i = (gi * bi + lax.broadcasted_iota(jnp.float32, (bi, bj), 0))
    j = (gj * bj + lax.broadcasted_iota(jnp.float32, (bi, bj), 1))
    k = lax.broadcasted_iota(jnp.float32, (1, 1, nzh), 2)

    pm = pm_ref[...]  # (bs, 13) f32: 12 matrix entries + codec decode scale

    def step(s, acc):
        acc_f, acc_b = acc
        p = pm[s]
        qflat = qt_ref[s].astype(jnp.float32).reshape(-1)
        # Theorems 2/3: per-column invariants (2 inner products per column)
        x0 = p[0] * i + p[1] * j + p[3]
        y0 = p[4] * i + p[5] * j + p[7]
        z = p[8] * i + p[9] * j + p[11]
        f = 1.0 / z
        u = x0 * f                      # constant along k (T2)
        w = f * f * p[12]               # T3 weight x codec scale (decode)
        # v is affine in k: one FMA per voxel
        v = (y0[..., None] + p[6] * k) * f[..., None]        # (bi, bj, nzh)
        ub = jnp.broadcast_to(u[..., None], v.shape)
        front = w[..., None] * _bilinear_flat(qflat, nu, nv, ub, v)
        # Theorem-1 mirror: reuse u, w; reflect v
        back = w[..., None] * _bilinear_flat(qflat, nu, nv, ub, (n_v - 1.0) - v)
        return acc_f + front, acc_b + back

    zeros = jnp.zeros((bi, bj, nzh), jnp.float32)
    acc_f, acc_b = lax.fori_loop(0, bs, step, (zeros, zeros))
    acc = jnp.stack([acc_f, acc_b], axis=-2)  # (bi, bj, 2, nzh)

    @pl.when(gs == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(gs != 0)
    def _accum():
        out_ref[...] += acc


def vmem_bytes(bi: int, bj: int, bs: int, nu: int, nv: int, nzh: int,
               qt_dtype=jnp.float32) -> int:
    qbytes = jnp.dtype(qt_dtype).itemsize
    return bi * bj * 2 * nzh * 4 + bs * nu * nv * qbytes + bs * 13 * 4


@functools.partial(
    jax.jit, static_argnames=("nx", "ny", "nz", "bi", "bj", "bs", "interpret")
)
def backproject_dual_pallas(pmats: Array, qt: Array,
                            nx: int, ny: int, nz: int,
                            bi: int = 8, bj: int = 8, bs: int = 8,
                            interpret: bool = True) -> Array:
    """pmats (Np, 13) f32 — 12 projection-matrix entries + the stream
    codec's per-projection decode scale (pass 1.0 for unscaled streams; a
    legacy (Np, 12) matrix is widened with unit scales) — and qt (Np, Nu,
    Nv) -> dual-slab volume (nx, ny, 2, nz/2).

    Np must be a multiple of bs, nx of bi, ny of bj (ops.py pads).
    """
    n_p, nu, nv = qt.shape
    assert nz % 2 == 0 and n_p % bs == 0 and nx % bi == 0 and ny % bj == 0
    if pmats.shape[1] == 12:
        pmats = jnp.concatenate(
            [pmats, jnp.ones((n_p, 1), pmats.dtype)], axis=1)
    nzh = nz // 2
    grid = (nx // bi, ny // bj, n_p // bs)
    kernel = functools.partial(_bp_kernel, bs=bs, nzh=nzh, n_v=nv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, 13), lambda gi, gj, gs: (gs, 0)),
            pl.BlockSpec((bs, nu, nv), lambda gi, gj, gs: (gs, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bi, bj, 2, nzh), lambda gi, gj, gs: (gi, gj, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((nx, ny, 2, nzh), jnp.float32),
        interpret=interpret,
    )(pmats, qt)
