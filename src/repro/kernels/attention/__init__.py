from .ops import flash_attention
from .ref import attention_ref
