"""Pure-jnp oracle for the flash-attention Pallas kernel: causal GQA SDPA."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@partial(jax.jit, static_argnames=("causal",))
def attention_ref(q: Array, k: Array, v: Array, causal: bool = True) -> Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0.

    f32 softmax, bf16/f32 inputs. Returns (B, Sq, H, D) in q's dtype.
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if h != kh:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        iq = jnp.arange(sq)[:, None]
        ik = jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(ik <= iq, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
