"""Public wrapper: (B, S, H, D) GQA attention via the flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: Array, k: Array, v: Array,
                    causal: bool = True,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D), H % K == 0 (GQA repeat)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if interpret is None:
        interpret = not _on_tpu()
    if h != kh:
        k = jnp.repeat(k, h // kh, axis=2)
        v = jnp.repeat(v, h // kh, axis=2)
    bq = min(bq, sq)
    bk = min(bk, k.shape[1])
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    out = flash_attention_bhsd(qf, kf, vf, bq=bq, bk=bk, causal=causal,
                               interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
