"""Flash-attention Pallas TPU kernel (online-softmax, causal, GQA-folded).

The LM substrate's perf-critical compute layer for the prefill_32k cells:
never materializes the (Sq, Sk) score matrix. Standard blocked structure:

  grid = (B*H, Sq/Bq, Sk/Bk)   (k-block innermost: output block revisited)
  VMEM per step: q (Bq, D) + k/v (Bk, D) + out (Bq, D)
               + scratch m/l (Bq,), acc (Bq, D)

Carries the running max (m) and normalizer (l) in VMEM scratch across the
k-block loop — the Flash-Attention-2 recurrence. Causality skips
fully-masked k-blocks via pl.when on the block indices.

Validated with interpret=True against ref.attention_ref (CPU container);
block shapes default to MXU-aligned (128, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               *, scale: float, bq: int, bk: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip k-blocks strictly above the diagonal
    run = (ki * bk <= qi * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)              # (Bq, D)
        k = k_ref[0].astype(jnp.float32)              # (Bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = (q @ k.T) * scale                         # (Bq, Bk)
        if causal:
            iq = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ik = ki * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(ik <= iq, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "causal", "interpret")
)
def flash_attention_bhsd(q: Array, k: Array, v: Array,
                         bq: int = 128, bk: int = 128,
                         causal: bool = True,
                         interpret: bool = True) -> Array:
    """Fused attention over (BH, S, D) folded batch-head arrays."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0
    scale = 1.0 / np.sqrt(d)
    grid = (bh, sq // bq, sk // bk)
    kernel = functools.partial(_fa_kernel, scale=scale, bq=bq, bk=bk,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running normalizer l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
