"""3-D Shepp-Logan phantom and analytic cone-beam forward projector.

The paper (§5.1) generates test projections with RTK's forward projector from
the standard Shepp-Logan phantom; reconstruction quality is then verified
against the reference implementation (RMSE < 1e-5) and visually. We do the
same end-to-end, but use the *analytic* line integral through the phantom's
ellipsoids — exact, sampling-free, and fast enough on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import CBCTGeometry, detector_pixel_position, source_position

Array = jax.Array

# (rho, a, b, c, x0, y0, z0, phi_deg) -- modified (high-contrast) 3D
# Shepp-Logan, Kak-Slaney / phantom3d parameterisation, z-rotation only.
SHEPP_LOGAN_3D = np.array(
    [
        [1.00, 0.6900, 0.920, 0.810, 0.00, 0.000, 0.00, 0.0],
        [-0.80, 0.6624, 0.874, 0.780, 0.00, -0.0184, 0.00, 0.0],
        [-0.20, 0.1100, 0.310, 0.220, 0.22, 0.000, 0.00, -18.0],
        [-0.20, 0.1600, 0.410, 0.280, -0.22, 0.000, 0.00, 18.0],
        [0.10, 0.2100, 0.250, 0.410, 0.00, 0.350, -0.15, 0.0],
        [0.10, 0.0460, 0.046, 0.050, 0.00, 0.100, 0.25, 0.0],
        [0.10, 0.0460, 0.046, 0.050, 0.00, -0.100, 0.25, 0.0],
        [0.10, 0.0460, 0.023, 0.050, -0.08, -0.605, 0.00, 0.0],
        [0.10, 0.0230, 0.023, 0.020, 0.00, -0.606, 0.00, 0.0],
        [0.10, 0.0230, 0.046, 0.020, 0.06, -0.605, 0.00, 0.0],
    ],
    dtype=np.float64,
)


def _ellipsoid_frames(table: np.ndarray):
    """Per-ellipsoid (center, inv-axes rotation) for unit-sphere mapping."""
    rho = table[:, 0]
    axes = table[:, 1:4]
    centers = table[:, 4:7]
    phi = np.deg2rad(table[:, 7])
    c, s = np.cos(phi), np.sin(phi)
    zeros, ones = np.zeros_like(c), np.ones_like(c)
    # rotation about z by -phi composed with axis scaling: M = diag(1/a) @ Rz(-phi)
    rot = np.stack(
        [
            np.stack([c, s, zeros], -1),
            np.stack([-s, c, zeros], -1),
            np.stack([zeros, zeros, ones], -1),
        ],
        axis=-2,
    )  # (E, 3, 3)
    minv = rot / axes[:, :, None]  # scale rows by 1/axes
    return rho, centers, minv


@partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def _phantom_volume(rho: Array, centers: Array, minv: Array,
                    nx: int, ny: int, nz: int,
                    dx: float, dy: float, dz: float) -> Array:
    """Voxelize: world coords match geometry.py's M0 (gantry frame)."""
    i = jnp.arange(nx, dtype=jnp.float32)
    j = jnp.arange(ny, dtype=jnp.float32)
    k = jnp.arange(nz, dtype=jnp.float32)
    gx = dx * (i - (nx - 1) / 2.0)
    gy = -dy * (j - (ny - 1) / 2.0)
    gz = -dz * (k - (nz - 1) / 2.0)
    pts = jnp.stack(
        jnp.meshgrid(gx, gy, gz, indexing="ij"), axis=-1
    )  # (nx, ny, nz, 3)

    def one(e_rho, e_c, e_m):
        q = jnp.einsum("ab,xyzb->xyza", e_m, pts - e_c)
        return e_rho * (jnp.sum(q * q, -1) <= 1.0).astype(jnp.float32)

    vol = jax.vmap(one)(rho, centers, minv).sum(0)
    return vol


def shepp_logan_volume(g: CBCTGeometry) -> Array:
    """The phantom voxelized on the geometry's grid, shape (n_x, n_y, n_z)."""
    rho, centers, minv = _ellipsoid_frames(SHEPP_LOGAN_3D)
    return _phantom_volume(
        jnp.asarray(rho, jnp.float32), jnp.asarray(centers, jnp.float32),
        jnp.asarray(minv, jnp.float32),
        g.n_x, g.n_y, g.n_z, g.d_x, g.d_y, g.d_z,
    )


@jax.jit
def _project_one_angle(rho: Array, centers: Array, minv: Array,
                       src: Array, pix: Array) -> Array:
    """Analytic chord lengths from source `src` to each pixel in `pix`.

    pix: (n_v, n_u, 3) world positions. Returns (n_v, n_u) line integrals.
    """
    d = pix - src  # ray directions (not normalized)
    dn = jnp.linalg.norm(d, axis=-1, keepdims=True)
    d = d / dn

    def one(e_rho, e_c, e_m):
        o = jnp.einsum("ab,b->a", e_m, src - e_c)  # (3,)
        dd = jnp.einsum("ab,vub->vua", e_m, d)
        a = jnp.sum(dd * dd, -1)
        b = 2.0 * jnp.einsum("a,vua->vu", o, dd)
        c = jnp.sum(o * o) - 1.0
        disc = b * b - 4.0 * a * c
        chord = jnp.where(disc > 0.0, jnp.sqrt(jnp.maximum(disc, 0.0)) / a, 0.0)
        return e_rho * chord

    return jax.vmap(one)(rho, centers, minv).sum(0)


def forward_project(g: CBCTGeometry, dtype=jnp.float32) -> Array:
    """Analytic cone-beam projections of the Shepp-Logan phantom.

    Returns (N_p, N_v, N_u) — the paper's E input.
    """
    rho, centers, minv = _ellipsoid_frames(SHEPP_LOGAN_3D)
    rho = jnp.asarray(rho, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    minv = jnp.asarray(minv, jnp.float32)
    iu = np.arange(g.n_u)
    iv = np.arange(g.n_v)
    iuu, ivv = np.meshgrid(iu, iv, indexing="xy")  # (n_v, n_u)
    out = []
    for beta in g.angles:
        src = jnp.asarray(source_position(g, beta), jnp.float32)
        pix = jnp.asarray(
            detector_pixel_position(g, beta, iuu, ivv), jnp.float32
        )
        out.append(_project_one_angle(rho, centers, minv, src, pix))
    return jnp.stack(out).astype(dtype)
