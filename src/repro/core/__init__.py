from .geometry import CBCTGeometry, default_geometry, projection_matrices
from .fdk import reconstruct, fdk_scale, gups
from .plan import ReconstructionPlan, plan_from_spec
