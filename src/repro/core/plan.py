"""Declarative reconstruction plans + the staged engine (paper §4, unified).

The paper's framework is ONE pipeline — load/filter -> column AllGather ->
slab back-projection -> row Reduce — previously implemented four times
(`fdk.reconstruct`, `make_distributed_fdk`, `make_pipelined_fdk`,
`make_chunked_fdk`), each separately threading precision, filter, impl
dispatch, shard_map and reduce logic. This module replaces the fork with a
plan -> build -> run engine:

    plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="pipelined",
                              n_steps=4, reduce="scatter", precision="bf16")
    fdk = plan.build()          # validated, tuned, jitted — cached per plan
    volume = fdk(projections)

A `ReconstructionPlan` is a frozen dataclass capturing every degree of
freedom of the pipeline; `validate()` centralizes the divisibility checks
that used to live inline in each builder, and `build()` composes shared
stage primitives:

    filter stage         make_filter(window, storage dtype)   [per batch]
    gather schedule      column AllGather over the `model` axis
    slab back-projection shift_pmats_i (x-slab) / shift_pmats_j (y-chunk)
    reduce epilogue      psum (replicated) | psum_scatter (sharded store)

into one rank function, run under shard_map when a mesh is given and
directly on one device when not. The schedule x reduce x precision x impl
cross-product is fully available — including combinations the legacy
builders never offered (chunked+psum, pipelined single-device).

Tuned Pallas block shapes for `impl="kernel"` are resolved ONCE at plan
time (kernels/backproject/tune.py, file-backed cache) instead of per-call
inside ops.py, and can be pinned explicitly via `blocks=(bi, bj, bs)`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, Literal, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import numpy as np

from repro.compat import shard_map
from repro.obs.trace import get_tracer
from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD, axis_size
from .cache import CountingLRU
from .distributed import (
    IFDKGrid, SCATTER_REDUCES, _proj_spec, input_sharding, output_spec,
    shift_pmats_i,
)
from .fdk import BpImpl, _get_backprojector, fdk_scale
from .filtering import _WINDOWS, make_filter
from .geometry import CBCTGeometry, projection_matrices
from .precision import Precision, resolve_precision

Array = jax.Array

Schedule = Literal["fused", "pipelined", "chunked", "incremental"]
ReduceMode = Literal["psum", "scatter", "scatter_bf16"]

_SCHEDULES = ("fused", "pipelined", "chunked", "incremental")
_REDUCES = ("psum",) + SCATTER_REDUCES
_IMPLS = ("reference", "factorized", "kernel")
_PRECISIONS = ("fp32", "bf16", "fp16", "fp8_e4m3", "fp8_e5m2")

# build()/build_batched() results, keyed by the (hashable) plan (plus batch
# size for batched engines): repeated builds of the same plan reuse the
# jitted function, so `reconstruct(...)`-style per-call wrappers never
# re-trace. Bounded LRU: engines pin compiled XLA executables, and a
# long-lived service seeing many scan families must not leak them; the
# hit/miss counters feed the service stats (repro/service).
_ENGINE_CACHE = CountingLRU(capacity=64, name="core.engine_cache")


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()


def _traced_call(fn: Callable, name: str, attrs: dict) -> Callable:
    """Wrap an engine callable in a fenced span when the process tracer is
    on. The disabled path is ONE attribute load + branch per call (the
    <1%-overhead contract, tests/test_obs.py); `attrs` are fixed at build
    time so the hot path allocates nothing. The span's `dispatch_us` arg is
    the async-dispatch time, its total duration dispatch + device compute
    (`Span.fence` semantics)."""
    def call(*args, **kwargs):
        tracer = get_tracer()
        if not tracer.enabled:
            return fn(*args, **kwargs)
        with tracer.span(name, **attrs) as sp:
            out = fn(*args, **kwargs)
            sp.fence(out)
        return out
    call.__wrapped__ = fn
    return call


def engine_cache_stats() -> dict:
    """hit/miss/eviction/unhashable counters of the shared engine cache."""
    return _ENGINE_CACHE.stats()


def bp_call_shape(g: CBCTGeometry, r: int, c: int, schedule: str,
                  n_steps: int, y_chunks: Optional[int]
                  ) -> Tuple[int, int, int]:
    """(nx, ny, n_p) of ONE back-projection call under a plan point: the
    x-slab (and y-chunk, if chunked) of one gathered micro-batch. The one
    formula shared by the engine's block resolution and the planner's
    kernel-VMEM feasibility check (planner/feasibility.py)."""
    nx_call = g.n_x // r
    ny_call = (g.n_y // y_chunks if schedule == "chunked" and y_chunks
               else g.n_y)
    np_call = g.n_proj // (c * n_steps)
    return nx_call, ny_call, np_call


def shift_pmats_j(pmats: Array, j0) -> Array:
    """Reparameterize P for a y-chunk starting at voxel index j0 (same trick
    as distributed.shift_pmats_i, on the j column)."""
    shift = pmats[..., :, 1] * j0
    return pmats.at[..., :, 3].add(shift)


@dataclasses.dataclass
class _Stages:
    """The engine's shared per-rank stage primitives, composed once per plan
    and reused by every schedule's rank function AND the incremental
    session (`build_incremental`) — the one place the filter/encode/gather,
    slab reparameterization and row-reduce logic is defined."""

    gather_batch: Callable   # (pm_b, raw_b) -> (pm_col, q_col, scales_col)
    filter_encode: Callable  # raw_b -> (data_b, scales_b)  [no collectives]
    gather_cols: Callable    # (pm_b, data_b, scales_b) -> gathered columns
    slab_pmats: Callable     # pm_col -> P shifted to this rank's x-slab
    reduce_slab: Callable    # full-slab row-reduce epilogue (fused/pipelined)
    backproject: Callable    # resolved impl (tuned blocks for "kernel")
    nx_slab: int
    scale: float             # fdk_scale(geometry)
    model_axis: Optional[str]
    data_axis: Optional[str]
    pod_axis: Optional[str]
    dp: Tuple[str, ...]      # row-reduce axes present on the mesh


@dataclasses.dataclass(frozen=True)
class ReconstructionPlan:
    """Everything that determines a reconstruction, in one declarative value.

    Fields
    ------
    geometry   : the CBCT scan geometry (paper Table 1).
    mesh       : device mesh; None = plain single-device execution (no
                 shard_map). The paper's R x C rank grid is derived from it:
                 R = `model` axis (volume slabs), C = `pod` x `data`
                 (projection groups) — see `grid`.
    impl       : back-projection implementation ("reference" | "factorized"
                 | "kernel").
    window     : ramp-filter apodization window.
    precision  : storage dtype policy of the filtered-projection stream
                 (core/precision.py): a Precision, a name, or None for the
                 backend default. Accumulation is always f32.
    schedule   : "fused"     — one gather, one slab back-projection;
                 "pipelined" — lax.scan over `n_steps` micro-batches, the
                               AllGather of batch s overlapping the
                               back-projection of batch s-1 (paper Fig. 4);
                 "chunked"   — pipelined + per-y-chunk reduce (streaming
                               output side; bounds the live slab state).
    n_steps    : projection micro-batches per rank (pipelined/chunked).
    y_chunks   : y-axis chunks (chunked only).
    reduce     : row-reduce epilogue. "psum" replicates the slab; "scatter"
                 leaves it sharded over `data` for the parallel store
                 (requires a mesh with a `data` axis); "scatter_bf16" is
                 scatter at half the reduce wire bytes — partial slabs are
                 quantized to bf16 before the psum_scatter and the result
                 upcast to f32, with an f32 error-feedback carry under the
                 chunked schedule (each step's quantization residual is
                 re-injected into the next step's partial, so the error
                 does not grow with n_steps). See DESIGN.md (codec layer)
                 for the error model.
    blocks     : explicit (bi, bj, bs) Pallas tile for impl="kernel";
                 None = resolve from the VMEM-budget autotuner at plan time.
    vmem_budget: byte budget handed to the autotuner (None = env default).
    """

    geometry: CBCTGeometry
    mesh: Optional[Mesh] = None
    impl: BpImpl = "factorized"
    window: str = "ramlak"
    precision: Precision | str | None = "fp32"
    schedule: Schedule = "fused"
    n_steps: int = 1
    y_chunks: Optional[int] = None
    reduce: ReduceMode = "psum"
    blocks: Optional[Tuple[int, int, int]] = None
    vmem_budget: Optional[int] = None

    # -- derived quantities -------------------------------------------------

    @property
    def grid(self) -> IFDKGrid:
        """The paper's R (slabs) x C (projection groups) rank grid."""
        if self.mesh is None:
            return IFDKGrid(r=1, c=1)
        return IFDKGrid(r=axis_size(self.mesh, AXIS_MODEL),
                        c=axis_size(self.mesh, AXIS_POD, AXIS_DATA))

    @property
    def _data_size(self) -> int:
        return axis_size(self.mesh, AXIS_DATA) if self.mesh is not None else 1

    def resolved_precision(self) -> Precision:
        return resolve_precision(self.precision)

    # -- validation ---------------------------------------------------------

    def validate(self) -> "ReconstructionPlan":
        """Centralized feasibility checks (every legacy builder's scattered
        divisibility tests live here, with uniform error messages)."""
        g = self.geometry
        if self.impl not in _IMPLS:
            raise ValueError(
                f"unknown back-projection impl {self.impl!r}; "
                f"choose from {_IMPLS}")
        if self.window not in _WINDOWS:
            raise ValueError(
                f"unknown window {self.window!r}; choose from {_WINDOWS}")
        if self.schedule not in _SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; "
                f"choose from {_SCHEDULES}")
        if self.reduce not in _REDUCES:
            raise ValueError(
                f"unknown reduce mode {self.reduce!r}; "
                f"choose from {_REDUCES}")
        resolve_precision(self.precision)  # raises on unknown storage
        if self.mesh is not None and AXIS_MODEL not in self.mesh.axis_names:
            raise ValueError(
                f"mesh axes {self.mesh.axis_names} lack the {AXIS_MODEL!r} "
                "axis that carries the paper's R volume slabs")
        grid = self.grid
        n_ranks = grid.n_ranks
        if g.n_proj % n_ranks:
            raise ValueError(
                f"N_p={g.n_proj} must divide over the {n_ranks} ranks of "
                f"the R={grid.r} x C={grid.c} grid")
        if g.n_x % grid.r:
            raise ValueError(
                f"N_x={g.n_x} must divide into R={grid.r} volume slabs")
        if self.n_steps < 1:
            raise ValueError(f"n_steps={self.n_steps} must be >= 1")
        if self.schedule == "fused" and self.n_steps != 1:
            raise ValueError(
                "the fused schedule has no micro-batching; use "
                "schedule='pipelined' (or 'chunked') for n_steps > 1")
        np_local = g.n_proj // n_ranks
        if np_local % self.n_steps:
            raise ValueError(
                f"per-rank N_p={np_local} must divide into "
                f"n_steps={self.n_steps} micro-batches")
        if self.schedule == "chunked":
            if self.y_chunks is None:
                raise ValueError("the chunked schedule requires y_chunks")
            if g.n_y % self.y_chunks:
                raise ValueError(
                    f"N_y={g.n_y} must divide into y_chunks={self.y_chunks}")
        elif self.y_chunks is not None:
            raise ValueError(
                "y_chunks only applies to the chunked schedule")
        if self.reduce in SCATTER_REDUCES:
            if self.mesh is None or AXIS_DATA not in self.mesh.axis_names:
                raise ValueError(
                    f"reduce={self.reduce!r} needs a mesh with a 'data' "
                    "axis to scatter over; use reduce='psum' on a single "
                    "device")
            scatter_extent = (g.n_y // self.y_chunks
                              if self.schedule == "chunked" else g.n_y)
            if scatter_extent % self._data_size:
                raise ValueError(
                    f"scatter extent {scatter_extent} (y) must divide over "
                    f"the data axis of size {self._data_size}")
        if self.blocks is not None and self.impl != "kernel":
            raise ValueError(
                "blocks=(bi, bj, bs) only applies to impl='kernel'")
        if self.impl == "kernel" and g.n_z % 2:
            raise ValueError(
                f"impl='kernel' requires even N_z (dual-slab layout), "
                f"got N_z={g.n_z}")
        if self.blocks is not None:
            bi, bj, bs = self.blocks
            nx_call, ny_call, _ = self._bp_call_shape()
            if bi < 1 or bj < 1 or bs < 1:
                raise ValueError(f"blocks={self.blocks} must be positive")
            # bs need not divide the projection count (ops.py pads), but the
            # output tile must tile the per-call slab exactly.
            if nx_call % bi or ny_call % bj:
                raise ValueError(
                    f"blocks=(bi={bi}, bj={bj}) must tile the per-call "
                    f"back-projection slab ({nx_call}, {ny_call}) — the "
                    f"x-slab/y-chunk of one gathered micro-batch")
        return self

    # -- kernel block resolution (plan-time, not per-call) ------------------

    def _bp_call_shape(self) -> Tuple[int, int, int]:
        grid = self.grid
        return bp_call_shape(self.geometry, grid.r, grid.c, self.schedule,
                             self.n_steps, self.y_chunks)

    def resolved_blocks(self) -> Optional[Tuple[int, int, int]]:
        """The (bi, bj, bs) Pallas tile this plan will run with — explicit
        `blocks` if given, else the autotuner's pick for the per-call
        back-projection shape. None for non-kernel impls."""
        if self.impl != "kernel":
            return None
        if self.blocks is not None:
            return tuple(self.blocks)
        from repro.kernels.backproject import tune
        g = self.geometry
        nx_call, ny_call, np_call = self._bp_call_shape()
        prec = self.resolved_precision()
        return tune.pick_blocks(nx_call, ny_call, g.n_z, np_call,
                                g.n_u, g.n_v,
                                qt_dtype=prec.storage_dtype,
                                budget=self.vmem_budget)

    def _resolve_backprojector(self) -> Callable:
        if self.impl != "kernel":
            return _get_backprojector(self.impl)
        from repro.kernels.backproject.ops import backproject_pallas
        bi, bj, bs = self.resolved_blocks()
        return partial(backproject_pallas, bi=bi, bj=bj, bs=bs)

    def _span_attrs(self) -> dict:
        """Fixed span args of this plan's engines (trace labels) — built
        once at build() time, JSON-plain for the Perfetto export."""
        grid = self.grid
        return {
            "schedule": self.schedule,
            "impl": self.impl,
            "reduce": self.reduce,
            "precision": self.resolved_precision().storage,
            "grid": f"{grid.r}x{grid.c}",
            "n_steps": self.n_steps,
        }

    def describe(self) -> dict:
        """Flat summary of the resolved plan (benchmark/report labels)."""
        grid = self.grid
        return {
            "schedule": self.schedule,
            "impl": self.impl,
            "window": self.window,
            "precision": self.resolved_precision().storage,
            "grid": (grid.r, grid.c),
            "n_steps": self.n_steps,
            "y_chunks": self.y_chunks,
            "reduce": self.reduce,
            "blocks": self.resolved_blocks(),
        }

    # -- engine -------------------------------------------------------------

    def _output_spec(self) -> Optional[P]:
        if self.mesh is None:
            return None
        if self.schedule == "chunked" and self.reduce in SCATTER_REDUCES:
            # (nx_slab, y_chunks, yc/dp, nz): x over model, chunk interior
            # scattered over data; reshape(nx, ny, nz) outside restores the
            # canonical volume.
            return P(AXIS_MODEL, None, AXIS_DATA, None)
        return output_spec(self.mesh, self.reduce)

    def _make_stages(self) -> _Stages:
        """Compose the shared stage primitives for this plan's mesh/precision
        — the building blocks both `_build_rank_fn` (batch schedules) and
        `IncrementalSession` (streaming) assemble their rank functions from."""
        g = self.geometry
        mesh = self.mesh
        grid = self.grid
        model_axis = (AXIS_MODEL if mesh is not None
                      and AXIS_MODEL in mesh.axis_names else None)
        data_axis = (AXIS_DATA if mesh is not None
                     and AXIS_DATA in mesh.axis_names else None)
        pod_axis = (AXIS_POD if mesh is not None
                    and AXIS_POD in mesh.axis_names else None)
        dp = tuple(a for a in (pod_axis, data_axis) if a is not None)
        nx_slab = g.n_x // grid.r
        prec = self.resolved_precision()
        codec = prec.codec
        # The filter emits f32; the stream codec owns the quantization to
        # the wire format (scale-free codecs are a plain cast — fused under
        # jit, byte-identical to casting inside the filter).
        filt = make_filter(g, self.window, out_dtype=jnp.float32)

        # --- stage: filter + encode + column AllGather (paper Fig. 3b) -----
        # The AllGather moves the codec's WIRE format: quantized data plus,
        # for scaled codecs (fp8), the per-projection f32 scale sidecar.
        # Split in two: `filter_encode` is per-projection-independent and
        # collective-free (the batched engine hoists it out of its vmap —
        # the FFT must not see a vmap batch dim, see build_batched), while
        # `gather_cols` moves the wire bytes over the model axis.
        def filter_encode(raw_b: Array):
            return codec.encode(filt(raw_b))

        def gather_cols(pm_b: Array, data: Array, scales):
            if model_axis is None:
                return pm_b, data, scales
            gathered_scales = (
                None if scales is None
                else lax.all_gather(scales, model_axis, axis=0, tiled=True))
            return (lax.all_gather(pm_b, model_axis, axis=0, tiled=True),
                    lax.all_gather(data, model_axis, axis=0, tiled=True),
                    gathered_scales)

        def gather_batch(pm_b: Array, raw_b: Array):
            return gather_cols(pm_b, *filter_encode(raw_b))

        # --- stage: x-slab reparameterization (offset folded into P) -------
        def slab_pmats(pm_col: Array) -> Array:
            if model_axis is None:
                return pm_col
            i0 = lax.axis_index(model_axis) * nx_slab
            return shift_pmats_i(pm_col, i0.astype(pm_col.dtype))

        # --- stage: row-reduce epilogue (fused/pipelined full slab) --------
        # "scatter_bf16" moves the partial slab at half width: quantize to
        # bf16, psum_scatter, upcast — ONE rounding per rank (relative error
        # <= C_data * eps_bf16/2 on the reduced slab); the cross-pod finish
        # stays f32. Plain "scatter"/"psum" paths are byte-identical to the
        # f32 collective (the astype(f32) is a no-op on an f32 slab).
        def reduce_slab(slab: Array) -> Array:
            if not dp:
                return slab
            if self.reduce in SCATTER_REDUCES:
                if self.reduce == "scatter_bf16":
                    slab = slab.astype(jnp.bfloat16)
                slab = lax.psum_scatter(slab, dp[-1], scatter_dimension=1,
                                        tiled=True).astype(jnp.float32)
                for a in dp[:-1]:  # multi-pod: finish across pods
                    slab = lax.psum(slab, a)
                return slab
            for a in dp:
                slab = lax.psum(slab, a)
            return slab

        return _Stages(
            gather_batch=gather_batch, filter_encode=filter_encode,
            gather_cols=gather_cols, slab_pmats=slab_pmats,
            reduce_slab=reduce_slab,
            backproject=self._resolve_backprojector(),
            nx_slab=nx_slab, scale=fdk_scale(g),
            model_axis=model_axis, data_axis=data_axis, pod_axis=pod_axis,
            dp=dp,
        )

    def _build_rank_fn(self, st: Optional[_Stages] = None,
                       encoded: bool = False) -> Callable:
        """Compose the shared stage primitives into one per-rank function.

        encoded=False (the build() path): rank_fn(pm_local, proj_local)
        takes RAW per-rank projections and runs filter + encode inline
        (inside the scan for the micro-batched schedules).

        encoded=True (the build_batched() path): rank_fn(pm_local,
        data_local, sc_local) takes the codec's WIRE-format stream (+ scale
        sidecar, or None) and starts at the column AllGather — the batched
        engine hoists filter_encode out of its vmap, because XLA's CPU FFT
        rejects the non-dim0-major layouts a vmap batch dim induces, and
        filtering/encoding are per-projection-independent anyway (bit-equal
        hoisted or inline). Both variants share ONE copy of each schedule
        body below.
        """
        g = self.geometry
        grid = self.grid
        st = st if st is not None else self._make_stages()
        gather_batch = st.gather_batch
        gather_cols = st.gather_cols
        slab_pmats = st.slab_pmats
        reduce_slab = st.reduce_slab
        backproject = st.backproject
        nx_slab = st.nx_slab
        scale = st.scale
        data_axis = st.data_axis
        pod_axis = st.pod_axis
        n_steps = self.n_steps
        nb = g.n_proj // grid.n_ranks // n_steps

        # Normalize both input shapes to (payload tuple, gather callable):
        # schedule bodies below are written once against `gath(pm_b, *pl)`.
        if encoded:
            def make_rank(schedule_fn):
                def rank_fn(pm_local, data_local, sc_local=None):
                    if sc_local is None:
                        return schedule_fn(
                            pm_local, (data_local,),
                            lambda pm_b, d_b: gather_cols(pm_b, d_b, None))
                    return schedule_fn(pm_local, (data_local, sc_local),
                                       gather_cols)
                return rank_fn
        else:
            def make_rank(schedule_fn):
                def rank_fn(pm_local, proj_local):
                    return schedule_fn(pm_local, (proj_local,), gather_batch)
                return rank_fn

        def split_steps(pm_local, payload):
            pm_steps = pm_local.reshape(n_steps, nb, 3, 4)
            steps = tuple(x.reshape((n_steps, nb) + x.shape[1:])
                          for x in payload)
            return pm_steps, steps

        if self.schedule == "fused":
            def fused(pm_local, payload, gath):
                pm_col, q_col, sc_col = gath(pm_local, *payload)
                slab = backproject(slab_pmats(pm_col), q_col,
                                   nx_slab, g.n_y, g.n_z, scales=sc_col)
                return reduce_slab(slab) * scale
            return make_rank(fused)

        if self.schedule == "pipelined":
            def pipelined(pm_local, payload, gath):
                pm_steps, steps = split_steps(pm_local, payload)
                buf = gath(pm_steps[0], *(x[0] for x in steps))  # prologue

                def step(carry, xs):
                    acc, (pm_prev, q_prev, sc_prev) = carry
                    nxt = gath(*xs)                # comm for batch s
                    acc = acc + backproject(        # compute for batch s-1
                        slab_pmats(pm_prev), q_prev, nx_slab, g.n_y, g.n_z,
                        scales=sc_prev)
                    return (acc, nxt), None

                init = (jnp.zeros((nx_slab, g.n_y, g.n_z), jnp.float32), buf)
                (acc, (pm_last, q_last, sc_last)), _ = lax.scan(
                    step, init,
                    (pm_steps[1:],) + tuple(x[1:] for x in steps))
                acc = acc + backproject(            # epilogue
                    slab_pmats(pm_last), q_last, nx_slab, g.n_y, g.n_z,
                    scales=sc_last)
                return reduce_slab(acc) * scale
            return make_rank(pipelined)

        # chunked: per-y-chunk back-projection with an immediate per-chunk
        # reduce, bounding the live slab state (output-side streaming).
        y_chunks = self.y_chunks
        yc = g.n_y // y_chunks
        scatter = self.reduce in SCATTER_REDUCES
        compensated = self.reduce == "scatter_bf16"
        yc_local = yc // self._data_size if scatter else yc

        def chunk_reduce(part: Array) -> Array:
            if scatter:
                return lax.psum_scatter(part, data_axis, scatter_dimension=1,
                                        tiled=True)
            if data_axis is not None:
                part = lax.psum(part, data_axis)
            return part

        def chunked(pm_local, payload, gath):
            pm_steps, steps = split_steps(pm_local, payload)
            buf = gath(pm_steps[0], *(x[0] for x in steps))

            def bp_chunks(state, pm_col, q_col, sc_col):
                acc, err = state
                pm_slab = slab_pmats(pm_col)

                def one_chunk(ci, st):
                    a, e = st
                    pm_c = shift_pmats_j(pm_slab,
                                         (ci * yc).astype(pm_slab.dtype))
                    part = backproject(pm_c, q_col, nx_slab, yc, g.n_z,
                                       scales=sc_col)
                    if compensated:
                        # error feedback: re-inject the residual this rank
                        # dropped when it quantized the SAME chunk last
                        # round, so quantization error does not accumulate
                        # over the n_steps micro-batches — only the final
                        # round's rounding survives (one per rank).
                        part = part + lax.dynamic_index_in_dim(
                            e, ci, axis=1, keepdims=False)
                        half = part.astype(jnp.bfloat16)
                        e = lax.dynamic_update_index_in_dim(
                            e, part - half.astype(jnp.float32), ci, axis=1)
                        red = lax.psum_scatter(
                            half, data_axis, scatter_dimension=1,
                            tiled=True).astype(jnp.float32)
                    else:
                        red = chunk_reduce(part)
                    a = lax.dynamic_update_index_in_dim(
                        a, a[:, ci] + red, ci, axis=1)
                    return a, e

                return lax.fori_loop(0, y_chunks, one_chunk, (acc, err))

            def step(carry, xs):
                state, prev = carry
                nxt = gath(*xs)                    # comm for batch s
                state = bp_chunks(state, *prev)    # compute for batch s-1
                return (state, nxt), None

            acc0 = jnp.zeros((nx_slab, y_chunks, yc_local, g.n_z),
                             jnp.float32)
            err0 = (jnp.zeros((nx_slab, y_chunks, yc, g.n_z), jnp.float32)
                    if compensated else None)
            ((acc, err), last), _ = lax.scan(
                step, ((acc0, err0), buf),
                (pm_steps[1:],) + tuple(x[1:] for x in steps))
            acc, _ = bp_chunks((acc, err), *last)  # epilogue
            if pod_axis is not None:
                acc = lax.psum(acc, pod_axis)
            if not scatter:
                # dims 1,2 are contiguous locally when nothing is scattered
                acc = acc.reshape(nx_slab, g.n_y, g.n_z)
            return acc * scale

        return make_rank(chunked)

    def build(self, source=None, sink=None) -> Callable[[Array], Array]:
        """Validated, tuned, jitted reconstruction: projections -> volume.

        Input : (N_p, N_v, N_u) projections — sharded with
                `input_sharding(mesh)` when the plan has a mesh.
        Output: (N_x, N_y, N_z) f32; x slab-sharded over `model` on a mesh,
                plus y sharded over `data` with reduce="scatter". The
                chunked+scatter combination returns the 4-D
                (N_x, y_chunks, N_y/y_chunks/C_data, N_z) store layout —
                reshape(N_x, N_y, N_z) restores the canonical volume.

        `source`/`sink` (repro/io/streams.py) close the pipeline at the
        filesystem like the paper's ranks do: with a `ProjectionSource` the
        returned callable may be invoked with no argument — each rank
        scatter-reads only its own projection slice; with a `VolumeSink`
        the sharded output volume is streamed shard-per-file to the store
        before being returned (the slice-per-rank PFS write).

        Results are cached per plan, so repeated builds (and the thin
        legacy wrappers that build per call) never re-trace.
        """
        if self.schedule == "incremental":
            raise ValueError(
                "schedule='incremental' is stateful (projections arrive as "
                "deltas); use plan.build_incremental() to obtain a "
                "streaming session instead of build()")
        if source is not None or sink is not None:
            return self._build_with_io(source, sink)
        # Counted LRU: unhashable keys (exotic meshes) are counted inside
        # and fall through to an uncached build.
        cached = _ENGINE_CACHE.get(self)
        if cached is not None:
            return cached
        self.validate()
        rank_fn = self._build_rank_fn()
        pmats_all = jnp.asarray(projection_matrices(self.geometry))
        if self.mesh is None:
            @jax.jit
            def reconstruct_fn(projections: Array) -> Array:
                return rank_fn(pmats_all, projections)
        else:
            mesh = self.mesh
            pspec = _proj_spec(mesh)
            out_sp = self._output_spec()

            @jax.jit
            def reconstruct_fn(projections: Array) -> Array:
                return shard_map(
                    rank_fn, mesh=mesh,
                    in_specs=(pspec, pspec),
                    out_specs=out_sp,
                    check_vma=False,
                )(pmats_all, projections)

        reconstruct_fn = _traced_call(
            reconstruct_fn, "engine.reconstruct", self._span_attrs())
        _ENGINE_CACHE.put(self, reconstruct_fn)
        return reconstruct_fn

    def build_batched(self, batch_size: int) -> Callable[[Array], Array]:
        """Batched engine: reconstruct `batch_size` same-geometry scans in
        ONE dispatch — the service layer's geometry-bucketed serving path.

        Input : (B, N_p, N_v, N_u) projections, B == batch_size. On a mesh
                each scan is sharded like build()'s input with the scan axis
                replicated — place with `batched_input_sharding(mesh)`.
        Output: (B, N_x, N_y, N_z) f32 (or B x the plan's 4-D chunked+
                scatter store layout), sharded per scan like build()'s.

        Exactness contract (tests/test_batched.py): lane b of the output is
        BIT-IDENTICAL to `self.build()(projections[b])` — padding a bucket
        with junk scans cannot perturb real ones, and a served scan equals
        the single-scan answer exactly. Two ingredients make this hold:
        filter+encode are hoisted out of the vmap and run on the flattened
        (B*N_p) projection axis (per-projection-independent ops, bit-equal
        to per-scan application; also keeps the FFT away from vmap batch
        dims, which XLA's CPU FFT thunk rejects), and the back-projectors
        pin their P-derived coordinate chains behind an optimization
        barrier so batched and unbatched compilations contract FMAs
        identically (core/backprojection.py).

        Engines are cached per (plan, batch_size) in the same counted LRU
        as build()'s.
        """
        if self.schedule == "incremental":
            raise ValueError(
                "schedule='incremental' is stateful; the batched serving "
                "path needs a batch schedule (fused/pipelined/chunked)")
        bsz = int(batch_size)
        if bsz < 1:
            raise ValueError(f"batch_size={batch_size} must be >= 1")
        key = (self, "batched", bsz)
        cached = _ENGINE_CACHE.get(key)
        if cached is not None:
            return cached
        self.validate()
        g = self.geometry
        grid = self.grid
        np_local = g.n_proj // grid.n_ranks
        st = self._make_stages()
        filter_encode = st.filter_encode
        rank_enc = self._build_rank_fn(st=st, encoded=True)

        def batched_rank(pm_local: Array, proj_b: Array) -> Array:
            # proj_b: (B, np_local, N_v, N_u) — this rank's block of every
            # scan. Filter+encode on the flattened projection axis, then
            # vmap the collective/back-projection half over the scan axis.
            flat = proj_b.reshape((bsz * np_local,) + proj_b.shape[2:])
            data, scales = filter_encode(flat)
            data = data.reshape((bsz, np_local) + data.shape[1:])
            if scales is not None:
                scales = scales.reshape((bsz, np_local) + scales.shape[1:])
                return jax.vmap(rank_enc, in_axes=(None, 0, 0))(
                    pm_local, data, scales)
            return jax.vmap(rank_enc, in_axes=(None, 0, None))(
                pm_local, data, None)

        pmats_all = jnp.asarray(projection_matrices(g))
        if self.mesh is None:
            @jax.jit
            def batched_fn(projections: Array) -> Array:
                return batched_rank(pmats_all, projections)
        else:
            mesh = self.mesh
            pspec = _proj_spec(mesh)
            out_sp = self._output_spec()

            @jax.jit
            def batched_fn(projections: Array) -> Array:
                return shard_map(
                    batched_rank, mesh=mesh,
                    in_specs=(pspec, P(None, *pspec)),
                    out_specs=P(None, *out_sp),
                    check_vma=False,
                )(pmats_all, projections)

        attrs = self._span_attrs()
        attrs["batch"] = bsz
        batched_fn = _traced_call(batched_fn, "engine.batched", attrs)
        _ENGINE_CACHE.put(key, batched_fn)
        return batched_fn

    def build_incremental(self, source=None, sink=None) -> "IncrementalSession":
        """Streaming reconstruction (the paper's *instant* CT): a stateful
        session that folds projection deltas into the per-rank slab
        accumulator as the scanner writes them, so time-from-last-projection
        is one delta's fold plus the reduce epilogue — not the full pipeline.

            plan = ReconstructionPlan(geometry=g, mesh=mesh,
                                      schedule="incremental", n_steps=8)
            sess = plan.build_incremental(source=src)
            while not sess.is_complete:
                sess.poll()          # discover + fold newly landed deltas
            volume = sess.finalize() # reduce epilogue + FDK scale only

        `n_steps` is the *nominal* delta count the planner prices; at run
        time any contiguous, disjoint angle slices whose length divides
        over the rank grid may be folded, in any order. See
        `IncrementalSession` for the state machine and exactness contract.
        """
        if self.schedule != "incremental":
            raise ValueError(
                f"build_incremental() needs schedule='incremental', got "
                f"{self.schedule!r} — batch schedules go through build()")
        return IncrementalSession(self, source=source, sink=sink)

    def _build_with_io(self, source, sink) -> Callable:
        """The engine with its filesystem endpoints attached: scatter-read
        projections from `source` when none are passed, stream the sharded
        output volume to `sink` shard-per-file. The core engine underneath
        comes from the per-plan cache, so attaching I/O never re-traces."""
        engine = self.build()
        # chunked+scatter emits the engine's internal 4-D y-chunk-major
        # layout (see _output_spec); record it in the sink's manifest so
        # VolumeSink.read() restores the canonical volume instead of
        # silently returning chunked axes.
        layout = None
        if self.schedule == "chunked" and self.reduce in SCATTER_REDUCES:
            layout = {"kind": "y_chunk_major", "y_chunks": self.y_chunks}

        def reconstruct_io(projections: Optional[Array] = None) -> Array:
            tracer = get_tracer()
            if projections is None:
                if source is None:
                    raise TypeError(
                        "this plan was built without a ProjectionSource; "
                        "pass the projections array")
                with tracer.span("stage.read") as sp:
                    projections = sp.fence(source.load(self.mesh))
            volume = engine(projections)
            if sink is not None:
                jax.block_until_ready(volume)
                with tracer.span("stage.write"):
                    sink.write(volume, layout=layout)
            return volume

        return reconstruct_io

    # -- traced engine (per-stage attribution) -------------------------------

    def build_traced(self, source=None, sink=None) -> Callable:
        """The engine cut at its stage seams, each stage a fenced span —
        the measurement counterpart of the planner's `PerfBreakdown`
        (obs/attribution.py joins the two).

        Every schedule runs the same FUSED stage decomposition here: one
        jitted dispatch per stage (filter+encode, column AllGather, slab
        back-projection, row-reduce epilogue; plus source read / sink write
        when wired), fenced with `block_until_ready` between stages so each
        span's duration is that stage's wall time — per-stage attribution
        trades away the overlap the pipelined schedules buy, so a traced
        run is a MEASUREMENT run, not a production configuration. Span
        names are the fixed ``stage.*`` vocabulary of
        `obs.attribution.STAGE_FIELDS`; output is always the canonical
        fused layout (chunked+scatter's y-chunk-major store layout does
        not apply).

        Works with the tracer disabled too (stages just run unfenced);
        enable via `obs.enable()` (or a local Tracer via obs.set_tracer)
        to collect the spans. With tracing enabled, every run also deposits
        its per-stage wall times into the calibration store
        (planner/calibrate.py) — traced runs are what anchors the planner's
        cost constants to this host.

        schedule="incremental" returns a `TracedIncrementalSession` instead
        of a callable: the same per-stage decomposition applied to the
        streaming session's stage()/fold path (its `session.stage`/
        `session.fold` work split into the ``stage.*`` vocabulary), feeding
        the same store.
        """
        if self.schedule == "incremental":
            return TracedIncrementalSession(self, source=source, sink=sink)
        self.validate()
        g = self.geometry
        mesh = self.mesh
        st = self._make_stages()
        has_scales = self.resolved_precision().codec.has_scales
        nx_slab, scale = st.nx_slab, st.scale
        attrs = self._span_attrs()

        def bp_rank(pm_col, q_col, sc_col):
            part = st.backproject(st.slab_pmats(pm_col), q_col,
                                  nx_slab, g.n_y, g.n_z, scales=sc_col)
            return part[None] if mesh is not None else part

        def reduce_rank(parts):
            slab = parts[0] if mesh is not None else parts
            return st.reduce_slab(slab) * scale

        if mesh is None:
            _filter = jax.jit(st.filter_encode)
            _gather = jax.jit(st.gather_cols)
            bp_fn = jax.jit(bp_rank)
            reduce_fn = jax.jit(reduce_rank)

            def run_filter(proj):
                return _filter(proj)           # (data, scales|None)

            def run_gather(data, scales):
                return _gather(pmats_all, data, scales)
        else:
            pspec = _proj_spec(mesh)
            gspec = P(_lead_axes(st.dp))
            # Un-reduced per-rank partial slabs: leading (pod x data) rank
            # dim so every rank's partial survives the stage boundary
            # (same trick as IncrementalSession's resident accumulator).
            part_spec = P(_lead_axes(st.dp), AXIS_MODEL, None, None)
            if has_scales:
                # plain tuple: shard_map's out_specs prefix does not match
                # the EncodedStream NamedTuple subtype.
                _filter = jax.jit(shard_map(
                    lambda raw: tuple(st.filter_encode(raw)), mesh=mesh,
                    in_specs=(pspec,), out_specs=(pspec, pspec),
                    check_vma=False))
                _gather = jax.jit(shard_map(
                    st.gather_cols, mesh=mesh,
                    in_specs=(pspec, pspec, pspec),
                    out_specs=(gspec, gspec, gspec), check_vma=False))

                def run_filter(proj):
                    return _filter(proj)

                def run_gather(data, scales):
                    return _gather(pmats_all, data, scales)
            else:
                _filter = jax.jit(shard_map(
                    lambda raw: st.filter_encode(raw)[0], mesh=mesh,
                    in_specs=(pspec,), out_specs=pspec, check_vma=False))
                _gather = jax.jit(shard_map(
                    lambda pm, d: st.gather_cols(pm, d, None)[:2],
                    mesh=mesh, in_specs=(pspec, pspec),
                    out_specs=(gspec, gspec), check_vma=False))

                def run_filter(proj):
                    return _filter(proj), None

                def run_gather(data, scales):
                    pm_col, q_col = _gather(pmats_all, data)
                    return pm_col, q_col, None
            # A None sc_col is an empty pytree: its gspec entry is simply
            # unused (same convention as IncrementalSession's fold fns).
            bp_fn = jax.jit(shard_map(
                bp_rank, mesh=mesh, in_specs=(gspec, gspec, gspec),
                out_specs=part_spec, check_vma=False))
            reduce_fn = jax.jit(shard_map(
                reduce_rank, mesh=mesh, in_specs=(part_spec,),
                out_specs=output_spec(mesh, self.reduce),
                check_vma=False))

        pmats_all = jnp.asarray(projection_matrices(g))
        if mesh is not None:
            pmats_all = jax.device_put(pmats_all, input_sharding(mesh))

        def reconstruct_traced(projections: Optional[Array] = None) -> Array:
            tracer = get_tracer()
            seconds: Dict[str, float] = {}
            with tracer.span("engine.traced", **attrs):
                if projections is None:
                    if source is None:
                        raise TypeError(
                            "this traced plan has no ProjectionSource; "
                            "pass the projections array")
                    with tracer.span("stage.read") as sp:
                        projections = sp.fence(source.load(mesh))
                    seconds["stage.read"] = sp.duration_s
                elif mesh is not None:
                    projections = jax.device_put(projections,
                                                 input_sharding(mesh))
                with tracer.span("stage.filter") as sp:
                    data, scales = sp.fence(run_filter(projections))
                seconds["stage.filter"] = sp.duration_s
                with tracer.span("stage.allgather") as sp:
                    pm_col, q_col, sc_col = sp.fence(
                        run_gather(data, scales))
                seconds["stage.allgather"] = sp.duration_s
                with tracer.span("stage.backproject") as sp:
                    parts = sp.fence(bp_fn(pm_col, q_col, sc_col))
                seconds["stage.backproject"] = sp.duration_s
                with tracer.span("stage.reduce") as sp:
                    volume = sp.fence(reduce_fn(parts))
                seconds["stage.reduce"] = sp.duration_s
                if sink is not None:
                    with tracer.span("stage.write") as sp:
                        sink.write(volume)
                    seconds["stage.write"] = sp.duration_s
            if tracer.enabled:
                # a traced run IS a calibration sample: feed the measured
                # stage times back into the planner's store. Disabled
                # tracer: spans are no-ops, there is nothing to record.
                from repro.planner.calibrate import record_traced_run
                record_traced_run(self, seconds)
            return volume

        return reconstruct_traced


def _lead_axes(axes: Tuple[str, ...]):
    """PartitionSpec entry for a leading state dim sharded over `axes`."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


class StagedDelta(NamedTuple):
    """One angle subset after the ARRIVAL-side stages — filtered, encoded
    and column-AllGathered, awaiting only its fold. Produced by
    `IncrementalSession.stage`, consumed by `IncrementalSession.update`."""

    lo: int
    hi: int
    pm_col: Array        # shifted-ready projection matrices, gathered
    q_col: Array         # filtered + encoded column batch (wire format)
    sc_col: Optional[Array]   # per-projection scale sidecar (scaled codecs)


class IncrementalSession:
    """Stateful streaming reconstruction — `plan.build_incremental()`.

    State machine (DESIGN.md, incremental schedule)::

        OPEN --update(delta, angles)--> OPEN    fold one angle subset
        OPEN --poll()-----------------> OPEN    discover + fold source deltas
        OPEN --finalize(partial=True)-> OPEN    peek: reduce a COPY of state
        OPEN --finalize()-------------> OPEN    full volume (all angles seen)

    `finalize` is pure — the resident accumulator is never consumed, so the
    session can keep folding after a peek. Each `update` filters, encodes
    and column-AllGathers ONE contiguous angle slice and folds it into the
    per-rank slab accumulator; `finalize` runs only the row-reduce epilogue
    and the FDK scale.

    Resident state (per rank): the f32 slab accumulator — full-width
    (nx_slab, N_y, N_z) under reduce="psum" (row-reduce deferred to
    finalize), or already scattered (nx_slab, N_y/C_data, N_z) under the
    scatter reduces (each update psum_scatters its partial, so state stays
    bounded exactly like the chunked schedule's output streaming). For
    "scatter_bf16" an f32 error-feedback carry of the full-width slab rides
    along: the quantization residual each update drops is re-injected into
    the next update's partial — the chunked schedule's carry, turned along
    the time axis — so only the final update's rounding survives per rank.

    Exactness contract (tests/test_streaming.py): with
    impl="reference"/"factorized" the fold threads the accumulator INTO the
    back-projection scan (`init=`), continuing the per-voxel addition
    sequence — so folding deltas in order is bit-identical to the fused
    batch engine on the same device count, and folding any permutation is
    bit-identical to the fused engine fed that same permuted projection
    stream (f32 addition does not commute, so no schedule can make every
    order bit-equal to the canonical one; permutations agree with it to
    f32 reassociation tolerance). impl="kernel" folds `acc + bp(delta)`
    (the Pallas kernel owns its accumulator) and matches to the same
    reassociation tolerance.
    """

    def __init__(self, plan: ReconstructionPlan, source=None, sink=None):
        plan.validate()
        self.plan = plan
        self._source = source
        self._sink = sink
        self._stages = plan._make_stages()
        self._scatter = plan.reduce in SCATTER_REDUCES
        self._compensated = plan.reduce == "scatter_bf16"
        g = plan.geometry
        self._covered = np.zeros(g.n_proj, dtype=bool)
        self._pmats = np.asarray(projection_matrices(g))
        self._update_fns: dict = {}
        self._stage_fns: dict = {}
        self._fold_fns: dict = {}
        self._finalize_fn = None
        self._init_state()

    # -- state --------------------------------------------------------------

    def _init_state(self) -> None:
        g = self.plan.geometry
        mesh = self.plan.mesh
        st = self._stages
        if mesh is None:
            self._acc_spec = self._carry_spec = None
            self._acc = jnp.zeros((g.n_x, g.n_y, g.n_z), jnp.float32)
            self._carry = None
            return
        # Global state arrays carry a leading rank-row dim so each rank-row
        # keeps its own partial under shard_map (block (1, nx_slab, ...)).
        dp = st.dp
        if self._scatter:
            lead = (st.pod_axis,) if st.pod_axis is not None else ()
            self._acc_spec = P(_lead_axes(lead), AXIS_MODEL, AXIS_DATA, None)
            acc_shape = (axis_size(mesh, AXIS_POD), g.n_x, g.n_y, g.n_z)
        else:
            self._acc_spec = P(_lead_axes(dp), AXIS_MODEL, None, None)
            acc_shape = (axis_size(mesh, AXIS_POD, AXIS_DATA),
                         g.n_x, g.n_y, g.n_z)
        self._acc = jax.device_put(
            jnp.zeros(acc_shape, jnp.float32),
            NamedSharding(mesh, self._acc_spec))
        if self._compensated:
            self._carry_spec = P(_lead_axes(dp), AXIS_MODEL, None, None)
            self._carry = jax.device_put(
                jnp.zeros((axis_size(mesh, AXIS_POD, AXIS_DATA),
                           g.n_x, g.n_y, g.n_z), jnp.float32),
                NamedSharding(mesh, self._carry_spec))
        else:
            self._carry_spec = None
            self._carry = None

    # -- bookkeeping --------------------------------------------------------

    @property
    def n_folded(self) -> int:
        """Angles folded so far."""
        return int(self._covered.sum())

    @property
    def is_complete(self) -> bool:
        return bool(self._covered.all())

    def pending_ranges(self) -> list:
        """Contiguous [lo, hi) angle ranges not folded yet."""
        missing = ~self._covered
        (idx,) = np.nonzero(np.diff(missing.astype(np.int8), prepend=0,
                                    append=0))
        return [(int(idx[i]), int(idx[i + 1]))
                for i in range(0, len(idx), 2)]

    def _check_slice(self, angle_slice) -> Tuple[int, int]:
        if isinstance(angle_slice, slice):
            if angle_slice.step not in (None, 1):
                raise ValueError("angle_slice must be contiguous (step 1)")
            lo, hi = angle_slice.start or 0, angle_slice.stop
        else:
            lo, hi = angle_slice
        n_proj = self.plan.geometry.n_proj
        if hi is None:
            hi = n_proj
        lo, hi = int(lo), int(hi)
        if not (0 <= lo < hi <= n_proj):
            raise ValueError(
                f"angle_slice [{lo}, {hi}) out of range for N_p={n_proj}")
        if self._covered[lo:hi].any():
            raise ValueError(
                f"angle_slice [{lo}, {hi}) overlaps angles already folded "
                "into this session — double-folding corrupts the volume")
        n_ranks = self.plan.grid.n_ranks
        if (hi - lo) % n_ranks:
            raise ValueError(
                f"delta of {hi - lo} angles must divide over the "
                f"{n_ranks} ranks of the grid")
        return lo, hi

    # -- the fold (one delta) -----------------------------------------------

    def _fold_closures(self, with_volume: bool):
        """(fold, rank_fold, accumulate): the per-delta fold shared by the
        raw-delta update path and the staged fold path.

        fold(acc_slab, pm_col, q_col, sc_col)       one rank's slab fold
        rank_fold(acc, carry, pm_col, q_col, sc_col)
            -> (new_acc, new_carry, volume|None)    leading-dim state block,
                                                    scatter reduce + carry,
                                                    fused epilogue when
                                                    with_volume
        accumulate(acc, carry, part)
            -> (new_acc, new_carry)                 the scatter branch's
                                                    reduce-into-state given
                                                    a PRECOMPUTED partial —
                                                    the seam the traced
                                                    session cuts at to time
                                                    back-projection apart
                                                    from the reduce
        """
        plan, st, g = self.plan, self._stages, self.plan.geometry
        slab_pmats = st.slab_pmats
        backproject = st.backproject
        nx_slab = st.nx_slab
        data_axis = st.data_axis
        scale = st.scale
        pod_axis = st.pod_axis
        dp = st.dp
        scatter, compensated = self._scatter, self._compensated
        # reference/factorized thread the accumulator INTO the scan (`init=`)
        # for the bit-exact fold; the Pallas kernel owns its accumulator, so
        # it falls back to `acc + bp(delta)`.
        threads_init = plan.impl in ("reference", "factorized")

        def fold(acc_slab, pm_col, q_col, sc_col):
            pm_s = slab_pmats(pm_col)
            if threads_init:
                return backproject(pm_s, q_col, nx_slab, g.n_y, g.n_z,
                                   scales=sc_col, init=acc_slab)
            return acc_slab + backproject(pm_s, q_col, nx_slab, g.n_y,
                                          g.n_z, scales=sc_col)

        def fin_slab(acc_new):
            """Per-rank finalize of the NEW accumulator block (epilogue of
            the fused last-delta dispatch) — mirrors _get_finalize_fn."""
            slab = acc_new[0]
            if scatter:
                if pod_axis is not None:  # cross-pod finish stays f32
                    slab = lax.psum(slab, pod_axis)
            else:
                for a in dp:
                    slab = lax.psum(slab, a)
            return slab * scale

        def accumulate(acc, carry, part):
            if compensated:
                # error feedback along the time axis: re-inject the
                # residual this rank dropped quantizing the PREVIOUS
                # delta before quantizing this one (cf. the chunked
                # schedule's per-chunk carry).
                part = part + carry[0]
                half = part.astype(jnp.bfloat16)
                new_carry = (part - half.astype(jnp.float32))[None]
                red = lax.psum_scatter(
                    half, data_axis, scatter_dimension=1,
                    tiled=True).astype(jnp.float32)
            else:
                new_carry = carry
                red = lax.psum_scatter(part, data_axis,
                                       scatter_dimension=1, tiled=True)
            return acc + red[None], new_carry

        def rank_fold(acc, carry, pm_col, q_col, sc_col):
            if not scatter:
                new = fold(acc[0], pm_col, q_col, sc_col)[None]
                new_carry = carry
            else:
                part = backproject(slab_pmats(pm_col), q_col,
                                   nx_slab, g.n_y, g.n_z, scales=sc_col)
                new, new_carry = accumulate(acc, carry, part)
            return new, new_carry, fin_slab(new) if with_volume else None

        return fold, rank_fold, accumulate

    def _state_specs(self, with_volume: bool):
        """(in-state specs, out_specs, pack) for a shard_mapped fold: the
        accumulator (plus carry when compensated, plus the volume when the
        epilogue is fused in) — shared wiring of update and staged-fold."""
        carry_spec = self._carry_spec if self._compensated else None
        state_in = ((self._acc_spec, carry_spec) if self._compensated
                    else (self._acc_spec,))
        outs = [self._acc_spec]
        if self._compensated:
            outs.append(carry_spec)
        if with_volume:
            outs.append(output_spec(self.plan.mesh, self.plan.reduce))

        def pack(new, new_carry, vol):
            out = (new,)
            if self._compensated:
                out += (new_carry,)
            if with_volume:
                out += (vol,)
            return out[0] if len(out) == 1 else out

        return state_in, (outs[0] if len(outs) == 1 else tuple(outs)), pack

    def _get_update_fn(self, n_d: int, with_volume: bool = False) -> Callable:
        """Jitted fold of one n_d-angle RAW delta: filter + encode + column
        AllGather + fold. with_volume=True additionally runs the reduce
        epilogue + FDK scale INSIDE the same dispatch and returns the
        finished volume alongside the new state — the time-from-last-delta
        path (one launch, XLA fuses the scale into the fold's epilogue
        instead of paying a second dispatch)."""
        fn = self._update_fns.get((n_d, with_volume))
        if fn is not None:
            return fn
        mesh = self.plan.mesh
        st = self._stages
        gather_batch = st.gather_batch
        scale = st.scale
        fold, rank_fold, _ = self._fold_closures(with_volume)

        if mesh is None:
            def update_fn(acc, pm_d, raw_d):
                new = fold(acc, *gather_batch(pm_d, raw_d))
                return (new, new * scale) if with_volume else new

            update_fn = jax.jit(update_fn)
        else:
            pspec = _proj_spec(mesh)
            state_in, out_specs, pack = self._state_specs(with_volume)
            if self._compensated:
                def rank(acc, carry, pm_d, raw_d):
                    return pack(*rank_fold(acc, carry,
                                           *gather_batch(pm_d, raw_d)))
            else:
                def rank(acc, pm_d, raw_d):  # carry unused: pass acc
                    return pack(*rank_fold(acc, acc,
                                           *gather_batch(pm_d, raw_d)))

            update_fn = jax.jit(shard_map(
                rank, mesh=mesh, in_specs=state_in + (pspec, pspec),
                out_specs=out_specs, check_vma=False))

        self._update_fns[(n_d, with_volume)] = update_fn
        return update_fn

    # -- staged folding (arrival-side work split off the fold) ---------------

    def _gathered_spec(self):
        """Spec of a staged column batch: the model-axis AllGather leaves
        projections sharded over the remaining (pod, data) axes and
        replicated over model."""
        return P(_lead_axes(self._stages.dp))

    def _get_stage_fn(self, n_d: int) -> Callable:
        fn = self._stage_fns.get(n_d)
        if fn is not None:
            return fn
        mesh = self.plan.mesh
        gather_batch = self._stages.gather_batch
        if mesh is None:
            fn = jax.jit(gather_batch)
        else:
            pspec = _proj_spec(mesh)
            gspec = self._gathered_spec()
            fn = jax.jit(shard_map(
                gather_batch, mesh=mesh, in_specs=(pspec, pspec),
                out_specs=(gspec, gspec, gspec), check_vma=False))
        self._stage_fns[n_d] = fn
        return fn

    def _get_fold_fn(self, n_d: int, with_volume: bool = False) -> Callable:
        """Jitted fold of a STAGED delta (post-filter, post-gather columns):
        only the back-projection + reduce (+ fused epilogue) — the work that
        cannot overlap acquisition."""
        fn = self._fold_fns.get((n_d, with_volume))
        if fn is not None:
            return fn
        mesh = self.plan.mesh
        scale = self._stages.scale
        fold, rank_fold, _ = self._fold_closures(with_volume)

        if mesh is None:
            def fold_fn(acc, pm_col, q_col, sc_col):
                new = fold(acc, pm_col, q_col, sc_col)
                return (new, new * scale) if with_volume else new

            fold_fn = jax.jit(fold_fn)
        else:
            gspec = self._gathered_spec()
            state_in, out_specs, pack = self._state_specs(with_volume)
            if self._compensated:
                def rank(acc, carry, pm_col, q_col, sc_col):
                    return pack(*rank_fold(acc, carry, pm_col, q_col,
                                           sc_col))
            else:
                def rank(acc, pm_col, q_col, sc_col):
                    return pack(*rank_fold(acc, acc, pm_col, q_col, sc_col))

            fold_fn = jax.jit(shard_map(
                rank, mesh=mesh,
                in_specs=state_in + (gspec, gspec, gspec),
                out_specs=out_specs, check_vma=False))

        self._fold_fns[(n_d, with_volume)] = fold_fn
        return fold_fn

    def stage(self, projection_delta: Array, angle_slice) -> "StagedDelta":
        """Run the ARRIVAL-side half of an update — filter + encode + column
        AllGather — without folding. Pure (no session state changes).

        Filtering is per-projection independent, so a streaming rank stages
        frames while the burst is still landing: by the time the burst's
        last frame commits, only the fold (back-projection + reduce) is
        left — `update(staged, finalize=True)` is then the entire
        time-from-last-projection tail (the instant-CT figure of merit,
        benchmarks/bench_streaming.py)."""
        lo, hi = self._check_slice(angle_slice)
        self._check_delta_shape(projection_delta, lo, hi)
        with get_tracer().span("session.stage", lo=lo, hi=hi) as sp:
            pm_d, raw_d = self._place_delta(projection_delta, lo, hi)
            pm_col, q_col, sc_col = sp.fence(
                self._get_stage_fn(hi - lo)(pm_d, raw_d))
        return StagedDelta(lo, hi, pm_col, q_col, sc_col)

    def _check_delta_shape(self, delta, lo: int, hi: int) -> None:
        g = self.plan.geometry
        if tuple(delta.shape) != (hi - lo, g.n_v, g.n_u):
            raise ValueError(
                f"projection_delta shape {tuple(delta.shape)} does not "
                f"match angles [{lo}, {hi}) x detector ({g.n_v}, {g.n_u})")

    def _place_delta(self, delta, lo: int, hi: int):
        """(pm_d, raw_d) for the angle range, device-placed for the mesh."""
        mesh = self.plan.mesh
        pm_d = jnp.asarray(self._pmats[lo:hi])
        raw_d = delta
        if mesh is not None:
            sharding = input_sharding(mesh)
            pm_d = jax.device_put(pm_d, sharding)
            raw_d = jax.device_put(raw_d, sharding)
        return pm_d, raw_d

    def update(self, projection_delta, angle_slice=None,
               finalize: bool = False):
        """Fold one contiguous angle subset: filter + encode + column
        AllGather + slab back-projection (+ per-delta scatter reduce).

        projection_delta : (n_d, N_v, N_u) raw projections for the global
                           angle range `angle_slice` = slice/(lo, hi),
                           n_d dividing over the rank grid — or a
                           `StagedDelta` from `stage()` (no angle_slice;
                           only the fold runs).
        finalize         : True fuses the reduce epilogue + FDK scale into
                           the SAME dispatch and returns the volume (the
                           time-from-last-delta path — one launch instead
                           of update-then-finalize). State is still folded,
                           and a full-coverage finalize streams to the
                           session's VolumeSink exactly like finalize().

        Returns the session (chaining) — or the volume when finalize=True.
        """
        if isinstance(projection_delta, StagedDelta):
            if angle_slice is not None:
                raise TypeError(
                    "a StagedDelta carries its own angle range; do not "
                    "pass angle_slice")
            s = projection_delta
            lo, hi = self._check_slice((s.lo, s.hi))
            fn = self._get_fold_fn(hi - lo, with_volume=finalize)
            args = (s.pm_col, s.q_col, s.sc_col)
        else:
            if angle_slice is None:
                raise TypeError("angle_slice is required for a raw delta")
            lo, hi = self._check_slice(angle_slice)
            self._check_delta_shape(projection_delta, lo, hi)
            fn = self._get_update_fn(hi - lo, with_volume=finalize)
            args = self._place_delta(projection_delta, lo, hi)
        volume = None
        staged = isinstance(projection_delta, StagedDelta)
        with get_tracer().span("session.fold", lo=lo, hi=hi, staged=staged,
                               final=finalize) as sp:
            if self._compensated:
                if finalize:
                    self._acc, self._carry, volume = fn(
                        self._acc, self._carry, *args)
                else:
                    self._acc, self._carry = fn(self._acc, self._carry,
                                                *args)
            elif finalize:
                self._acc, volume = fn(self._acc, *args)
            else:
                self._acc = fn(self._acc, *args)
            sp.fence(volume if finalize else self._acc)
        self._covered[lo:hi] = True
        if not finalize:
            return self
        if self._sink is not None and self.is_complete:
            jax.block_until_ready(volume)
            with get_tracer().span("stage.write"):
                self._sink.write(volume)
        return volume

    # -- source coupling ----------------------------------------------------

    def poll(self) -> int:
        """Discover newly landed deltas on the ProjectionSource and fold
        them. Returns the number of deltas folded (0 = nothing new)."""
        if self._source is None:
            raise TypeError(
                "session was built without a ProjectionSource; feed deltas "
                "via update(delta, angle_slice) instead")
        n = 0
        with get_tracer().span("session.poll") as sp:
            for lo, hi, delta in self._source.iter_deltas(self.plan.mesh):
                self.update(delta, (lo, hi))
                n += 1
            sp.set(n_deltas=n)
        return n

    # -- epilogue -----------------------------------------------------------

    def _get_finalize_fn(self) -> Callable:
        if self._finalize_fn is not None:
            return self._finalize_fn
        plan, st = self.plan, self._stages
        mesh = plan.mesh
        scale = st.scale
        if mesh is None:
            self._finalize_fn = jax.jit(lambda acc: acc * scale)
            return self._finalize_fn
        if self._scatter:
            pod_axis = st.pod_axis

            def rank(acc):
                slab = acc[0]
                if pod_axis is not None:  # cross-pod finish stays f32
                    slab = lax.psum(slab, pod_axis)
                return slab * scale
        else:
            dp = st.dp

            def rank(acc):
                slab = acc[0]
                for a in dp:
                    slab = lax.psum(slab, a)
                return slab * scale

        self._finalize_fn = jax.jit(shard_map(
            rank, mesh=mesh, in_specs=(self._acc_spec,),
            out_specs=output_spec(mesh, plan.reduce), check_vma=False))
        return self._finalize_fn

    def finalize(self, partial: bool = False) -> Array:
        """Row-reduce epilogue + FDK scale — the ONLY work left after the
        last delta folds. Pure: the session keeps accepting updates.

        partial=True returns the reconstruction from the angles folded so
        far (a mid-scan peek; limited-angle artifacts are the caller's to
        interpret). The default demands full coverage. A full finalize
        streams the volume to the session's VolumeSink, if one was given.
        """
        if not partial and not self.is_complete:
            raise ValueError(
                f"only {self.n_folded}/{self.plan.geometry.n_proj} angles "
                f"folded; missing ranges {self.pending_ranges()} — fold "
                "them (update/poll) or pass partial=True for a mid-scan "
                "peek")
        tracer = get_tracer()
        with tracer.span("session.finalize", partial=partial) as sp:
            volume = sp.fence(self._get_finalize_fn()(self._acc))
        if self._sink is not None and not partial:
            jax.block_until_ready(volume)
            with tracer.span("stage.write"):
                self._sink.write(volume)
        return volume


class TracedIncrementalSession(IncrementalSession):
    """The streaming session cut at its stage seams — `build_traced` for
    schedule="incremental".

    Same state machine and exactness contract as `IncrementalSession`, but
    every `session.stage`/`session.fold` is decomposed into separately
    dispatched, fenced ``stage.*`` spans (the `STAGE_FIELDS` vocabulary):
    stage() emits ``stage.filter`` + ``stage.allgather``; a fold emits
    ``stage.backproject`` and — under the scatter reduces, where each delta
    psum_scatters its partial — ``stage.reduce`` (the accumulate half of
    `_fold_closures`, dispatched apart from the back-projection); the
    finalize epilogue is a ``stage.reduce`` span too (psum's one deferred
    reduce). Raw deltas are routed through stage() first so the raw-update
    path decomposes identically.

    Like `build_traced`, this is a MEASUREMENT configuration: the split
    dispatches trade away the fold fusion the production session buys, and
    the spans are `timed=True` so stage seconds accumulate even with the
    tracer disabled. On the first full-coverage volume (finalize, or a
    fused `update(..., finalize=True)`) the accumulated stage times are
    deposited into the calibration store (planner/calibrate.py) against
    the plan's incremental cost point — streaming sessions feed the same
    predicted->measured loop as the batch engines.
    """

    def __init__(self, plan: ReconstructionPlan, source=None, sink=None):
        super().__init__(plan, source=source, sink=sink)
        self._stage_seconds: Dict[str, float] = {}
        self._recorded = False
        self._traced_finalize = None

    def _bump(self, name: str, sp) -> None:
        self._stage_seconds[name] = (self._stage_seconds.get(name, 0.0)
                                     + sp.duration_s)

    def stage_seconds(self) -> Dict[str, float]:
        """Accumulated per-stage wall seconds so far (a copy)."""
        return dict(self._stage_seconds)

    # -- stage decomposition -------------------------------------------------

    def _get_stage_fn(self, n_d: int) -> Callable:
        fn = self._stage_fns.get(("traced", n_d))
        if fn is not None:
            return fn
        mesh = self.plan.mesh
        st = self._stages
        filter_encode = st.filter_encode
        gather_cols = st.gather_cols
        if mesh is None:
            _filter = jax.jit(filter_encode)
            _gather = jax.jit(gather_cols)

            def run_filter(raw):
                return _filter(raw)

            def run_gather(pm_d, data, scales):
                return _gather(pm_d, data, scales)
        else:
            pspec = _proj_spec(mesh)
            gspec = self._gathered_spec()
            if self.plan.resolved_precision().codec.has_scales:
                # plain tuple: shard_map's out_specs prefix does not match
                # the EncodedStream NamedTuple subtype (same trick as
                # build_traced's batch decomposition).
                _filter = jax.jit(shard_map(
                    lambda raw: tuple(filter_encode(raw)), mesh=mesh,
                    in_specs=(pspec,), out_specs=(pspec, pspec),
                    check_vma=False))
                _gather = jax.jit(shard_map(
                    gather_cols, mesh=mesh,
                    in_specs=(pspec, pspec, pspec),
                    out_specs=(gspec, gspec, gspec), check_vma=False))

                def run_filter(raw):
                    return _filter(raw)

                def run_gather(pm_d, data, scales):
                    return _gather(pm_d, data, scales)
            else:
                _filter = jax.jit(shard_map(
                    lambda raw: filter_encode(raw)[0], mesh=mesh,
                    in_specs=(pspec,), out_specs=pspec, check_vma=False))
                _gather = jax.jit(shard_map(
                    lambda pm, d: gather_cols(pm, d, None)[:2],
                    mesh=mesh, in_specs=(pspec, pspec),
                    out_specs=(gspec, gspec), check_vma=False))

                def run_filter(raw):
                    return _filter(raw), None

                def run_gather(pm_d, data, scales):
                    pm_col, q_col = _gather(pm_d, data)
                    return pm_col, q_col, None

        def staged_fn(pm_d, raw_d):
            tracer = get_tracer()
            with tracer.span("stage.filter", timed=True) as sp:
                data, scales = sp.fence(run_filter(raw_d))
            self._bump("stage.filter", sp)
            with tracer.span("stage.allgather", timed=True) as sp:
                cols = sp.fence(run_gather(pm_d, data, scales))
            self._bump("stage.allgather", sp)
            return cols

        self._stage_fns[("traced", n_d)] = staged_fn
        return staged_fn

    def _get_fold_fn(self, n_d: int, with_volume: bool = False) -> Callable:
        key = ("traced", n_d, with_volume)
        fn = self._fold_fns.get(key)
        if fn is not None:
            return fn
        fin = self._get_finalize_fn() if with_volume else None

        if not self._scatter:
            # psum: the fold IS the back-projection (accumulation is the
            # back-projector's own `init=` epilogue — nothing to cut); the
            # row reduce is deferred to finalize, dispatched via `fin`.
            inner = IncrementalSession._get_fold_fn(self, n_d,
                                                    with_volume=False)

            def traced_fold(*args):
                with get_tracer().span("stage.backproject",
                                       timed=True) as sp:
                    new = sp.fence(inner(*args))
                self._bump("stage.backproject", sp)
                return (new, fin(new)) if with_volume else new
        else:
            # scatter: cut the per-delta fold at the _fold_closures
            # `accumulate` seam — back-projection partial in one dispatch
            # (stage.backproject), carry + psum_scatter into the resident
            # state in another (stage.reduce).
            mesh = self.plan.mesh
            st = self._stages
            g = self.plan.geometry
            backproject, slab_pmats = st.backproject, st.slab_pmats
            nx_slab = st.nx_slab
            _, _, accumulate = self._fold_closures(with_volume=False)

            def bp_rank(pm_col, q_col, sc_col):
                return backproject(slab_pmats(pm_col), q_col, nx_slab,
                                   g.n_y, g.n_z, scales=sc_col)[None]

            gspec = self._gathered_spec()
            part_spec = P(_lead_axes(st.dp), AXIS_MODEL, None, None)
            bp_fn = jax.jit(shard_map(
                bp_rank, mesh=mesh, in_specs=(gspec, gspec, gspec),
                out_specs=part_spec, check_vma=False))
            state_in, out_specs, pack = self._state_specs(False)
            if self._compensated:
                def acc_rank(acc, carry, part):
                    new, new_carry = accumulate(acc, carry, part[0])
                    return pack(new, new_carry, None)
            else:
                def acc_rank(acc, part):  # carry unused: pass acc
                    new, _ = accumulate(acc, acc, part[0])
                    return pack(new, None, None)
            acc_fn = jax.jit(shard_map(
                acc_rank, mesh=mesh, in_specs=state_in + (part_spec,),
                out_specs=out_specs, check_vma=False))
            n_state = 2 if self._compensated else 1

            def traced_fold(*args):
                state, cols = args[:n_state], args[n_state:]
                tracer = get_tracer()
                with tracer.span("stage.backproject", timed=True) as sp:
                    part = sp.fence(bp_fn(*cols))
                self._bump("stage.backproject", sp)
                with tracer.span("stage.reduce", timed=True) as sp:
                    new_state = sp.fence(acc_fn(*state, part))
                self._bump("stage.reduce", sp)
                if not with_volume:
                    return new_state
                if n_state == 2:
                    new_acc, new_carry = new_state
                    return new_acc, new_carry, fin(new_acc)
                return new_state, fin(new_state)

        self._fold_fns[key] = traced_fold
        return traced_fold

    def _get_finalize_fn(self) -> Callable:
        if self._traced_finalize is None:
            inner = super()._get_finalize_fn()

            def fin(acc):
                with get_tracer().span("stage.reduce", timed=True) as sp:
                    out = sp.fence(inner(acc))
                self._bump("stage.reduce", sp)
                return out

            self._traced_finalize = fin
        return self._traced_finalize

    # -- calibration feedback ------------------------------------------------

    def update(self, projection_delta, angle_slice=None,
               finalize: bool = False):
        if not isinstance(projection_delta, StagedDelta):
            if angle_slice is None:
                raise TypeError("angle_slice is required for a raw delta")
            # route raw deltas through stage() so the raw-update path
            # decomposes into the same stage.filter/allgather/fold spans.
            projection_delta = self.stage(projection_delta, angle_slice)
            angle_slice = None
        out = super().update(projection_delta, angle_slice,
                             finalize=finalize)
        if finalize and self.is_complete:
            self._record_calibration()
        return out

    def finalize(self, partial: bool = False) -> Array:
        volume = super().finalize(partial=partial)
        if not partial:
            self._record_calibration()
        return volume

    def _record_calibration(self) -> None:
        if self._recorded:
            return
        self._recorded = True
        from repro.planner.calibrate import record_traced_run
        record_traced_run(self.plan, dict(self._stage_seconds))


_SPEC_INT_KEYS = ("n_steps", "y_chunks", "vmem_budget")
_SPEC_STR_KEYS = ("impl", "window", "precision", "schedule", "reduce")
_SPEC_KEYS = _SPEC_STR_KEYS + _SPEC_INT_KEYS + ("blocks",)

# Known *values*, mapped to the key they belong to — so a bare typo like
# "pipelned" can be answered with "did you mean 'schedule=pipelined'?".
_SPEC_VALUE_KEYS = {
    **{v: "schedule" for v in _SCHEDULES},
    **{v: "reduce" for v in _REDUCES},
    **{v: "impl" for v in _IMPLS},
    **{v: "precision" for v in _PRECISIONS},
    **{v: "window" for v in _WINDOWS},
}


def _spec_hint(token: str) -> str:
    """'; did you mean ...?' for the nearest valid spec token, or ''."""
    import difflib
    candidates = ["auto"] + list(_SPEC_KEYS) + list(_SPEC_VALUE_KEYS)
    close = difflib.get_close_matches(token, candidates, n=1, cutoff=0.6)
    if not close:
        return ""
    match = close[0]
    if match in _SPEC_VALUE_KEYS:
        match = f"{_SPEC_VALUE_KEYS[match]}={match}"
    elif match in _SPEC_KEYS:
        match = f"{match}=..."
    return f"; did you mean {match!r}?"


def plan_from_spec(geometry: CBCTGeometry, spec: str = "",
                   mesh: Mesh | None = None, **overrides) -> ReconstructionPlan:
    """Build a plan from a compact ``key=value,key=value`` spec string — the
    one-flag configuration surface shared by the benchmark/example harnesses
    (e.g. ``--plan "schedule=pipelined,n_steps=4,precision=bf16"``).

    Recognized keys: impl, window, precision, schedule, n_steps, y_chunks,
    reduce, vmem_budget, blocks (as ``bi:bj:bs``). ``overrides`` kwargs win
    over the spec string.

    The bare token ``auto`` hands the remaining (pinned) dimensions to the
    planner (repro/planner): ``"auto"`` searches the whole space for the
    best feasible plan on this (geometry, mesh); ``"auto,precision=bf16"``
    searches with the precision axis pinned.
    """
    kwargs: dict = {}
    auto = False
    for item in filter(None, (s.strip() for s in spec.split(","))):
        if "=" not in item:
            if item == "auto":
                auto = True
                continue
            raise ValueError(
                f"plan spec token {item!r} is not key=value and not 'auto'; "
                f"valid keys: {', '.join(_SPEC_KEYS)}{_spec_hint(item)}")
        key, val = (s.strip() for s in item.split("=", 1))
        if key in _SPEC_INT_KEYS:
            kwargs[key] = int(val)
        elif key == "blocks":
            kwargs[key] = tuple(int(v) for v in val.split(":"))
        elif key in _SPEC_STR_KEYS:
            kwargs[key] = val
        else:
            raise ValueError(
                f"unknown plan spec key {key!r}; valid keys: "
                f"{', '.join(_SPEC_KEYS)}{_spec_hint(key)}")
    kwargs.update(overrides)
    if auto:
        from repro.planner import auto_plan
        window = kwargs.pop("window", "ramlak")
        vmem_budget = kwargs.pop("vmem_budget", None)
        return auto_plan(geometry, mesh=mesh, window=window,
                         vmem_budget=vmem_budget, **kwargs)
    return ReconstructionPlan(geometry=geometry, mesh=mesh, **kwargs)
