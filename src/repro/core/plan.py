"""Declarative reconstruction plans + the staged engine (paper §4, unified).

The paper's framework is ONE pipeline — load/filter -> column AllGather ->
slab back-projection -> row Reduce — previously implemented four times
(`fdk.reconstruct`, `make_distributed_fdk`, `make_pipelined_fdk`,
`make_chunked_fdk`), each separately threading precision, filter, impl
dispatch, shard_map and reduce logic. This module replaces the fork with a
plan -> build -> run engine:

    plan = ReconstructionPlan(geometry=g, mesh=mesh, schedule="pipelined",
                              n_steps=4, reduce="scatter", precision="bf16")
    fdk = plan.build()          # validated, tuned, jitted — cached per plan
    volume = fdk(projections)

A `ReconstructionPlan` is a frozen dataclass capturing every degree of
freedom of the pipeline; `validate()` centralizes the divisibility checks
that used to live inline in each builder, and `build()` composes shared
stage primitives:

    filter stage         make_filter(window, storage dtype)   [per batch]
    gather schedule      column AllGather over the `model` axis
    slab back-projection shift_pmats_i (x-slab) / shift_pmats_j (y-chunk)
    reduce epilogue      psum (replicated) | psum_scatter (sharded store)

into one rank function, run under shard_map when a mesh is given and
directly on one device when not. The schedule x reduce x precision x impl
cross-product is fully available — including combinations the legacy
builders never offered (chunked+psum, pipelined single-device).

Tuned Pallas block shapes for `impl="kernel"` are resolved ONCE at plan
time (kernels/backproject/tune.py, file-backed cache) instead of per-call
inside ops.py, and can be pinned explicitly via `blocks=(bi, bj, bs)`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Literal, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD, axis_size
from .distributed import (
    IFDKGrid, SCATTER_REDUCES, _proj_spec, output_spec, shift_pmats_i,
)
from .fdk import BpImpl, _get_backprojector, fdk_scale
from .filtering import _WINDOWS, make_filter
from .geometry import CBCTGeometry, projection_matrices
from .precision import Precision, resolve_precision

Array = jax.Array

Schedule = Literal["fused", "pipelined", "chunked"]
ReduceMode = Literal["psum", "scatter", "scatter_bf16"]

_SCHEDULES = ("fused", "pipelined", "chunked")
_REDUCES = ("psum",) + SCATTER_REDUCES
_IMPLS = ("reference", "factorized", "kernel")
_PRECISIONS = ("fp32", "bf16", "fp16", "fp8_e4m3")

# build() results, keyed by the (hashable) plan: repeated builds of the same
# plan reuse the jitted function, so `reconstruct(...)`-style per-call
# wrappers never re-trace.
_ENGINE_CACHE: dict = {}


def clear_engine_cache() -> None:
    _ENGINE_CACHE.clear()


def bp_call_shape(g: CBCTGeometry, r: int, c: int, schedule: str,
                  n_steps: int, y_chunks: Optional[int]
                  ) -> Tuple[int, int, int]:
    """(nx, ny, n_p) of ONE back-projection call under a plan point: the
    x-slab (and y-chunk, if chunked) of one gathered micro-batch. The one
    formula shared by the engine's block resolution and the planner's
    kernel-VMEM feasibility check (planner/feasibility.py)."""
    nx_call = g.n_x // r
    ny_call = (g.n_y // y_chunks if schedule == "chunked" and y_chunks
               else g.n_y)
    np_call = g.n_proj // (c * n_steps)
    return nx_call, ny_call, np_call


def shift_pmats_j(pmats: Array, j0) -> Array:
    """Reparameterize P for a y-chunk starting at voxel index j0 (same trick
    as distributed.shift_pmats_i, on the j column)."""
    shift = pmats[..., :, 1] * j0
    return pmats.at[..., :, 3].add(shift)


@dataclasses.dataclass(frozen=True)
class ReconstructionPlan:
    """Everything that determines a reconstruction, in one declarative value.

    Fields
    ------
    geometry   : the CBCT scan geometry (paper Table 1).
    mesh       : device mesh; None = plain single-device execution (no
                 shard_map). The paper's R x C rank grid is derived from it:
                 R = `model` axis (volume slabs), C = `pod` x `data`
                 (projection groups) — see `grid`.
    impl       : back-projection implementation ("reference" | "factorized"
                 | "kernel").
    window     : ramp-filter apodization window.
    precision  : storage dtype policy of the filtered-projection stream
                 (core/precision.py): a Precision, a name, or None for the
                 backend default. Accumulation is always f32.
    schedule   : "fused"     — one gather, one slab back-projection;
                 "pipelined" — lax.scan over `n_steps` micro-batches, the
                               AllGather of batch s overlapping the
                               back-projection of batch s-1 (paper Fig. 4);
                 "chunked"   — pipelined + per-y-chunk reduce (streaming
                               output side; bounds the live slab state).
    n_steps    : projection micro-batches per rank (pipelined/chunked).
    y_chunks   : y-axis chunks (chunked only).
    reduce     : row-reduce epilogue. "psum" replicates the slab; "scatter"
                 leaves it sharded over `data` for the parallel store
                 (requires a mesh with a `data` axis); "scatter_bf16" is
                 scatter at half the reduce wire bytes — partial slabs are
                 quantized to bf16 before the psum_scatter and the result
                 upcast to f32, with an f32 error-feedback carry under the
                 chunked schedule (each step's quantization residual is
                 re-injected into the next step's partial, so the error
                 does not grow with n_steps). See DESIGN.md (codec layer)
                 for the error model.
    blocks     : explicit (bi, bj, bs) Pallas tile for impl="kernel";
                 None = resolve from the VMEM-budget autotuner at plan time.
    vmem_budget: byte budget handed to the autotuner (None = env default).
    """

    geometry: CBCTGeometry
    mesh: Optional[Mesh] = None
    impl: BpImpl = "factorized"
    window: str = "ramlak"
    precision: Precision | str | None = "fp32"
    schedule: Schedule = "fused"
    n_steps: int = 1
    y_chunks: Optional[int] = None
    reduce: ReduceMode = "psum"
    blocks: Optional[Tuple[int, int, int]] = None
    vmem_budget: Optional[int] = None

    # -- derived quantities -------------------------------------------------

    @property
    def grid(self) -> IFDKGrid:
        """The paper's R (slabs) x C (projection groups) rank grid."""
        if self.mesh is None:
            return IFDKGrid(r=1, c=1)
        return IFDKGrid(r=axis_size(self.mesh, AXIS_MODEL),
                        c=axis_size(self.mesh, AXIS_POD, AXIS_DATA))

    @property
    def _data_size(self) -> int:
        return axis_size(self.mesh, AXIS_DATA) if self.mesh is not None else 1

    def resolved_precision(self) -> Precision:
        return resolve_precision(self.precision)

    # -- validation ---------------------------------------------------------

    def validate(self) -> "ReconstructionPlan":
        """Centralized feasibility checks (every legacy builder's scattered
        divisibility tests live here, with uniform error messages)."""
        g = self.geometry
        if self.impl not in _IMPLS:
            raise ValueError(
                f"unknown back-projection impl {self.impl!r}; "
                f"choose from {_IMPLS}")
        if self.window not in _WINDOWS:
            raise ValueError(
                f"unknown window {self.window!r}; choose from {_WINDOWS}")
        if self.schedule not in _SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; "
                f"choose from {_SCHEDULES}")
        if self.reduce not in _REDUCES:
            raise ValueError(
                f"unknown reduce mode {self.reduce!r}; "
                f"choose from {_REDUCES}")
        resolve_precision(self.precision)  # raises on unknown storage
        if self.mesh is not None and AXIS_MODEL not in self.mesh.axis_names:
            raise ValueError(
                f"mesh axes {self.mesh.axis_names} lack the {AXIS_MODEL!r} "
                "axis that carries the paper's R volume slabs")
        grid = self.grid
        n_ranks = grid.n_ranks
        if g.n_proj % n_ranks:
            raise ValueError(
                f"N_p={g.n_proj} must divide over the {n_ranks} ranks of "
                f"the R={grid.r} x C={grid.c} grid")
        if g.n_x % grid.r:
            raise ValueError(
                f"N_x={g.n_x} must divide into R={grid.r} volume slabs")
        if self.n_steps < 1:
            raise ValueError(f"n_steps={self.n_steps} must be >= 1")
        if self.schedule == "fused" and self.n_steps != 1:
            raise ValueError(
                "the fused schedule has no micro-batching; use "
                "schedule='pipelined' (or 'chunked') for n_steps > 1")
        np_local = g.n_proj // n_ranks
        if np_local % self.n_steps:
            raise ValueError(
                f"per-rank N_p={np_local} must divide into "
                f"n_steps={self.n_steps} micro-batches")
        if self.schedule == "chunked":
            if self.y_chunks is None:
                raise ValueError("the chunked schedule requires y_chunks")
            if g.n_y % self.y_chunks:
                raise ValueError(
                    f"N_y={g.n_y} must divide into y_chunks={self.y_chunks}")
        elif self.y_chunks is not None:
            raise ValueError(
                "y_chunks only applies to the chunked schedule")
        if self.reduce in SCATTER_REDUCES:
            if self.mesh is None or AXIS_DATA not in self.mesh.axis_names:
                raise ValueError(
                    f"reduce={self.reduce!r} needs a mesh with a 'data' "
                    "axis to scatter over; use reduce='psum' on a single "
                    "device")
            scatter_extent = (g.n_y // self.y_chunks
                              if self.schedule == "chunked" else g.n_y)
            if scatter_extent % self._data_size:
                raise ValueError(
                    f"scatter extent {scatter_extent} (y) must divide over "
                    f"the data axis of size {self._data_size}")
        if self.blocks is not None and self.impl != "kernel":
            raise ValueError(
                "blocks=(bi, bj, bs) only applies to impl='kernel'")
        if self.impl == "kernel" and g.n_z % 2:
            raise ValueError(
                f"impl='kernel' requires even N_z (dual-slab layout), "
                f"got N_z={g.n_z}")
        if self.blocks is not None:
            bi, bj, bs = self.blocks
            nx_call, ny_call, _ = self._bp_call_shape()
            if bi < 1 or bj < 1 or bs < 1:
                raise ValueError(f"blocks={self.blocks} must be positive")
            # bs need not divide the projection count (ops.py pads), but the
            # output tile must tile the per-call slab exactly.
            if nx_call % bi or ny_call % bj:
                raise ValueError(
                    f"blocks=(bi={bi}, bj={bj}) must tile the per-call "
                    f"back-projection slab ({nx_call}, {ny_call}) — the "
                    f"x-slab/y-chunk of one gathered micro-batch")
        return self

    # -- kernel block resolution (plan-time, not per-call) ------------------

    def _bp_call_shape(self) -> Tuple[int, int, int]:
        grid = self.grid
        return bp_call_shape(self.geometry, grid.r, grid.c, self.schedule,
                             self.n_steps, self.y_chunks)

    def resolved_blocks(self) -> Optional[Tuple[int, int, int]]:
        """The (bi, bj, bs) Pallas tile this plan will run with — explicit
        `blocks` if given, else the autotuner's pick for the per-call
        back-projection shape. None for non-kernel impls."""
        if self.impl != "kernel":
            return None
        if self.blocks is not None:
            return tuple(self.blocks)
        from repro.kernels.backproject import tune
        g = self.geometry
        nx_call, ny_call, np_call = self._bp_call_shape()
        prec = self.resolved_precision()
        return tune.pick_blocks(nx_call, ny_call, g.n_z, np_call,
                                g.n_u, g.n_v,
                                qt_dtype=prec.storage_dtype,
                                budget=self.vmem_budget)

    def _resolve_backprojector(self) -> Callable:
        if self.impl != "kernel":
            return _get_backprojector(self.impl)
        from repro.kernels.backproject.ops import backproject_pallas
        bi, bj, bs = self.resolved_blocks()
        return partial(backproject_pallas, bi=bi, bj=bj, bs=bs)

    def describe(self) -> dict:
        """Flat summary of the resolved plan (benchmark/report labels)."""
        grid = self.grid
        return {
            "schedule": self.schedule,
            "impl": self.impl,
            "window": self.window,
            "precision": self.resolved_precision().storage,
            "grid": (grid.r, grid.c),
            "n_steps": self.n_steps,
            "y_chunks": self.y_chunks,
            "reduce": self.reduce,
            "blocks": self.resolved_blocks(),
        }

    # -- engine -------------------------------------------------------------

    def _output_spec(self) -> Optional[P]:
        if self.mesh is None:
            return None
        if self.schedule == "chunked" and self.reduce in SCATTER_REDUCES:
            # (nx_slab, y_chunks, yc/dp, nz): x over model, chunk interior
            # scattered over data; reshape(nx, ny, nz) outside restores the
            # canonical volume.
            return P(AXIS_MODEL, None, AXIS_DATA, None)
        return output_spec(self.mesh, self.reduce)

    def _build_rank_fn(self) -> Callable[[Array, Array], Array]:
        """Compose the shared stage primitives into one per-rank function."""
        g = self.geometry
        mesh = self.mesh
        grid = self.grid
        model_axis = (AXIS_MODEL if mesh is not None
                      and AXIS_MODEL in mesh.axis_names else None)
        data_axis = (AXIS_DATA if mesh is not None
                     and AXIS_DATA in mesh.axis_names else None)
        pod_axis = (AXIS_POD if mesh is not None
                    and AXIS_POD in mesh.axis_names else None)
        dp = tuple(a for a in (pod_axis, data_axis) if a is not None)
        nx_slab = g.n_x // grid.r
        n_steps = self.n_steps
        nb = g.n_proj // grid.n_ranks // n_steps
        scale = fdk_scale(g)
        prec = self.resolved_precision()
        codec = prec.codec
        # The filter emits f32; the stream codec owns the quantization to
        # the wire format (scale-free codecs are a plain cast — fused under
        # jit, byte-identical to casting inside the filter).
        filt = make_filter(g, self.window, out_dtype=jnp.float32)
        backproject = self._resolve_backprojector()

        # --- stage: filter + encode + column AllGather (paper Fig. 3b) -----
        # The AllGather moves the codec's WIRE format: quantized data plus,
        # for scaled codecs (fp8), the per-projection f32 scale sidecar.
        def gather_batch(pm_b: Array, raw_b: Array):
            data, scales = codec.encode(filt(raw_b))
            if model_axis is None:
                return pm_b, data, scales
            gathered_scales = (
                None if scales is None
                else lax.all_gather(scales, model_axis, axis=0, tiled=True))
            return (lax.all_gather(pm_b, model_axis, axis=0, tiled=True),
                    lax.all_gather(data, model_axis, axis=0, tiled=True),
                    gathered_scales)

        # --- stage: x-slab reparameterization (offset folded into P) -------
        def slab_pmats(pm_col: Array) -> Array:
            if model_axis is None:
                return pm_col
            i0 = lax.axis_index(model_axis) * nx_slab
            return shift_pmats_i(pm_col, i0.astype(pm_col.dtype))

        # --- stage: row-reduce epilogue (fused/pipelined full slab) --------
        # "scatter_bf16" moves the partial slab at half width: quantize to
        # bf16, psum_scatter, upcast — ONE rounding per rank (relative error
        # <= C_data * eps_bf16/2 on the reduced slab); the cross-pod finish
        # stays f32. Plain "scatter"/"psum" paths are byte-identical to the
        # f32 collective (the astype(f32) is a no-op on an f32 slab).
        def reduce_slab(slab: Array) -> Array:
            if not dp:
                return slab
            if self.reduce in SCATTER_REDUCES:
                if self.reduce == "scatter_bf16":
                    slab = slab.astype(jnp.bfloat16)
                slab = lax.psum_scatter(slab, dp[-1], scatter_dimension=1,
                                        tiled=True).astype(jnp.float32)
                for a in dp[:-1]:  # multi-pod: finish across pods
                    slab = lax.psum(slab, a)
                return slab
            for a in dp:
                slab = lax.psum(slab, a)
            return slab

        if self.schedule == "fused":
            def rank_fn(pm_local: Array, proj_local: Array) -> Array:
                pm_col, q_col, sc_col = gather_batch(pm_local, proj_local)
                slab = backproject(slab_pmats(pm_col), q_col,
                                   nx_slab, g.n_y, g.n_z, scales=sc_col)
                return reduce_slab(slab) * scale
            return rank_fn

        if self.schedule == "pipelined":
            def rank_fn(pm_local: Array, proj_local: Array) -> Array:
                pm_steps = pm_local.reshape(n_steps, nb, 3, 4)
                raw_steps = proj_local.reshape(n_steps, nb, g.n_v, g.n_u)
                buf = gather_batch(pm_steps[0], raw_steps[0])  # prologue

                def step(carry, xs):
                    acc, (pm_prev, q_prev, sc_prev) = carry
                    nxt = gather_batch(*xs)        # comm for batch s
                    acc = acc + backproject(        # compute for batch s-1
                        slab_pmats(pm_prev), q_prev, nx_slab, g.n_y, g.n_z,
                        scales=sc_prev)
                    return (acc, nxt), None

                init = (jnp.zeros((nx_slab, g.n_y, g.n_z), jnp.float32), buf)
                (acc, (pm_last, q_last, sc_last)), _ = lax.scan(
                    step, init, (pm_steps[1:], raw_steps[1:]))
                acc = acc + backproject(            # epilogue
                    slab_pmats(pm_last), q_last, nx_slab, g.n_y, g.n_z,
                    scales=sc_last)
                return reduce_slab(acc) * scale
            return rank_fn

        # chunked: per-y-chunk back-projection with an immediate per-chunk
        # reduce, bounding the live slab state (output-side streaming).
        y_chunks = self.y_chunks
        yc = g.n_y // y_chunks
        scatter = self.reduce in SCATTER_REDUCES
        compensated = self.reduce == "scatter_bf16"
        yc_local = yc // self._data_size if scatter else yc

        def chunk_reduce(part: Array) -> Array:
            if scatter:
                return lax.psum_scatter(part, data_axis, scatter_dimension=1,
                                        tiled=True)
            if data_axis is not None:
                part = lax.psum(part, data_axis)
            return part

        def rank_fn(pm_local: Array, proj_local: Array) -> Array:
            pm_steps = pm_local.reshape(n_steps, nb, 3, 4)
            raw_steps = proj_local.reshape(n_steps, nb, g.n_v, g.n_u)
            buf = gather_batch(pm_steps[0], raw_steps[0])

            def bp_chunks(state, pm_col, q_col, sc_col):
                acc, err = state
                pm_slab = slab_pmats(pm_col)

                def one_chunk(ci, st):
                    a, e = st
                    pm_c = shift_pmats_j(pm_slab,
                                         (ci * yc).astype(pm_slab.dtype))
                    part = backproject(pm_c, q_col, nx_slab, yc, g.n_z,
                                       scales=sc_col)
                    if compensated:
                        # error feedback: re-inject the residual this rank
                        # dropped when it quantized the SAME chunk last
                        # round, so quantization error does not accumulate
                        # over the n_steps micro-batches — only the final
                        # round's rounding survives (one per rank).
                        part = part + lax.dynamic_index_in_dim(
                            e, ci, axis=1, keepdims=False)
                        half = part.astype(jnp.bfloat16)
                        e = lax.dynamic_update_index_in_dim(
                            e, part - half.astype(jnp.float32), ci, axis=1)
                        red = lax.psum_scatter(
                            half, data_axis, scatter_dimension=1,
                            tiled=True).astype(jnp.float32)
                    else:
                        red = chunk_reduce(part)
                    a = lax.dynamic_update_index_in_dim(
                        a, a[:, ci] + red, ci, axis=1)
                    return a, e

                return lax.fori_loop(0, y_chunks, one_chunk, (acc, err))

            def step(carry, xs):
                state, prev = carry
                nxt = gather_batch(*xs)            # comm for batch s
                state = bp_chunks(state, *prev)    # compute for batch s-1
                return (state, nxt), None

            acc0 = jnp.zeros((nx_slab, y_chunks, yc_local, g.n_z),
                             jnp.float32)
            err0 = (jnp.zeros((nx_slab, y_chunks, yc, g.n_z), jnp.float32)
                    if compensated else None)
            ((acc, err), last), _ = lax.scan(step, ((acc0, err0), buf),
                                             (pm_steps[1:], raw_steps[1:]))
            acc, _ = bp_chunks((acc, err), *last)  # epilogue
            if pod_axis is not None:
                acc = lax.psum(acc, pod_axis)
            if not scatter:
                # dims 1,2 are contiguous locally when nothing is scattered
                acc = acc.reshape(nx_slab, g.n_y, g.n_z)
            return acc * scale

        return rank_fn

    def build(self, source=None, sink=None) -> Callable[[Array], Array]:
        """Validated, tuned, jitted reconstruction: projections -> volume.

        Input : (N_p, N_v, N_u) projections — sharded with
                `input_sharding(mesh)` when the plan has a mesh.
        Output: (N_x, N_y, N_z) f32; x slab-sharded over `model` on a mesh,
                plus y sharded over `data` with reduce="scatter". The
                chunked+scatter combination returns the 4-D
                (N_x, y_chunks, N_y/y_chunks/C_data, N_z) store layout —
                reshape(N_x, N_y, N_z) restores the canonical volume.

        `source`/`sink` (repro/io/streams.py) close the pipeline at the
        filesystem like the paper's ranks do: with a `ProjectionSource` the
        returned callable may be invoked with no argument — each rank
        scatter-reads only its own projection slice; with a `VolumeSink`
        the sharded output volume is streamed shard-per-file to the store
        before being returned (the slice-per-rank PFS write).

        Results are cached per plan, so repeated builds (and the thin
        legacy wrappers that build per call) never re-trace.
        """
        if source is not None or sink is not None:
            return self._build_with_io(source, sink)
        try:
            cached = _ENGINE_CACHE.get(self)
        except TypeError:  # unhashable field (exotic mesh) — build uncached
            cached = None
        if cached is not None:
            return cached
        self.validate()
        rank_fn = self._build_rank_fn()
        pmats_all = jnp.asarray(projection_matrices(self.geometry))
        if self.mesh is None:
            @jax.jit
            def reconstruct_fn(projections: Array) -> Array:
                return rank_fn(pmats_all, projections)
        else:
            mesh = self.mesh
            pspec = _proj_spec(mesh)
            out_sp = self._output_spec()

            @jax.jit
            def reconstruct_fn(projections: Array) -> Array:
                return shard_map(
                    rank_fn, mesh=mesh,
                    in_specs=(pspec, pspec),
                    out_specs=out_sp,
                    check_vma=False,
                )(pmats_all, projections)

        try:
            _ENGINE_CACHE[self] = reconstruct_fn
        except TypeError:
            pass
        return reconstruct_fn

    def _build_with_io(self, source, sink) -> Callable:
        """The engine with its filesystem endpoints attached: scatter-read
        projections from `source` when none are passed, stream the sharded
        output volume to `sink` shard-per-file. The core engine underneath
        comes from the per-plan cache, so attaching I/O never re-traces."""
        engine = self.build()

        def reconstruct_io(projections: Optional[Array] = None) -> Array:
            if projections is None:
                if source is None:
                    raise TypeError(
                        "this plan was built without a ProjectionSource; "
                        "pass the projections array")
                projections = source.load(self.mesh)
            volume = engine(projections)
            if sink is not None:
                jax.block_until_ready(volume)
                sink.write(volume)
            return volume

        return reconstruct_io


_SPEC_INT_KEYS = ("n_steps", "y_chunks", "vmem_budget")
_SPEC_STR_KEYS = ("impl", "window", "precision", "schedule", "reduce")
_SPEC_KEYS = _SPEC_STR_KEYS + _SPEC_INT_KEYS + ("blocks",)

# Known *values*, mapped to the key they belong to — so a bare typo like
# "pipelned" can be answered with "did you mean 'schedule=pipelined'?".
_SPEC_VALUE_KEYS = {
    **{v: "schedule" for v in _SCHEDULES},
    **{v: "reduce" for v in _REDUCES},
    **{v: "impl" for v in _IMPLS},
    **{v: "precision" for v in _PRECISIONS},
    **{v: "window" for v in _WINDOWS},
}


def _spec_hint(token: str) -> str:
    """'; did you mean ...?' for the nearest valid spec token, or ''."""
    import difflib
    candidates = ["auto"] + list(_SPEC_KEYS) + list(_SPEC_VALUE_KEYS)
    close = difflib.get_close_matches(token, candidates, n=1, cutoff=0.6)
    if not close:
        return ""
    match = close[0]
    if match in _SPEC_VALUE_KEYS:
        match = f"{_SPEC_VALUE_KEYS[match]}={match}"
    elif match in _SPEC_KEYS:
        match = f"{match}=..."
    return f"; did you mean {match!r}?"


def plan_from_spec(geometry: CBCTGeometry, spec: str = "",
                   mesh: Mesh | None = None, **overrides) -> ReconstructionPlan:
    """Build a plan from a compact ``key=value,key=value`` spec string — the
    one-flag configuration surface shared by the benchmark/example harnesses
    (e.g. ``--plan "schedule=pipelined,n_steps=4,precision=bf16"``).

    Recognized keys: impl, window, precision, schedule, n_steps, y_chunks,
    reduce, vmem_budget, blocks (as ``bi:bj:bs``). ``overrides`` kwargs win
    over the spec string.

    The bare token ``auto`` hands the remaining (pinned) dimensions to the
    planner (repro/planner): ``"auto"`` searches the whole space for the
    best feasible plan on this (geometry, mesh); ``"auto,precision=bf16"``
    searches with the precision axis pinned.
    """
    kwargs: dict = {}
    auto = False
    for item in filter(None, (s.strip() for s in spec.split(","))):
        if "=" not in item:
            if item == "auto":
                auto = True
                continue
            raise ValueError(
                f"plan spec token {item!r} is not key=value and not 'auto'; "
                f"valid keys: {', '.join(_SPEC_KEYS)}{_spec_hint(item)}")
        key, val = (s.strip() for s in item.split("=", 1))
        if key in _SPEC_INT_KEYS:
            kwargs[key] = int(val)
        elif key == "blocks":
            kwargs[key] = tuple(int(v) for v in val.split(":"))
        elif key in _SPEC_STR_KEYS:
            kwargs[key] = val
        else:
            raise ValueError(
                f"unknown plan spec key {key!r}; valid keys: "
                f"{', '.join(_SPEC_KEYS)}{_spec_hint(key)}")
    kwargs.update(overrides)
    if auto:
        from repro.planner import auto_plan
        window = kwargs.pop("window", "ramlak")
        vmem_budget = kwargs.pop("vmem_budget", None)
        return auto_plan(geometry, mesh=mesh, window=window,
                         vmem_budget=vmem_budget, **kwargs)
    return ReconstructionPlan(geometry=geometry, mesh=mesh, **kwargs)
