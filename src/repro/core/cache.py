"""Counting, bounded LRU cache — the one cache primitive behind the engine
cache (core/plan.py) and the service plan cache (repro/service).

Both caches hold expensive build artifacts (jitted engines, planner-search
results) keyed by hashable plan-like values, and both need the same three
things the plain dict they replace did not have:

  * a bound — engines pin compiled XLA executables; an unbounded cache is a
    memory leak under a long-lived service seeing many scan families;
  * counters — the service surfaces hit/miss/eviction counts in its stats,
    and the ISSUE-7 acceptance check ("second request in a family does zero
    planner-search work") is read directly off them;
  * a defined unhashable path — exotic keys (e.g. a mesh subclass that
    raises in __hash__) must fall through to an uncached build, *counted*,
    instead of silently disabling caching with a bare try/except.

Thread-safety: a single lock around the OrderedDict; `get_or_build` may
build the same value twice under a race but never corrupts the map (last
writer wins) — the artifacts are pure, so duplicated work is the only cost.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Optional

_MISSING = object()


class CountingLRU:
    """Bounded LRU mapping with hit/miss/eviction/unhashable counters.

    capacity <= 0 disables storage entirely (every get is a miss, every put
    a no-op) — useful to switch caching off without touching call sites.

    `name` additionally mirrors every count into the process-global metrics
    registry (repro/obs/metrics.py) as ``cache.<name>.{hits,misses,
    evictions,unhashable}`` — the unified view across all caches. The int
    attributes stay the per-INSTANCE truth (and what `stats()` reports);
    registry counters are cumulative for the process and are never reset by
    `clear()`. Unnamed caches (tests, scratch) stay registry-silent.
    """

    def __init__(self, capacity: int = 64, name: Optional[str] = None):
        self.capacity = int(capacity)
        self.name = name
        self._data: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.unhashable = 0
        if name is None:
            self._mirror = None
        else:
            from repro.obs import metrics as _metrics
            self._mirror = {
                c: _metrics.counter(f"cache.{name}.{c}")
                for c in ("hits", "misses", "evictions", "unhashable")
            }

    def _count(self, which: str, n: int = 1) -> None:
        """Increment an attribute counter (+ its registry mirror). Caller
        holds the instance lock; the registry counter has its own."""
        setattr(self, which, getattr(self, which) + n)
        if self._mirror is not None:
            self._mirror[which].inc(n)

    # -- mapping core --------------------------------------------------------

    def get(self, key: Any, default: Any = None) -> Any:
        """Counted lookup; unhashable keys count and return `default`."""
        try:
            with self._lock:
                val = self._data.get(key, _MISSING)
                if val is _MISSING:
                    self._count("misses")
                    return default
                self._data.move_to_end(key)
                self._count("hits")
                return val
        except TypeError:
            with self._lock:
                self._count("unhashable")
            return default

    def put(self, key: Any, value: Any) -> None:
        """Insert/refresh; evicts the least-recently-used entry past
        capacity. Unhashable keys count and are dropped."""
        try:
            with self._lock:
                if self.capacity <= 0:
                    return
                if key in self._data:
                    self._data.move_to_end(key)
                self._data[key] = value
                while len(self._data) > self.capacity:
                    self._data.popitem(last=False)
                    self._count("evictions")
        except TypeError:
            with self._lock:
                self._count("unhashable")

    def get_or_build(self, key: Any, build: Callable[[], Any]) -> Any:
        """Counted get, building (and caching) on miss. Unhashable keys
        build uncached — counted once per resolve, never raised."""
        try:
            hash(key)
        except TypeError:
            with self._lock:
                self._count("unhashable")
            return build()
        val = self.get(key, _MISSING)
        if val is not _MISSING:
            return val
        val = build()
        self.put(key, val)
        return val

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key: Any) -> bool:
        try:
            with self._lock:
                return key in self._data
        except TypeError:
            return False

    def keys(self):
        with self._lock:
            return list(self._data.keys())

    def clear(self, reset_counters: bool = False) -> None:
        with self._lock:
            self._data.clear()
            if reset_counters:
                self.hits = self.misses = 0
                self.evictions = self.unhashable = 0

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "unhashable": self.unhashable,
            }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        s = self.stats()
        return (f"CountingLRU(size={s['size']}/{s['capacity']}, "
                f"hits={s['hits']}, misses={s['misses']}, "
                f"evictions={s['evictions']}, unhashable={s['unhashable']})")
