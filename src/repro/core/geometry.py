"""CBCT geometry: projection matrices and the iFDK factorization theorems.

Implements Eq. (2) of the paper:  P_i = (M1 · Mrot · M0)[0:3, :]
with the volume->gantry transform M0, the gantry rotation Mrot (angle beta,
source-axis distance d) and the FPD projection M1 (source-detector distance D,
pixel pitches Du, Dv).

The three theorems that enable the factorized back-projection (Alg. 4):
  T1 (Z-symmetry)   voxels (i,j,k) and (i,j,Nz-1-k) project to (u,v) and
                    (u, Nv-1-v).
  T2 (u-invariance) u is independent of k.
  T3 (z-invariance) the homogeneous depth z (hence W = 1/z^2) is independent
                    of k:  z = d + sin(b)(i-cx)Dx - cos(b)(j-cy)Dy   (Eq. 3)

Both T2 and T3 are *structural* zeros of P (entries P[0,2] and P[2,2] vanish
exactly, not approximately), so the factorized algorithm is bit-compatible
with the reference up to floating-point reassociation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CBCTGeometry:
    """Cone-beam CT scan geometry (paper Table 1).

    All physical quantities share one length unit (mm by convention).
    """

    n_proj: int          # N_p: number of projections over 2*pi
    n_u: int             # detector width  (pixels)
    n_v: int             # detector height (pixels)
    d_u: float           # detector pixel pitch, U direction
    d_v: float           # detector pixel pitch, V direction
    d: float             # distance source -> rotation axis
    dsd: float           # D: distance source -> detector plane
    n_x: int             # volume size X (voxels)
    n_y: int             # volume size Y
    n_z: int             # volume size Z
    d_x: float           # voxel pitch X
    d_y: float           # voxel pitch Y
    d_z: float           # voxel pitch Z

    @property
    def theta(self) -> float:
        """Rotation step angle (paper: theta = 2*pi / N_p)."""
        return 2.0 * np.pi / self.n_proj

    @property
    def magnification(self) -> float:
        return self.dsd / self.d

    # -- virtual-detector (isocenter-rescaled) quantities used by filtering --
    @property
    def tau_u(self) -> float:
        """Detector pitch rescaled to the isocenter (virtual detector)."""
        return self.d_u * self.d / self.dsd

    @property
    def tau_v(self) -> float:
        return self.d_v * self.d / self.dsd

    @property
    def angles(self) -> np.ndarray:
        return np.arange(self.n_proj, dtype=np.float64) * self.theta

    def volume_shape(self) -> Tuple[int, int, int]:
        return (self.n_x, self.n_y, self.n_z)

    def proj_shape(self) -> Tuple[int, int, int]:
        return (self.n_proj, self.n_v, self.n_u)


def default_geometry(n: int = 64, n_proj: int | None = None) -> CBCTGeometry:
    """A well-posed test geometry reconstructing the unit ball [-1,1]^3.

    Source orbit radius 4, detector at distance 8 (magnification 2), detector
    sized to cover the unit ball with margin.
    """
    n_proj = n_proj if n_proj is not None else max(2 * n, 16)
    n_u = n_v = int(1.5 * n)
    half = 2.4  # physical detector half width at distance dsd=8
    return CBCTGeometry(
        n_proj=n_proj, n_u=n_u, n_v=n_v,
        d_u=2 * half / n_u, d_v=2 * half / n_v,
        d=4.0, dsd=8.0,
        n_x=n, n_y=n, n_z=n,
        d_x=2.0 / n, d_y=2.0 / n, d_z=2.0 / n,
    )


def paper_geometry(n_out: int = 4096, n_proj: int = 4096,
                   detector: int = 2048) -> CBCTGeometry:
    """The paper's benchmark problem (§5, Table 1): a 2048^2 x 4096
    projection set reconstructing an N^3 volume — the single source of the
    constants shared by the scaling-model/end-to-end/plan-search benchmarks
    and the perf-model regression tests."""
    return CBCTGeometry(
        n_proj=n_proj, n_u=detector, n_v=detector, d_u=0.002, d_v=0.002,
        d=4.0, dsd=8.0, n_x=n_out, n_y=n_out, n_z=n_out,
        d_x=0.001, d_y=0.001, d_z=0.001,
    )


# ---------------------------------------------------------------------------
# Projection matrices (Eq. 2)
# ---------------------------------------------------------------------------

def _m0(g: CBCTGeometry) -> np.ndarray:
    """Volume (voxel index) -> gantry (physical, centered) transform."""
    scale = np.diag([g.d_x, g.d_y, g.d_z, 1.0])
    center = np.array(
        [
            [1, 0, 0, -(g.n_x - 1) / 2.0],
            [0, -1, 0, (g.n_y - 1) / 2.0],
            [0, 0, -1, (g.n_z - 1) / 2.0],
            [0, 0, 0, 1],
        ],
        dtype=np.float64,
    )
    return scale @ center


def _mrot(g: CBCTGeometry, beta: float) -> np.ndarray:
    """Gantry rotation about Z by beta, then camera-frame swap with source
    translated d away from the axis."""
    cam = np.array(
        [
            [1, 0, 0, 0],
            [0, 0, -1, 0],
            [0, 1, 0, g.d],
            [0, 0, 0, 1],
        ],
        dtype=np.float64,
    )
    c, s = np.cos(beta), np.sin(beta)
    rot = np.array(
        [
            [c, -s, 0, 0],
            [s, c, 0, 0],
            [0, 0, 1, 0],
            [0, 0, 0, 1],
        ],
        dtype=np.float64,
    )
    return cam @ rot


def _m1(g: CBCTGeometry) -> np.ndarray:
    """Perspective projection onto the FPD plane (pixel coordinates)."""
    pix = np.diag([1.0 / g.d_u, 1.0 / g.d_v, 1.0, 1.0])
    proj = np.array(
        [
            [g.dsd, 0, (g.n_u - 1) * g.d_u / 2.0, 0],
            [0, g.dsd, (g.n_v - 1) * g.d_v / 2.0, 0],
            [0, 0, 1, 0],
            [0, 0, 0, 1],
        ],
        dtype=np.float64,
    )
    return pix @ proj


def projection_matrix(g: CBCTGeometry, beta: float) -> np.ndarray:
    """The 3x4 projection matrix P for gantry angle beta (Eq. 2)."""
    p_hat = _m1(g) @ _mrot(g, beta) @ _m0(g)
    return p_hat[0:3, :]


def projection_matrices(g: CBCTGeometry) -> np.ndarray:
    """All N_p projection matrices, shape (N_p, 3, 4), float32."""
    mats = np.stack([projection_matrix(g, b) for b in g.angles])
    return mats.astype(np.float32)


def assert_factorizable(p: np.ndarray, atol: float = 1e-6) -> None:
    """Verify the structural zeros required by Theorems 2 & 3.

    P may come from calibration rather than from an ideal geometry; the
    factorized back-projection (Alg. 4) is only valid when the k-column of the
    x and z rows vanish.
    """
    p = np.asarray(p)
    bad_x = np.max(np.abs(p[..., 0, 2]))
    bad_z = np.max(np.abs(p[..., 2, 2]))
    if bad_x > atol or bad_z > atol:
        raise ValueError(
            "projection matrix is not factorizable: "
            f"|P[0,2]|={bad_x:.3e}, |P[2,2]|={bad_z:.3e} (Theorems 2/3 violated)"
        )


# ---------------------------------------------------------------------------
# Coordinate computation (used by the reference algorithm and the oracles)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def project_voxels(p: Array, nx: int, ny: int, nz: int) -> Tuple[Array, Array, Array]:
    """Project every voxel index (i,j,k) through P (Alg. 2 lines 6-9).

    Returns (u, v, w) each of shape (nx, ny, nz): detector coordinates and the
    distance weight w = 1/z^2.
    """
    i = jnp.arange(nx, dtype=jnp.float32)[:, None, None]
    j = jnp.arange(ny, dtype=jnp.float32)[None, :, None]
    k = jnp.arange(nz, dtype=jnp.float32)[None, None, :]
    x = p[0, 0] * i + p[0, 1] * j + p[0, 2] * k + p[0, 3]
    y = p[1, 0] * i + p[1, 1] * j + p[1, 2] * k + p[1, 3]
    z = p[2, 0] * i + p[2, 1] * j + p[2, 2] * k + p[2, 3]
    f = 1.0 / z
    return x * f, y * f, f * f


def source_position(g: CBCTGeometry, beta: float) -> np.ndarray:
    """World (gantry-frame, physical) position of the X-ray source."""
    return np.array([-g.d * np.sin(beta), -g.d * np.cos(beta), 0.0])


def detector_pixel_position(g: CBCTGeometry, beta: float,
                            iu: np.ndarray, iv: np.ndarray) -> np.ndarray:
    """World positions of detector pixel centers (iu, iv) at angle beta.

    Inverts the camera mapping used by projection_matrix: a detector pixel
    (iu, iv) sits at camera coords (cx, cy, cz=D) with
    cx = (iu - cu) * Du, cy = (iv - cv) * Dv.
    """
    cu = (g.n_u - 1) / 2.0
    cv = (g.n_v - 1) / 2.0
    cx = (np.asarray(iu, np.float64) - cu) * g.d_u
    cy = (np.asarray(iv, np.float64) - cv) * g.d_v
    # camera -> rotated gantry frame: rx = cx, rz = -cy, ry = cz - d
    rx, ry, rz = cx, g.dsd - g.d, -cy
    c, s = np.cos(-beta), np.sin(-beta)
    gx = c * rx - s * ry
    gy = s * rx + c * ry
    gz = rz * np.ones_like(gx)
    return np.stack(np.broadcast_arrays(gx, gy, gz), axis=-1)
