"""Storage-precision policy for the projection stream (paper §3.2).

iFDK stores filtered projections as FP16 textures: the back-projection hot
loop reads half-width taps (halving HBM/texture traffic) while the voxel
accumulator stays in FP32 — and, at scale, the MPI AllGather of filtered
projections (the dominant communication term, §4.1.3) moves half the bytes.
This module is the single source of truth for that trade:

  * ``storage``  — the dtype filtered projections are *stored and
                   communicated* in (``fp32`` | ``bf16`` | ``fp16``).
  * accumulation — always float32, in every back-projection implementation
                   (reference, factorized, Pallas kernel, MXU): taps are
                   upcast after the gather, before the w = 1/z^2 FMA.

The policy rides through ``fdk.reconstruct``, ``make_distributed_fdk``,
``make_pipelined_fdk`` and ``make_chunked_fdk`` as a ``precision=`` argument
(a ``Precision``, a storage-dtype name, or None for the backend default).

Default selection: ``bf16`` on CPU/TPU (same exponent range as f32 — no
overflow concern for ramp-filtered projections, which can exceed fp16's
65504 for high-contrast scans), ``fp16`` on GPU (texture-unit heritage,
matches the paper's choice).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

_STORAGE_DTYPES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
}
_CANONICAL = {
    "float32": "fp32", "f32": "fp32",
    "bfloat16": "bf16",
    "float16": "fp16", "half": "fp16",
}


def default_storage(backend: str | None = None) -> str:
    """bf16 on CPU/TPU, fp16 on GPU (the paper's texture dtype)."""
    backend = backend or jax.default_backend()
    return "fp16" if backend == "gpu" else "bf16"


@dataclasses.dataclass(frozen=True)
class Precision:
    """Projection-stream precision policy: storage dtype + f32 accumulate."""

    storage: str = "fp32"

    def __post_init__(self):
        name = _CANONICAL.get(self.storage, self.storage)
        if name not in _STORAGE_DTYPES:
            raise ValueError(
                f"unknown storage precision {self.storage!r}; "
                f"choose from {sorted(_STORAGE_DTYPES)}"
            )
        object.__setattr__(self, "storage", name)

    @property
    def storage_dtype(self) -> jnp.dtype:
        return jnp.dtype(_STORAGE_DTYPES[self.storage])

    @property
    def accum_dtype(self) -> jnp.dtype:
        return jnp.dtype(jnp.float32)

    @property
    def storage_bytes(self) -> int:
        return self.storage_dtype.itemsize

    def eps(self) -> float:
        """Machine epsilon of the storage dtype (the quantization step)."""
        return float(jnp.finfo(self.storage_dtype).eps)

    def rmse_tol(self) -> float:
        """Relative-RMSE acceptance bound vs an fp32 oracle.

        Quantizing the projections to storage dtype perturbs each tap by at
        most eps/2 relative; the weighted sum over N_p projections averages
        the independent rounding errors, so a small multiple of eps bounds
        the volume RMSE with margin. fp32 keeps the paper's 1e-5 bound.
        """
        return max(1e-5, 2.0 * self.eps())

    def max_tol(self) -> float:
        """Relative max-abs-error bound vs an fp32 oracle (no averaging)."""
        return max(1e-4, 8.0 * self.eps())

    def allgather_bytes(self, n_proj: int, n_v: int, n_u: int) -> int:
        """Per-rank AllGather payload for the filtered-projection stream."""
        return n_proj * n_v * n_u * self.storage_bytes


def resolve_precision(precision: "Precision | str | None") -> Precision:
    """None -> backend default; str -> Precision(str); Precision -> itself."""
    if precision is None:
        return Precision(default_storage())
    if isinstance(precision, str):
        return Precision(precision)
    return precision


def psnr(x, ref, data_range: float | None = None) -> float:
    """Peak signal-to-noise ratio of x against ref, in dB.

    Used by the golden-value regression tests: a reconstruction-quality
    floor that any kernel/precision change must clear.
    """
    x = np.asarray(x, np.float64)
    ref = np.asarray(ref, np.float64)
    if data_range is None:
        data_range = float(ref.max() - ref.min())
    mse = float(np.mean((x - ref) ** 2))
    if mse == 0.0:
        return float("inf")
    return 10.0 * np.log10(data_range * data_range / mse)
