"""Stream codecs + storage-precision policy for the projection stream.

At scale the pipeline is bound by moving bytes, not flops: the AllGather of
filtered projections (paper §4.1.3) and the row Reduce of partial volumes
(§4.1.4) dominate. iFDK's answer is FP16 textures — half-width taps, f32
accumulate. This module generalizes that into a **stream-codec layer**: one
abstraction owning how the filtered-projection stream is represented on the
wire (and on disk), so every consumer — the plan engine's collectives, the
planner's cost/feasibility models, the shard store, the kernels — prices and
moves the same bytes.

  StreamCodec        encode (f32 -> wire) / decode (wire -> f32), the wire
                     dtype, wire bytes per sample, and an optional
                     per-projection f32 **scale sidecar**.
  f32 / bf16         plain casts (byte-identical to the historical policy).
  fp16               scale-on-overflow: ramp-filtered projections of
                     high-contrast scans can exceed fp16's 65504 — a naive
                     cast emits inf and poisons the volume. Encode applies
                     a per-projection scale s = max(1, max|q| / 65504):
                     in-range projections get s = 1.0 exactly (data bits
                     identical to the naive cast), overflowing ones are
                     brought into range and recovered by the decode scale
                     instead of clipped (a pure saturate would bias every
                     clipped tap; scaling keeps fp16 relative accuracy at
                     any contrast).
  fp8_e4m3           e4m3 storage with one f32 scale per projection:
                     encode *normalizes* each projection by s = max|q|/448
                     (e4m3's epsilon is relative — using the full range
                     maximizes SNR) and casts; the (N_p,) f32 scale sidecar
                     rides next to the data through the AllGather and the
                     shard store. Quarter the AllGather bytes of f32
                     (+ 4 B/projection sidecar).
  fp8_e5m2           e5m2 storage, same normalizing scheme: one mantissa
                     bit fewer than e4m3 (eps 0.25 vs 0.125, so ~6 dB less
                     PSNR) but 8x the dynamic range within one projection
                     (max/eps ~ 2^18 vs 2^15) — the wide-exponent wire
                     format for very-high-contrast scans where a single
                     per-projection scale must cover both metal-bright and
                     soft-tissue taps. Same bytes as e4m3.

Decoding happens *inside* the back-projection implementations: taps are
gathered in the wire dtype, upcast to f32, and the per-projection scale is
folded into the accumulation weight (``w * scale`` — bilinear interpolation
is linear, so scaling after the gather equals decoding up front). The voxel
accumulator is always f32.

``Precision`` remains the user-facing policy object (a storage name riding
through every plan/entry point); it now resolves to a codec via ``.codec``.
Default selection: ``bf16`` on CPU/TPU (f32 exponent range), ``fp16`` on GPU
(texture-unit heritage, matching the paper).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_STORAGE_DTYPES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
}
_CANONICAL = {
    "float32": "fp32", "f32": "fp32",
    "bfloat16": "bf16",
    "float16": "fp16", "half": "fp16",
    "fp8": "fp8_e4m3", "e4m3": "fp8_e4m3",
    "float8_e4m3": "fp8_e4m3", "float8_e4m3fn": "fp8_e4m3",
    "e5m2": "fp8_e5m2", "float8_e5m2": "fp8_e5m2",
}

# One f32 scale per projection (the sidecar "manifest row" of a scaled
# codec): 4 bytes per projection on the wire and in the shard store.
SCALE_BYTES = 4


class EncodedStream(NamedTuple):
    """A filtered-projection batch in wire format: the quantized data and,
    for scaled codecs, one f32 scale per projection (else None). The pair is
    what the column AllGather moves and what the shard store persists."""

    data: Array
    scales: Optional[Array]

    @property
    def nbytes(self) -> int:
        n = self.data.size * jnp.dtype(self.data.dtype).itemsize
        if self.scales is not None:
            n += self.scales.size * SCALE_BYTES
        return n


@dataclasses.dataclass(frozen=True)
class StreamCodec:
    """How the filtered-projection stream is represented on the wire.

    ``encode`` consumes the filter stage's f32 output; ``decode`` restores
    f32 (the oracle inverse — the engine instead folds ``scales`` into the
    back-projection weight, which is equivalent by linearity).
    """

    name: str
    wire_dtype: jnp.dtype
    has_scales: bool = False
    # Scaled codecs only: True normalizes every projection to the full wire
    # range (fp8 — relative epsilon, use all of it); False scales only when
    # the projection would overflow, so in-range data stays bit-identical
    # to a plain cast (fp16).
    normalize: bool = False

    @property
    def wire_bytes_per_sample(self) -> int:
        return jnp.dtype(self.wire_dtype).itemsize

    def sidecar_bytes(self, n_proj: int) -> int:
        """Bytes of the per-projection scale sidecar for `n_proj` frames."""
        return SCALE_BYTES * n_proj if self.has_scales else 0

    def wire_bytes(self, n_proj: int, n_v: int, n_u: int) -> int:
        """Total wire bytes of an encoded (n_proj, n_v, n_u) stream:
        quantized data + scale sidecar. The one formula the engine, the
        planner's cost model and the benchmarks all share."""
        return (n_proj * n_v * n_u * self.wire_bytes_per_sample
                + self.sidecar_bytes(n_proj))

    def encode(self, q: Array) -> EncodedStream:
        """f32 filtered projections (..., N_v, N_u) -> wire format."""
        if self.has_scales:
            fmax = float(jnp.finfo(self.wire_dtype).max)
            amax = jnp.max(jnp.abs(q).astype(jnp.float32), axis=(-2, -1))
            if self.normalize:
                scales = jnp.where(amax > 0, amax / fmax, 1.0)
            else:
                scales = jnp.maximum(amax / fmax, 1.0)
            data = (q.astype(jnp.float32)
                    / scales[..., None, None]).astype(self.wire_dtype)
            return EncodedStream(data, scales)
        return EncodedStream(q.astype(self.wire_dtype), None)

    def decode(self, data: Array, scales: Optional[Array] = None) -> Array:
        """Wire format -> f32 taps (the reference inverse of ``encode``)."""
        out = data.astype(jnp.float32)
        if self.has_scales:
            if scales is None:
                raise ValueError(
                    f"codec {self.name!r} needs its per-projection scale "
                    "sidecar to decode")
            out = out * scales[..., None, None].astype(jnp.float32)
        return out


CODECS = {
    "fp32": StreamCodec("fp32", jnp.dtype(jnp.float32)),
    "bf16": StreamCodec("bf16", jnp.dtype(jnp.bfloat16)),
    "fp16": StreamCodec("fp16", jnp.dtype(jnp.float16), has_scales=True),
    "fp8_e4m3": StreamCodec("fp8_e4m3", jnp.dtype(jnp.float8_e4m3fn),
                            has_scales=True, normalize=True),
    "fp8_e5m2": StreamCodec("fp8_e5m2", jnp.dtype(jnp.float8_e5m2),
                            has_scales=True, normalize=True),
}


def codec_for(name: str) -> StreamCodec:
    """Resolve a storage name (or alias) to its StreamCodec."""
    return Precision(name).codec


def default_storage(backend: str | None = None) -> str:
    """bf16 on CPU/TPU, fp16 on GPU (the paper's texture dtype)."""
    backend = backend or jax.default_backend()
    return "fp16" if backend == "gpu" else "bf16"


@dataclasses.dataclass(frozen=True)
class Precision:
    """Projection-stream precision policy: storage codec + f32 accumulate."""

    storage: str = "fp32"

    def __post_init__(self):
        name = _CANONICAL.get(self.storage, self.storage)
        if name not in _STORAGE_DTYPES:
            raise ValueError(
                f"unknown storage precision {self.storage!r}; "
                f"choose from {sorted(_STORAGE_DTYPES)}"
            )
        object.__setattr__(self, "storage", name)

    @property
    def codec(self) -> StreamCodec:
        return CODECS[self.storage]

    @property
    def storage_dtype(self) -> jnp.dtype:
        return jnp.dtype(_STORAGE_DTYPES[self.storage])

    @property
    def accum_dtype(self) -> jnp.dtype:
        return jnp.dtype(jnp.float32)

    @property
    def storage_bytes(self) -> int:
        """Wire bytes per sample (the codec's quantized itemsize; the scale
        sidecar is priced separately — see ``wire_bytes``)."""
        return self.storage_dtype.itemsize

    def eps(self) -> float:
        """Machine epsilon of the storage dtype (the quantization step)."""
        return float(jnp.finfo(self.storage_dtype).eps)

    def rmse_tol(self) -> float:
        """Relative-RMSE acceptance bound vs an fp32 oracle.

        Quantizing the projections to storage dtype perturbs each tap by at
        most eps/2 relative; the weighted sum over N_p projections averages
        the independent rounding errors, so a small multiple of eps bounds
        the volume RMSE with margin. fp32 keeps the paper's 1e-5 bound.

        Normalizing codecs (fp8) get a TIGHTER bound than the generic
        2*eps: per-projection scaling pins every tap at eps/2 of its
        projection's max, and the projection average shrinks the volume
        RMSE further — eps/4 still leaves ~7x margin over the measured
        error while keeping the acceptance gates sensitive to a
        misapplied/misaligned scale sidecar (which degrades output 10x+).
        """
        if self.codec.normalize:
            return max(1e-5, self.eps() / 4)
        return max(1e-5, 2.0 * self.eps())

    def max_tol(self) -> float:
        """Relative max-abs-error bound vs an fp32 oracle (no averaging);
        eps (not 8*eps) for normalizing codecs, same rationale as
        ``rmse_tol``."""
        if self.codec.normalize:
            return max(1e-4, self.eps())
        return max(1e-4, 8.0 * self.eps())

    def sidecar_bytes(self, n_proj: int) -> int:
        return self.codec.sidecar_bytes(n_proj)

    def wire_bytes(self, n_proj: int, n_v: int, n_u: int) -> int:
        return self.codec.wire_bytes(n_proj, n_v, n_u)

    def allgather_bytes(self, n_proj: int, n_v: int, n_u: int) -> int:
        """Per-rank AllGather payload for the filtered-projection stream
        (quantized data + scale sidecar)."""
        return self.wire_bytes(n_proj, n_v, n_u)


def resolve_precision(precision: "Precision | str | None") -> Precision:
    """None -> backend default; str -> Precision(str); Precision -> itself."""
    if precision is None:
        return Precision(default_storage())
    if isinstance(precision, str):
        return Precision(precision)
    return precision


def psnr(x, ref, data_range: float | None = None) -> float:
    """Peak signal-to-noise ratio of x against ref, in dB.

    Used by the golden-value regression tests: a reconstruction-quality
    floor that any kernel/precision change must clear.
    """
    x = np.asarray(x, np.float64)
    ref = np.asarray(ref, np.float64)
    if data_range is None:
        data_range = float(ref.max() - ref.min())
    mse = float(np.mean((x - ref) ** 2))
    if mse == 0.0:
        return float("inf")
    return 10.0 * np.log10(data_range * data_range / mse)
