"""iFDK performance model (paper §4.2, Eqs. 8-19).

T_compute = max(T_load, T_flt, T_AllGather, T_bp)            (Eq. 17)
T_post    = T_trans + T_D2H + T_reduce + T_store             (Eq. 18)
T_runtime = T_compute + T_post                               (Eq. 19)

Constants are per-system micro-benchmark values (§4.2.1). `ABCI` reproduces
the paper's projections (V100 nodes, GPFS, EDR IB); `TPU_V5E` adapts the
model to the dry-run target: PCIe terms vanish (the volume never crosses a
host bus before the reduce — HBM-resident), H2D becomes an HBM write term,
and the collective throughputs derive from ICI/DCN link bandwidth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .distributed import IFDKGrid
from .geometry import CBCTGeometry


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Per-system micro-benchmark constants (§4.2.1), including the
    parallel-filesystem bandwidths the I/O terms (Eq. 8/16 — the planner's
    T_read/T_write) are priced from."""

    name: str
    bw_load: float          # PFS aggregate read bandwidth, B/s
    bw_store: float         # PFS aggregate write bandwidth, B/s
    th_flt: float           # filtering throughput, projections/s per node
    th_allgather: float     # AllGather throughput, projections/s per rank-group
    gups_bp: float          # back-projection kernel throughput, GUPS/device
    th_reduce: float        # volume reduction throughput, B/s per rank
    bw_hd: float            # host<->device (PCIe) bandwidth per connector, B/s
    n_hd_links: int         # PCIe connectors per node (paper N_PCIe)
    devices_per_node: int
    # Per-rank PFS link bandwidth, B/s. The slice-per-rank store (repro/io)
    # reads/writes one file per rank, so aggregate I/O bandwidth is
    # min(PFS aggregate, n_concurrent_ranks * bw_rank_io): few writers are
    # link-bound, many writers saturate the filesystem. None = uncapped
    # (the paper's Eq. 8/16, which assume full aggregate bandwidth).
    bw_rank_io: Optional[float] = None

    def with_pfs(self, read: Optional[float] = None,
                 write: Optional[float] = None,
                 rank_io: Optional[float] = None) -> "MachineSpec":
        """This machine with its PFS re-benchmarked (or throttled): the knob
        the planner's with-I/O ranking is regression-tested against."""
        updates = {}
        if read is not None:
            updates["bw_load"] = read
        if write is not None:
            updates["bw_store"] = write
        if rank_io is not None:
            updates["bw_rank_io"] = rank_io
        return dataclasses.replace(self, **updates)

    def with_overlay(self, *, flt_scale: float = 1.0,
                     allgather_scale: float = 1.0,
                     reduce_scale: float = 1.0,
                     read_scale: float = 1.0,
                     write_scale: float = 1.0) -> "MachineSpec":
        """This machine re-anchored by measured/predicted TIME scales (the
        calibration fit's overlay, planner/calibrate.py): a stage that ran
        `s`x slower than modeled gets its throughput/bandwidth divided by
        `s`, so the model predicts the measured time going forward. Scales
        of 1.0 (unfitted constants) leave the stock value untouched."""
        def div(v: float, s: float) -> float:
            return v / s if s > 0 else v

        updates = {}
        if flt_scale != 1.0:
            updates["th_flt"] = div(self.th_flt, flt_scale)
        if allgather_scale != 1.0:
            updates["th_allgather"] = div(self.th_allgather, allgather_scale)
        if reduce_scale != 1.0:
            updates["th_reduce"] = div(self.th_reduce, reduce_scale)
        if read_scale != 1.0:
            updates["bw_load"] = div(self.bw_load, read_scale)
        if write_scale != 1.0:
            updates["bw_store"] = div(self.bw_store, write_scale)
        if not updates:
            return self
        updates["name"] = f"{self.name}+calibrated"
        return dataclasses.replace(self, **updates)

    def agg_read_bw(self, n_readers: int) -> float:
        """Aggregate PFS read bandwidth `n_readers` concurrent ranks see."""
        if self.bw_rank_io is None:
            return self.bw_load
        return min(self.bw_load, n_readers * self.bw_rank_io)

    def agg_write_bw(self, n_writers: int) -> float:
        """Aggregate PFS write bandwidth `n_writers` concurrent ranks see."""
        if self.bw_rank_io is None:
            return self.bw_store
        return min(self.bw_store, n_writers * self.bw_rank_io)


# Backwards-compatible alias (pre-I/O name).
SystemConstants = MachineSpec


# Paper §5.1/§5.3.3 measured constants (ABCI: 4xV100 + 2xEDR per node, GPFS).
ABCI = MachineSpec(
    name="abci-v100",
    bw_load=50e9, bw_store=28.5e9,
    th_flt=100.0, th_allgather=55.0,
    gups_bp=200.0,                      # Table 4: L1-Tran ~200 GUPS
    th_reduce=3.0e9,                    # ~8GB in ~2.7s (dual EDR)
    bw_hd=11.9e9, n_hd_links=2, devices_per_node=4,
)

# TPU v5e pod target: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
# gups_bp derived from the roofline of the Pallas kernel (see EXPERIMENTS.md
# §Roofline): the BP inner loop is ~17 flops + 4 f32 taps per update; on v5e
# it is HBM/VMEM-bound at roughly bw_hbm / 20 B per update ~ 38 GUPS... the
# kernel streams the volume once per 32-projection batch, so the effective
# rate is gather-issue-bound; we use a conservative 100 GUPS/chip.
TPU_V5E = MachineSpec(
    name="tpu-v5e",
    bw_load=100e9, bw_store=100e9,
    th_flt=2000.0, th_allgather=400.0,
    gups_bp=100.0,
    th_reduce=50e9,                     # ICI reduce-scatter, ~1 link
    bw_hd=819e9, n_hd_links=1,          # HBM takes the PCIe role (no host hop)
    devices_per_node=4,
)


@dataclasses.dataclass(frozen=True)
class PerfBreakdown:
    t_load: float
    t_flt: float
    t_allgather: float
    t_h2d: float
    t_bp: float
    t_d2h: float
    t_reduce: float
    t_store: float
    # Eq. 17 assumes the paper's software pipeline: load/filter/AllGather/BP
    # overlap, so T_compute is the max of the stage times. A non-pipelined
    # (fused) schedule serializes the stages instead — overlap=False makes
    # t_compute their sum (the planner's schedule-aware cost, planner/cost.py).
    overlap: bool = True

    # Planner-visible I/O terms: Eq. 8 is the PFS read of the raw
    # projections, Eq. 16 the PFS write of the volume (the shard store's
    # slice-per-rank files, repro/io). Named aliases so I/O is first-class
    # in breakdown tables — t_read rides inside T_compute (the paper
    # overlaps the load with the pipeline), t_write inside T_post.
    @property
    def t_read(self) -> float:                         # Eq. 8 alias
        return self.t_load

    @property
    def t_write(self) -> float:                        # Eq. 16 alias
        return self.t_store

    @property
    def t_io(self) -> float:
        return self.t_read + self.t_write

    @property
    def t_compute(self) -> float:                      # Eq. 17
        stages = (self.t_load, self.t_flt, self.t_allgather, self.t_bp)
        return max(stages) if self.overlap else sum(stages)

    @property
    def t_post(self) -> float:                         # Eq. 18 (T_trans ~ 0)
        return self.t_d2h + self.t_reduce + self.t_store

    @property
    def t_runtime(self) -> float:                      # Eq. 19
        return self.t_compute + self.t_post

    @property
    def delta(self) -> float:
        """Paper Table 5 overlap factor: serial/overlapped compute time."""
        return (self.t_flt + self.t_allgather + self.t_bp) / max(
            self.t_compute, 1e-12
        )


def predict(g: CBCTGeometry, grid: IFDKGrid,
            sys: MachineSpec = ABCI,
            storage_bytes: float = 4.0,
            sidecar_bytes: float = 0.0,
            reduce_bytes: float = 4.0) -> PerfBreakdown:
    """Eqs. 8-16 (float32 volume; projection-stream width `storage_bytes`).

    `storage_bytes` is the wire itemsize of the projection stream — the
    stream codec's `wire_bytes_per_sample` (core/precision.py): it scales
    the load, AllGather and H2D terms — the paper's FP16-texture halving
    (or the fp8 codec's quartering) of the dominant communication time.
    `sidecar_bytes` is the codec's total per-projection scale sidecar
    (fp8: 4 B x N_p) riding on the same wire; it is amortized into the
    per-sample width so every projection-stream byte term prices it.
    `reduce_bytes` is the itemsize the volume Reduce moves (4.0 = f32 psum/
    psum_scatter, 2.0 = the plan layer's bf16 compensated scatter); D2H and
    the PFS store stay f32 — the accumulator and the stored volume are
    always f32. The defaults reproduce the paper's numbers verbatim.

    I/O terms (T_read = Eq. 8, T_write = Eq. 16) price the slice-per-rank
    shard store (repro/io): all R*C ranks read concurrently, R slab owners
    write. With `bw_rank_io` set on the MachineSpec the effective bandwidth
    is capped at n_concurrent * bw_rank_io (per-rank PFS links), otherwise
    the paper's aggregate-bandwidth assumption holds verbatim.
    """
    szf = 4.0
    # Effective wire bytes per projection sample: quantized data plus the
    # scale sidecar spread over the N_u*N_v samples of each projection.
    sp = float(storage_bytes) + float(sidecar_bytes) / (
        g.n_u * g.n_v * g.n_proj or 1)
    r, c = grid.r, grid.c
    n_ranks = grid.n_ranks
    n_nodes = max(1, n_ranks // sys.devices_per_node)
    proj_bytes = sp * g.n_u * g.n_v * g.n_proj
    vol_bytes = szf * g.n_x * g.n_y * g.n_z

    t_load = proj_bytes / sys.agg_read_bw(n_ranks)                      # Eq. 8
    t_flt = g.n_proj / (n_nodes * sys.th_flt)                           # Eq. 9
    t_allgather = (g.n_proj * (sp / szf)
                   / (c * r * sys.th_allgather))                        # Eq.10
    t_h2d = (sp * sys.devices_per_node * g.n_u * g.n_v * g.n_proj
             / (c * sys.bw_hd * sys.n_hd_links))                        # Eq.11
    updates = g.n_x * g.n_y * g.n_z / r * (g.n_proj / c)
    t_bp = t_h2d + updates / (sys.gups_bp * 2**30)                      # Eq.12
    t_d2h = (szf * sys.devices_per_node * g.n_x * g.n_y * g.n_z
             / (r * sys.bw_hd * sys.n_hd_links))                        # Eq.14
    t_reduce = (float(reduce_bytes) * g.n_x * g.n_y * g.n_z
                / (r * sys.th_reduce))                                  # Eq.15
    if c == 1:
        t_reduce = 0.0  # paper: no inter-rank reduction when C == 1
    t_store = vol_bytes / sys.agg_write_bw(r)                           # Eq.16
    return PerfBreakdown(t_load, t_flt, t_allgather, t_h2d, t_bp,
                         t_d2h, t_reduce, t_store)


def gups_end_to_end(g: CBCTGeometry, b: PerfBreakdown) -> float:
    updates = g.n_x * g.n_y * g.n_z * float(g.n_proj)
    return updates / (b.t_runtime * 2**30)
