"""iFDK distributed decomposition (paper §4) on a JAX device mesh.

Paper mapping (DESIGN.md §4):

  * C (columns, projection groups)  -> mesh axes ("pod", "data")
  * R (rows, volume slabs)          -> mesh axis "model"

Per rank (paper Fig. 3): load + filter N_p/(C*R) projections; **AllGather**
the filtered projections along the column (our `model` axis) so the whole
column group holds its N_p/C subset; back-project the rank's volume slab;
**Reduce** partial slabs along the row (our `data`/`pod` axes).

Adaptations (documented in DESIGN.md §2/§9):
  * The paper slabs the *outermost* dimension of its k-major volume layout
    (z). Our TPU layout keeps z on the lane dimension, so we slab the
    outermost dimension of *our* layout — x. Same decomposition principle;
    keeps Theorem-1 mirror pairs on-rank and lanes contiguous.
  * Slab offsets are folded into the projection matrices (a translation in i
    is P[:, 3] += i0 * P[:, 0]), so every back-projection implementation
    (reference / factorized / Pallas / MXU) is reused unchanged.
  * The paper's rooted MPI_Reduce becomes psum (replicated slab) or
    psum_scatter (beyond-paper: output left sharded over the data axis for
    parallel store — removes the root bottleneck).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL
from .fdk import BpImpl
from .geometry import CBCTGeometry
from .precision import Precision

Array = jax.Array

# Row-reduce modes that leave the volume sharded over the data axis (vs
# psum's replicated slab), and the itemsize each mode moves on the wire —
# THE two definitions shared by the engine (core/plan.py), output_spec
# below, and the planner's cost/feasibility models. A new reduce mode is
# added here once, not re-declared per consumer.
SCATTER_REDUCES = ("scatter", "scatter_bf16")
REDUCE_WIRE_ITEMSIZE = {"psum": 4, "scatter": 4, "scatter_bf16": 2}


@dataclasses.dataclass(frozen=True)
class IFDKGrid:
    """The paper's 2-D rank grid: R rows (volume slabs) x C columns."""

    r: int
    c: int

    @property
    def n_ranks(self) -> int:
        return self.r * self.c


def choose_grid(g: CBCTGeometry, n_devices: int,
                hbm_bytes: int = 16 * 2**30,
                sub_vol_bytes: int = 8 * 2**30) -> IFDKGrid:
    """Paper §4.1.5: minimize R (each slab as large as fits), maximize C.

    R = sizeof(float) * Nx*Ny*Nz / N_sub_vol, rounded up to a power of two
    that divides n_devices.
    """
    vol_bytes = 4 * g.n_x * g.n_y * g.n_z
    det_bytes = 4 * g.n_u * g.n_v * 32
    # Doubling R only shrinks the slab term vol_bytes/r: if the per-rank
    # detector working set ALONE does not fit, no R ever satisfies the loop
    # condition below and it spins forever. Fail loudly instead.
    if det_bytes >= hbm_bytes:
        raise ValueError(
            f"detector working set ({det_bytes / 2**30:.2f} GiB for "
            f"{g.n_u} x {g.n_v} projections) alone exceeds "
            f"hbm_bytes={hbm_bytes / 2**30:.2f} GiB — no slab count R can "
            "fit this geometry; reduce the detector or raise hbm_bytes")
    r = 1
    while vol_bytes / r > sub_vol_bytes or (det_bytes
                                            + vol_bytes / r) > hbm_bytes:
        r *= 2
    if g.n_x % r:
        # Caught here, where the number came from, instead of much later by
        # ReconstructionPlan.validate() on a grid the caller never chose.
        raise ValueError(
            f"memory bound needs R={r} volume slabs, but R={r} does not "
            f"tile N_x={g.n_x}; pad the volume or raise sub_vol_bytes")
    if r > n_devices:
        raise ValueError(
            f"volume needs R={r} slabs but only {n_devices} devices available"
        )
    # The grid must be rectangular: R has to divide n_devices. R is a power
    # of two, and if 2^k does not divide n_devices no larger power of two
    # does either — so a non-divisible R is unfixable, not growable (the old
    # `while n_devices % r: r *= 2` loop never terminated here).
    if n_devices % r:
        raise ValueError(
            f"memory bound needs R={r} volume slabs, but {r} does not "
            f"divide n_devices={n_devices}; use a device count whose "
            f"largest power-of-two factor is at least {r}, or raise "
            "sub_vol_bytes"
        )
    return IFDKGrid(r=r, c=n_devices // r)


def grid_candidates(g: CBCTGeometry, n_devices: int) -> list[IFDKGrid]:
    """Every rectangular R x C factorization of `n_devices` the pipeline can
    actually run: R must tile the volume (R | N_x) and the ranks must tile
    the projections (R*C | N_p) — the divisibility half of §4.1.5, with the
    memory half left to the caller (the planner's feasibility model, or
    `choose_grid`'s sub-volume bound). Ordered by ascending R (the paper's
    preference: slabs as large as possible, C maximal). Empty when no
    factorization works — including when the ranks cannot tile the
    projections at all."""
    if g.n_proj % n_devices:
        return []
    return [IFDKGrid(r=r, c=n_devices // r)
            for r in range(1, n_devices + 1)
            if n_devices % r == 0 and g.n_x % r == 0]


def shift_pmats_i(pmats: Array, i0: Array) -> Array:
    """Reparameterize P for a volume slab starting at voxel index i0:
    P . [i + i0, j, k, 1]^T == P' . [i, j, k, 1]^T with
    P'[:, 3] = P[:, 3] + i0 * P[:, 0]."""
    shift = pmats[..., :, 0] * i0
    return pmats.at[..., :, 3].add(shift)


def _proj_spec(mesh: Mesh) -> P:
    """Input projections are sharded over ALL mesh axes on the leading
    (projection-count) dim: each rank loads N_p/(C*R) projections (Eq. 5)."""
    return P(tuple(mesh.axis_names))


def input_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, _proj_spec(mesh))


def batched_input_sharding(mesh: Mesh) -> NamedSharding:
    """Sharding of a (B, N_p, N_v, N_u) scan batch for
    `ReconstructionPlan.build_batched`: the scan axis is replicated, each
    scan's projections are sharded exactly like `input_sharding`."""
    return NamedSharding(mesh, P(None, *_proj_spec(mesh)))


def output_spec(mesh: Mesh,
                reduce: Literal["psum", "scatter", "scatter_bf16"]) -> P:
    if reduce in SCATTER_REDUCES:
        # x sharded over model (slabs); y scattered over the intra-pod data
        # axis (the pod phase finishes with a psum, leaving y replicated
        # across pods for the sharded store). scatter_bf16 moves the
        # partial slabs at half width (core/plan.py reduce epilogue) but
        # lands the same f32 layout.
        return P(AXIS_MODEL, AXIS_DATA)
    return P(AXIS_MODEL)


def make_distributed_fdk(mesh: Mesh, g: CBCTGeometry,
                         impl: BpImpl = "factorized",
                         window: str = "ramlak",
                         reduce: Literal["psum", "scatter",
                                         "scatter_bf16"] = "scatter",
                         precision: Precision | str | None = "fp32",
                         ) -> Callable[[Array], Array]:
    """Build the jit-able distributed reconstruction: projections -> volume.

    Input : (N_p, N_v, N_u) sharded with `input_sharding(mesh)`.
    Output: (N_x, N_y, N_z); x slab-sharded over `model`, and with
            reduce="scatter" additionally y-sharded over `data` (+`pod`).

    `precision` (core/precision.py) selects the stream codec of the
    filtered projections: the encode runs *before* the column AllGather —
    the paper's dominant communication term — so bf16/fp16 halves and
    fp8_e4m3 quarters the gathered bytes per rank (+ the fp8 codec's
    4 B/projection scale sidecar); back-projection dequantizes taps and
    accumulates f32. The volume Reduce stays f32 under "psum"/"scatter";
    reduce="scatter_bf16" (core/plan.py) halves that side too.

    Deprecated-but-stable alias: a thin wrapper over
    ``ReconstructionPlan(..., schedule="fused").build()`` (core/plan.py).
    """
    from .fdk import warn_deprecated_once
    warn_deprecated_once(
        "make_distributed_fdk",
        'ReconstructionPlan(..., schedule="fused").build()')
    from .plan import ReconstructionPlan
    return ReconstructionPlan(
        geometry=g, mesh=mesh, impl=impl, window=window,
        schedule="fused", reduce=reduce, precision=precision,
    ).build()
