"""iFDK distributed decomposition (paper §4) on a JAX device mesh.

Paper mapping (DESIGN.md §4):

  * C (columns, projection groups)  -> mesh axes ("pod", "data")
  * R (rows, volume slabs)          -> mesh axis "model"

Per rank (paper Fig. 3): load + filter N_p/(C*R) projections; **AllGather**
the filtered projections along the column (our `model` axis) so the whole
column group holds its N_p/C subset; back-project the rank's volume slab;
**Reduce** partial slabs along the row (our `data`/`pod` axes).

Adaptations (documented in DESIGN.md §2/§9):
  * The paper slabs the *outermost* dimension of its k-major volume layout
    (z). Our TPU layout keeps z on the lane dimension, so we slab the
    outermost dimension of *our* layout — x. Same decomposition principle;
    keeps Theorem-1 mirror pairs on-rank and lanes contiguous.
  * Slab offsets are folded into the projection matrices (a translation in i
    is P[:, 3] += i0 * P[:, 0]), so every back-projection implementation
    (reference / factorized / Pallas / MXU) is reused unchanged.
  * The paper's rooted MPI_Reduce becomes psum (replicated slab) or
    psum_scatter (beyond-paper: output left sharded over the data axis for
    parallel store — removes the root bottleneck).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD, axis_size
from .backprojection import backproject_factorized
from .filtering import make_filter
from .fdk import fdk_scale, _get_backprojector, BpImpl
from .geometry import CBCTGeometry, projection_matrices
from .precision import Precision, resolve_precision

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class IFDKGrid:
    """The paper's 2-D rank grid: R rows (volume slabs) x C columns."""

    r: int
    c: int

    @property
    def n_ranks(self) -> int:
        return self.r * self.c


def choose_grid(g: CBCTGeometry, n_devices: int,
                hbm_bytes: int = 16 * 2**30,
                sub_vol_bytes: int = 8 * 2**30) -> IFDKGrid:
    """Paper §4.1.5: minimize R (each slab as large as fits), maximize C.

    R = sizeof(float) * Nx*Ny*Nz / N_sub_vol, rounded up to a power of two
    that divides n_devices.
    """
    vol_bytes = 4 * g.n_x * g.n_y * g.n_z
    r = 1
    while vol_bytes / r > sub_vol_bytes or (4 * g.n_u * g.n_v * 32
                                            + vol_bytes / r) > hbm_bytes:
        r *= 2
    if r > n_devices:
        raise ValueError(
            f"volume needs R={r} slabs but only {n_devices} devices available"
        )
    while n_devices % r:
        r *= 2  # keep the grid rectangular
    return IFDKGrid(r=r, c=n_devices // r)


def shift_pmats_i(pmats: Array, i0: Array) -> Array:
    """Reparameterize P for a volume slab starting at voxel index i0:
    P . [i + i0, j, k, 1]^T == P' . [i, j, k, 1]^T with
    P'[:, 3] = P[:, 3] + i0 * P[:, 0]."""
    shift = pmats[..., :, 0] * i0
    return pmats.at[..., :, 3].add(shift)


def _proj_spec(mesh: Mesh) -> P:
    """Input projections are sharded over ALL mesh axes on the leading
    (projection-count) dim: each rank loads N_p/(C*R) projections (Eq. 5)."""
    return P(tuple(mesh.axis_names))


def input_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, _proj_spec(mesh))


def output_spec(mesh: Mesh, reduce: Literal["psum", "scatter"]) -> P:
    if reduce == "scatter":
        # x sharded over model (slabs); y scattered over the intra-pod data
        # axis (the pod phase finishes with a psum, leaving y replicated
        # across pods for the sharded store).
        return P(AXIS_MODEL, AXIS_DATA)
    return P(AXIS_MODEL)


def make_distributed_fdk(mesh: Mesh, g: CBCTGeometry,
                         impl: BpImpl = "factorized",
                         window: str = "ramlak",
                         reduce: Literal["psum", "scatter"] = "scatter",
                         precision: Precision | str | None = "fp32",
                         ) -> Callable[[Array], Array]:
    """Build the jit-able distributed reconstruction: projections -> volume.

    Input : (N_p, N_v, N_u) sharded with `input_sharding(mesh)`.
    Output: (N_x, N_y, N_z); x slab-sharded over `model`, and with
            reduce="scatter" additionally y-sharded over `data` (+`pod`).

    `precision` (core/precision.py) sets the storage dtype of the filtered
    projections: filtering emits it *before* the column AllGather — the
    paper's dominant communication term — so bf16/fp16 halves the gathered
    bytes per rank; back-projection upcasts taps and accumulates f32, and
    the volume Reduce stays f32.
    """
    prec = resolve_precision(precision)
    r = axis_size(mesh, AXIS_MODEL)
    c = axis_size(mesh, AXIS_POD, AXIS_DATA)
    if g.n_proj % (r * c):
        raise ValueError(f"N_p={g.n_proj} must divide over {r * c} ranks")
    if g.n_x % r:
        raise ValueError(f"N_x={g.n_x} must divide into R={r} slabs")
    nx_slab = g.n_x // r
    dp = tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)
    filt = make_filter(g, window, out_dtype=prec.storage_dtype)
    backproject = _get_backprojector(impl)
    pmats_all = jnp.asarray(projection_matrices(g))
    scale = fdk_scale(g)

    def rank_fn(pmats_local: Array, proj_local: Array) -> Array:
        # --- filtering stage (paper: CPU/IPP; here: fused, see DESIGN §2)
        q_local = filt(proj_local)
        # --- paper Fig. 3b: AllGather within the column (model axis)
        q_col = lax.all_gather(q_local, AXIS_MODEL, axis=0, tiled=True)
        pm_col = lax.all_gather(pmats_local, AXIS_MODEL, axis=0, tiled=True)
        # --- back-project this rank's x-slab (offset folded into P)
        i0 = lax.axis_index(AXIS_MODEL) * nx_slab
        pm_slab = shift_pmats_i(pm_col, i0.astype(pm_col.dtype))
        slab = backproject(pm_slab, q_col, nx_slab, g.n_y, g.n_z)
        # --- paper Fig. 3b: Reduce within the row (data/pod axes)
        if reduce == "scatter":
            slab = lax.psum_scatter(slab, dp[-1], scatter_dimension=1,
                                    tiled=True)
            if len(dp) == 2:  # multi-pod: finish the reduction across pods
                slab = lax.psum(slab, dp[0])
        else:
            for a in dp:
                slab = lax.psum(slab, a)
        return slab * scale

    pspec = _proj_spec(mesh)
    out_sp = output_spec(mesh, reduce)

    @jax.jit
    def reconstruct(projections: Array) -> Array:
        return shard_map(
            rank_fn, mesh=mesh,
            in_specs=(pspec, pspec),
            out_specs=out_sp,
            check_vma=False,
        )(pmats_all, projections)

    return reconstruct
