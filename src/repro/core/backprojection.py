"""Back-projection: reference (paper Alg. 2) and factorized (paper Alg. 4).

Both are pure-jnp and serve as oracles for the Pallas kernel
(`repro.kernels.backproject`). The factorized variant implements the paper's
contribution:

  * Theorem-2/3: per voxel column (i, j) the detector column u and the depth
    z (hence the weight w = 1/z^2) are constant -> computed once per column
    (2 inner products) instead of per voxel.
  * v is *affine* in k (v_k = (y0 + k dy) / z) -> 1 inner product per voxel
    reduced to one FMA.
  * Theorem-1 (Z-symmetry): only k in [0, Nz/2) is computed; the mirrored
    half reuses u, w and the reflected v~ = (Nv - 1) - v.
  * Layout: volume is (Nx, Ny, Nz) with z innermost ("k-major" in the paper's
    sense: the streamed dimension is contiguous -> TPU lanes run along z);
    projections are transposed to Q^T = (N_u, N_v) so the inner gather walks
    a contiguous detector row (the paper's \tilde{Q}).

Cost of computing the projections: Alg. 2 does 3 inner products (12 MACs)
per (i,j,k); Alg. 4 does 2 inner products per (i,j) plus 1 FMA + 1 division
amortized-per-column, i.e. a factor ~1/6 on coordinate arithmetic for the
half-grid it visits — matching the paper's claim.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Bilinear interpolation (paper Alg. 3) with zero-outside boundary handling
# ---------------------------------------------------------------------------

def bilinear_gather(img: Array, rows: Array, cols: Array) -> Array:
    """Sample img[rows, cols] with bilinear sub-pixel interpolation.

    Out-of-bounds neighbours contribute zero (matches a zero-padded detector;
    the GPU texture unit's border mode in the paper's Bp-L1 variants).

    `img` may be stored in a reduced precision (bf16/fp16 — the precision
    policy's projection stream); each gathered tap is upcast to f32 before
    the weighted sum, so interpolation and accumulation are always f32
    (the paper's fp16-texture-fetch / fp32-blend split).
    """
    nr, nc = img.shape
    r0 = jnp.floor(rows)
    c0 = jnp.floor(cols)
    dr = rows - r0
    dc = cols - c0
    r0i = r0.astype(jnp.int32)
    c0i = c0.astype(jnp.int32)

    def tap(ri, ci, wgt):
        valid = (ri >= 0) & (ri < nr) & (ci >= 0) & (ci < nc)
        ric = jnp.clip(ri, 0, nr - 1)
        cic = jnp.clip(ci, 0, nc - 1)
        return jnp.where(valid, img[ric, cic].astype(jnp.float32) * wgt, 0.0)

    return (
        tap(r0i, c0i, (1 - dr) * (1 - dc))
        + tap(r0i, c0i + 1, (1 - dr) * dc)
        + tap(r0i + 1, c0i, dr * (1 - dc))
        + tap(r0i + 1, c0i + 1, dr * dc)
    )


# ---------------------------------------------------------------------------
# Reference: paper Algorithm 2 (as implemented by RTK / RabbitCT)
# ---------------------------------------------------------------------------

def _stream_scales(proj: Array, scales: Array | None) -> Array:
    """Per-projection decode factors: the codec sidecar, or exact ones.

    Every back-projector folds the stream codec's per-projection scale into
    the accumulation weight (``w * s``) — by linearity of the bilinear
    gather this equals decoding the projection up front, without ever
    materializing the f32 stream. ``scales=None`` (scale-free codecs)
    multiplies by exact 1.0f, which is bit-transparent.
    """
    if scales is None:
        return jnp.ones((proj.shape[0],), jnp.float32)
    return scales.astype(jnp.float32)


@partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def backproject_reference(pmats: Array, proj: Array,
                          nx: int, ny: int, nz: int,
                          scales: Array | None = None,
                          init: Array | None = None) -> Array:
    """Alg. 2: for each projection s, 3 inner products per voxel.

    pmats: (N_p, 3, 4) float32; proj: (N_p, N_v, N_u) filtered projections
    in any wire dtype (fp32/bf16/fp16/fp8 — the stream codec's output);
    `scales` is the codec's per-projection sidecar (None = unscaled).
    `init` (default zeros) seeds the accumulator, continuing the per-voxel
    addition sequence of an earlier call — the incremental schedule folds
    projection deltas through it so a split scan stays bit-identical to
    one fused scan over the concatenated projections.
    Returns volume (nx, ny, nz), *unscaled* (see fdk.fdk_scale).
    """
    i = jnp.arange(nx, dtype=jnp.float32)[:, None, None]
    j = jnp.arange(ny, dtype=jnp.float32)[None, :, None]
    k = jnp.arange(nz, dtype=jnp.float32)[None, None, :]

    def body(acc, sp):
        p, q, s = sp
        x = p[0, 0] * i + p[0, 1] * j + p[0, 2] * k + p[0, 3]
        y = p[1, 0] * i + p[1, 1] * j + p[1, 2] * k + p[1, 3]
        z = p[2, 0] * i + p[2, 1] * j + p[2, 2] * k + p[2, 3]
        f = 1.0 / z
        u = x * f
        v = y * f
        w0 = f * f
        # Pin the coordinate chain: without the barrier XLA may contract
        # these FMAs differently when the surrounding program changes (e.g.
        # under vmap in build_batched), breaking the batched == unbatched
        # bit-exactness contract. Only P-derived (batch-invariant) values go
        # through it — optimization_barrier has no vmap batching rule.
        u, v, w0 = jax.lax.optimization_barrier((u, v, w0))
        w = w0 * s                      # codec decode folded into the weight
        acc = acc + w * bilinear_gather(q, v, u)  # rows = v, cols = u
        return acc, None

    if init is None:
        init = jnp.zeros((nx, ny, nz), jnp.float32)
    vol, _ = jax.lax.scan(body, init.astype(jnp.float32),
                          (pmats, proj, _stream_scales(proj, scales)))
    return vol


# ---------------------------------------------------------------------------
# Factorized: paper Algorithm 4
# ---------------------------------------------------------------------------

def column_terms(p: Array, nx: int, ny: int) -> Tuple[Array, Array, Array, Array, Array]:
    """Per-(i,j)-column invariants (Alg. 4 lines 6-10).

    Returns (u, w, y0, dy, f): u and w constant along k (T2/T3); v_k is the
    affine ramp (y0 + k*dy) * f.
    """
    i = jnp.arange(nx, dtype=jnp.float32)[:, None]
    j = jnp.arange(ny, dtype=jnp.float32)[None, :]
    x0 = p[0, 0] * i + p[0, 1] * j + p[0, 3]
    y0 = p[1, 0] * i + p[1, 1] * j + p[1, 3]
    z = p[2, 0] * i + p[2, 1] * j + p[2, 3]
    f = 1.0 / z
    return x0 * f, f * f, y0, p[1, 2], f


@partial(jax.jit, static_argnames=("nx", "ny", "nz"))
def backproject_factorized(pmats: Array, proj: Array,
                           nx: int, ny: int, nz: int,
                           scales: Array | None = None,
                           init: Array | None = None) -> Array:
    """Alg. 4: factorized coordinates + Z-symmetry + transposed layout.

    Matches backproject_reference to float32 reassociation tolerance whenever
    the projection matrices satisfy Theorems 2/3 (structural zeros,
    see geometry.assert_factorizable).

    The accumulator lives in the DUAL-SLAB layout for the whole scan — the
    mirror half is stored z-reversed, so no per-projection flip/concat
    touches the volume (measured 1.9x on CPU, EXPERIMENTS.md §Perf); a
    single relayout at the end restores (nx, ny, nz).

    `init` (default zeros) seeds the accumulator in the CANONICAL
    (nx, ny, nz) layout; it is split into the dual slabs so the per-voxel
    addition sequence continues exactly where an earlier call stopped —
    the incremental schedule's bit-exact fold.
    """
    if nz % 2 != 0:
        raise ValueError("factorized back-projection requires even N_z (T1 pairing)")
    nzh = nz // 2
    n_v = proj.shape[-2]
    k = jnp.arange(nzh, dtype=jnp.float32)

    def body(acc, sp):
        acc_f, acc_b = acc
        p, q, s = sp
        qt = q.T  # \tilde{Q}: (N_u, N_v), v contiguous
        u, w, y0, dy, f = column_terms(p, nx, ny)
        v = (y0[..., None] + dy * k) * f[..., None]        # (nx, ny, nzh)
        ub = jnp.broadcast_to(u[..., None], v.shape)
        vm = (n_v - 1.0) - v                                # Theorem-1 mirror
        # Pin the coordinate chain so batched (vmap) and unbatched
        # compilations contract its FMAs identically — the build_batched
        # bit-exactness contract. Only P-derived (batch-invariant) values go
        # through the barrier; the per-projection scale `s` may carry a vmap
        # batch dim and optimization_barrier has no batching rule.
        ub, v, vm, w = jax.lax.optimization_barrier((ub, v, vm, w))
        w = w * s                       # codec decode folded into the weight
        front = w[..., None] * bilinear_gather(qt, ub, v)   # rows=u, cols=v
        back = w[..., None] * bilinear_gather(qt, ub, vm)
        return (acc_f + front, acc_b + back), None

    if init is None:
        init_f = init_b = jnp.zeros((nx, ny, nzh), jnp.float32)
    else:
        init = init.astype(jnp.float32)
        init_f = init[..., :nzh]
        init_b = jnp.flip(init[..., nzh:], axis=-1)
    (acc_f, acc_b), _ = jax.lax.scan(
        body, (init_f, init_b), (pmats, proj, _stream_scales(proj, scales)))
    # single relayout: back half is voxel nz-1-k at index k
    return jnp.concatenate([acc_f, jnp.flip(acc_b, axis=-1)], axis=-1)


# ---------------------------------------------------------------------------
# Dual-slab layout helpers (used by the Pallas kernel and the distributed
# decomposition): volume (nx, ny, nz) <-> (nx, ny, 2, nz/2) where slab 1 is
# stored z-reversed so that a symmetric pair (k, nz-1-k) shares an index.
# ---------------------------------------------------------------------------

def to_dual_slab(vol: Array) -> Array:
    nz = vol.shape[-1]
    front = vol[..., : nz // 2]
    back = jnp.flip(vol[..., nz // 2:], axis=-1)
    return jnp.stack([front, back], axis=-2)


def from_dual_slab(dual: Array) -> Array:
    front = dual[..., 0, :]
    back = jnp.flip(dual[..., 1, :], axis=-1)
    return jnp.concatenate([front, back], axis=-1)
