"""Filtering stage (paper Alg. 1): cosine weighting + 1-D ramp convolution.

Q_i(j, .) = (E_i * F_cos)(j, .)  (x)  F_ramp        for every detector row j

The ramp filter is applied per detector row via real FFT (Convolution Theorem,
§2.2.3), with the discrete band-limited ramp kernel of Kak & Slaney (ch. 3,
eq. 61) sampled at the virtual-detector pitch, optionally apodized
(shepp-logan / hann / hamming windows — the paper notes the window shape
affects image quality but not compute intensity).

The paper runs this stage on CPUs (IPP) to overlap with GPU back-projection;
on TPU it is a (cheap) jnp program fused into the pipelined reconstruction —
see DESIGN.md §2 for the rationale.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import CBCTGeometry

Array = jax.Array

_WINDOWS = ("ramlak", "shepp-logan", "hann", "hamming")


def cosine_weights(g: CBCTGeometry) -> np.ndarray:
    """F_cos: the FDK cosine (Feldkamp) weighting table, shape (N_v, N_u).

    w(u, v) = d / sqrt(d^2 + p^2 + zeta^2) with (p, zeta) the virtual-detector
    (isocenter-rescaled) physical coordinates of the pixel.
    """
    cu = (g.n_u - 1) / 2.0
    cv = (g.n_v - 1) / 2.0
    p = (np.arange(g.n_u, dtype=np.float64) - cu) * g.tau_u
    zeta = (np.arange(g.n_v, dtype=np.float64) - cv) * g.tau_v
    pp, zz = np.meshgrid(p, zeta, indexing="xy")
    return (g.d / np.sqrt(g.d * g.d + pp * pp + zz * zz)).astype(np.float32)


def ramp_kernel(n: int, tau: float) -> np.ndarray:
    """Band-limited spatial-domain ramp h[n], length n (n even, circular).

    h[0] = 1/(4 tau^2); h[m] = -1/(m pi tau)^2 for odd m; 0 for even m != 0.
    Negative lags are wrapped (h[n-m] = h[m]).
    """
    h = np.zeros(n, dtype=np.float64)
    h[0] = 1.0 / (4.0 * tau * tau)
    m = np.arange(1, n // 2 + 1)
    odd = m[m % 2 == 1]
    val = -1.0 / (odd * np.pi * tau) ** 2
    h[odd] = val
    h[n - odd] = val
    return h


def ramp_frequency_response(g: CBCTGeometry, window: str = "ramlak",
                            pad: int | None = None) -> np.ndarray:
    """rfft of the (apodized) ramp kernel at padded length."""
    if window not in _WINDOWS:
        raise ValueError(f"unknown window {window!r}; choose from {_WINDOWS}")
    n = pad or fft_length(g.n_u)
    h = ramp_kernel(n, g.tau_u)
    hf = np.fft.rfft(h)
    freq = np.fft.rfftfreq(n)  # cycles/sample in [0, 0.5]
    if window == "shepp-logan":
        x = np.pi * freq
        w = np.where(freq > 0, np.sin(np.clip(x, 1e-12, None)) / np.clip(x, 1e-12, None), 1.0)
    elif window == "hann":
        w = 0.5 * (1.0 + np.cos(2.0 * np.pi * freq))
    elif window == "hamming":
        w = 0.54 + 0.46 * np.cos(2.0 * np.pi * freq)
    else:
        w = np.ones_like(freq)
    return (hf * w).astype(np.complex64)


def fft_length(n_u: int) -> int:
    """Next power of two >= 2*N_u (linear, not circular, convolution)."""
    n = 1
    while n < 2 * n_u:
        n *= 2
    return n


@partial(jax.jit, static_argnames=("pad", "out_dtype"))
def _filter_batch(proj: Array, fcos: Array, hf: Array, pad: int, tau_u: float,
                  out_dtype=None) -> Array:
    """Alg. 1 over a batch: proj (B, N_v, N_u) -> filtered (B, N_v, N_u)."""
    n_u = proj.shape[-1]
    e = proj.astype(jnp.float32) * fcos[None]
    ef = jnp.fft.rfft(e, n=pad, axis=-1)
    q = jnp.fft.irfft(ef * hf[None, None, :], n=pad, axis=-1)[..., :n_u]
    # Discrete convolution sum approximates the integral: multiply by the
    # sample pitch tau (Kak & Slaney eq. 3.62).
    return (q * tau_u).astype(out_dtype or proj.dtype)


def make_filter(g: CBCTGeometry, window: str = "ramlak", out_dtype=None):
    """Returns filter_fn(proj: (B, N_v, N_u)) -> (B, N_v, N_u), plus tables.

    `out_dtype` is the *storage* dtype of the emitted filtered projections
    (the precision policy's half-width stream, see core/precision.py); the
    FFT convolution itself always runs in f32. None keeps the input dtype.
    """
    pad = fft_length(g.n_u)
    fcos = jnp.asarray(cosine_weights(g))
    hf = jnp.asarray(ramp_frequency_response(g, window, pad))
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else None

    def filter_fn(proj: Array) -> Array:
        return _filter_batch(proj, fcos, hf, pad, g.tau_u, out_dtype)

    return filter_fn


def filter_projections(g: CBCTGeometry, proj: Array,
                       window: str = "ramlak", out_dtype=None) -> Array:
    """One-shot filtering of all projections (N_p, N_v, N_u)."""
    return make_filter(g, window, out_dtype)(proj)
