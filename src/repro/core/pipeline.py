"""Software-pipelined distributed FDK (paper §4.1.4, Fig. 4).

The paper overlaps load/filter (CPU thread), AllGather (main thread) and
back-projection (GPU thread) with circular buffers. The XLA-native
equivalent is a `lax.scan` over projection micro-batches with a
double-buffered carry: step s issues the AllGather for batch s while the
back-projection of batch s-1 (already gathered) runs — the two are
data-independent inside one scan step, so XLA's async collectives hide the
communication behind the compute, exactly the paper's streaming benefit
(their delta > 1 in Table 5).

Over-decomposition of the projection axis (n_steps micro-batches per rank)
is also the straggler-mitigation hook: the host loop can re-slice the
batch->step mapping between scans without moving any state (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Callable, Literal

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD, axis_size
from .distributed import _proj_spec, output_spec, shift_pmats_i
from .fdk import fdk_scale, _get_backprojector, BpImpl
from .filtering import make_filter
from .geometry import CBCTGeometry, projection_matrices
from .precision import Precision, resolve_precision

Array = jax.Array


def shift_pmats_j(pmats: Array, j0) -> Array:
    """Reparameterize P for a y-chunk starting at voxel index j0 (same trick
    as distributed.shift_pmats_i, on the j column)."""
    shift = pmats[..., :, 1] * j0
    return pmats.at[..., :, 3].add(shift)


def make_chunked_fdk(mesh: Mesh, g: CBCTGeometry,
                     n_steps: int = 2, y_chunks: int = 16,
                     impl: BpImpl = "factorized",
                     window: str = "ramlak",
                     precision: Precision | str | None = "fp32"):
    """Beyond-paper (EXPERIMENTS.md §Perf cell C): y-chunked back-projection
    with PER-CHUNK psum_scatter accumulation.

    The plain pipeline back-projects the full (nx/R, ny, nz) slab before the
    row reduction — a 17 GB f32 transient for the 4K problem plus the BP
    intermediates (~69 GB/device peak, 4x over v5e HBM). Here each projection
    batch back-projects one y-chunk at a time and immediately reduce-scatters
    it over the data axis, so the live state is one chunk's intermediates
    plus the 1/C-scattered accumulator (fits in a few GB). The reduction
    moves from one giant end-of-step psum to y_chunks small psum_scatters
    that overlap with the next chunk's compute — the paper's Fig. 4
    streaming idea applied to the *output* side, which the paper itself
    left as future work ("overlapping after the back-projection").

    Output layout: (nx, y_chunks, ny/y_chunks, nz) with x sharded over
    `model` and dim 2 scattered over `data`; reshape(nx, ny, nz) restores
    the canonical volume (globally contiguous, see tests).
    """
    r = axis_size(mesh, AXIS_MODEL)
    c = axis_size(mesh, AXIS_POD, AXIS_DATA)
    dp_in = axis_size(mesh, AXIS_DATA)
    n_ranks = r * c
    np_local = g.n_proj // n_ranks
    yc = g.n_y // y_chunks
    if g.n_proj % n_ranks or np_local % n_steps or g.n_y % y_chunks \
            or yc % dp_in:
        raise ValueError("shape does not tile over the mesh/chunks")
    nb = np_local // n_steps
    nx_slab = g.n_x // r
    prec = resolve_precision(precision)
    filt = make_filter(g, window, out_dtype=prec.storage_dtype)
    backproject = _get_backprojector(impl)
    pmats_all = jnp.asarray(projection_matrices(g))
    scale = fdk_scale(g)

    def gather_batch(pm_b, raw_b):
        q = filt(raw_b)
        return (lax.all_gather(pm_b, AXIS_MODEL, axis=0, tiled=True),
                lax.all_gather(q, AXIS_MODEL, axis=0, tiled=True))

    def rank_fn(pmats_local: Array, proj_local: Array) -> Array:
        i0 = lax.axis_index(AXIS_MODEL) * nx_slab
        pm_steps = pmats_local.reshape(n_steps, nb, 3, 4)
        raw_steps = proj_local.reshape(n_steps, nb, g.n_v, g.n_u)
        buf = gather_batch(pm_steps[0], raw_steps[0])

        def bp_chunks(acc, pm_col, q_col):
            pm_slab = shift_pmats_i(pm_col, i0.astype(pm_col.dtype))

            def one_chunk(ci, a):
                pm_c = shift_pmats_j(pm_slab, (ci * yc).astype(pm_slab.dtype))
                part = backproject(pm_c, q_col, nx_slab, yc, g.n_z)
                part = lax.psum_scatter(part, AXIS_DATA,
                                        scatter_dimension=1, tiled=True)
                return lax.dynamic_update_index_in_dim(
                    a, a[:, ci] + part, ci, axis=1
                )

            return lax.fori_loop(0, y_chunks, one_chunk, acc)

        def step(carry, xs):
            acc, prev = carry
            nxt = gather_batch(*xs)                # comm for batch s
            acc = bp_chunks(acc, *prev)            # compute for batch s-1
            return (acc, nxt), None

        init = jnp.zeros((nx_slab, y_chunks, yc // dp_in, g.n_z), jnp.float32)
        (acc, last), _ = lax.scan(step, (init, buf),
                                  (pm_steps[1:], raw_steps[1:]))
        acc = bp_chunks(acc, *last)                # epilogue
        if AXIS_POD in mesh.axis_names:
            acc = lax.psum(acc, AXIS_POD)
        return acc * scale

    pspec = _proj_spec(mesh)
    out_sp = P(AXIS_MODEL, None, AXIS_DATA, None)

    @jax.jit
    def reconstruct(projections: Array) -> Array:
        return shard_map(
            rank_fn, mesh=mesh,
            in_specs=(pspec, pspec),
            out_specs=out_sp,
            check_vma=False,
        )(pmats_all, projections)

    return reconstruct


def make_pipelined_fdk(mesh: Mesh, g: CBCTGeometry,
                       n_steps: int = 4,
                       impl: BpImpl = "factorized",
                       window: str = "ramlak",
                       reduce: Literal["psum", "scatter"] = "scatter",
                       precision: Precision | str | None = "fp32",
                       ) -> Callable[[Array], Array]:
    """Pipelined reconstruction; same interface as make_distributed_fdk.

    With a low-precision `precision` policy the per-step AllGather moves
    half-width bytes *and* overlaps with the previous batch's f32-accumulate
    back-projection — the two paper speedups compose.
    """
    r = axis_size(mesh, AXIS_MODEL)
    c = axis_size(mesh, AXIS_POD, AXIS_DATA)
    n_ranks = r * c
    np_local = g.n_proj // n_ranks
    if g.n_proj % n_ranks or np_local % n_steps:
        raise ValueError(
            f"N_p={g.n_proj} must divide over {n_ranks} ranks x {n_steps} steps"
        )
    nb = np_local // n_steps          # local batch per pipeline step
    nx_slab = g.n_x // r
    dp = tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)
    prec = resolve_precision(precision)
    filt = make_filter(g, window, out_dtype=prec.storage_dtype)
    backproject = _get_backprojector(impl)
    pmats_all = jnp.asarray(projection_matrices(g))
    scale = fdk_scale(g)

    def gather_batch(pm_b, raw_b):
        q = filt(raw_b)
        q_col = lax.all_gather(q, AXIS_MODEL, axis=0, tiled=True)
        pm_col = lax.all_gather(pm_b, AXIS_MODEL, axis=0, tiled=True)
        return pm_col, q_col

    def rank_fn(pmats_local: Array, proj_local: Array) -> Array:
        i0 = lax.axis_index(AXIS_MODEL) * nx_slab
        pm_steps = pmats_local.reshape(n_steps, nb, 3, 4)
        raw_steps = proj_local.reshape(n_steps, nb, g.n_v, g.n_u)

        # Prologue: gather batch 0.
        buf = gather_batch(pm_steps[0], raw_steps[0])

        def step(carry, xs):
            acc, (pm_prev, q_prev) = carry
            pm_next, raw_next = xs
            # Comm for batch s (independent of the BP below -> overlapped).
            nxt = gather_batch(pm_next, raw_next)
            # Compute for batch s-1.
            pm_slab = shift_pmats_i(pm_prev, i0.astype(pm_prev.dtype))
            acc = acc + backproject(pm_slab, q_prev, nx_slab, g.n_y, g.n_z)
            return (acc, nxt), None

        init = (jnp.zeros((nx_slab, g.n_y, g.n_z), jnp.float32), buf)
        (acc, (pm_last, q_last)), _ = lax.scan(
            step, init, (pm_steps[1:], raw_steps[1:])
        )
        # Epilogue: BP of the final gathered batch.
        pm_slab = shift_pmats_i(pm_last, i0.astype(pm_last.dtype))
        acc = acc + backproject(pm_slab, q_last, nx_slab, g.n_y, g.n_z)

        if reduce == "scatter":
            acc = lax.psum_scatter(acc, AXIS_DATA, scatter_dimension=1,
                                   tiled=True)
            if AXIS_POD in mesh.axis_names:
                acc = lax.psum(acc, AXIS_POD)
        else:
            for a in dp:
                acc = lax.psum(acc, a)
        return acc * scale

    pspec = _proj_spec(mesh)
    out_sp = output_spec(mesh, reduce)

    @jax.jit
    def reconstruct(projections: Array) -> Array:
        return shard_map(
            rank_fn, mesh=mesh,
            in_specs=(pspec, pspec),
            out_specs=out_sp,
            check_vma=False,
        )(pmats_all, projections)

    return reconstruct
