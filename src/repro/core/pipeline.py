"""Software-pipelined distributed FDK (paper §4.1.4, Fig. 4) — legacy API.

The paper overlaps load/filter (CPU thread), AllGather (main thread) and
back-projection (GPU thread) with circular buffers. The XLA-native
equivalent is a `lax.scan` over projection micro-batches with a
double-buffered carry: step s issues the AllGather for batch s while the
back-projection of batch s-1 (already gathered) runs — the two are
data-independent inside one scan step, so XLA's async collectives hide the
communication behind the compute, exactly the paper's streaming benefit
(their delta > 1 in Table 5).

Both builders here are deprecated-but-stable thin wrappers over the
plan/engine layer (core/plan.py): the pipelined and chunked schedules are
plan points of the same staged engine, so every capability (reduce modes,
precision policies, tuned kernel blocks, single-device execution) is
shared rather than forked. Construct a `ReconstructionPlan` directly for
the full cross-product.
"""
from __future__ import annotations

from typing import Callable, Literal

import jax
from jax.sharding import Mesh

from .fdk import BpImpl, warn_deprecated_once
from .geometry import CBCTGeometry
from .plan import ReconstructionPlan, shift_pmats_j  # noqa: F401 (re-export)
from .precision import Precision

Array = jax.Array


def make_chunked_fdk(mesh: Mesh, g: CBCTGeometry,
                     n_steps: int = 2, y_chunks: int = 16,
                     impl: BpImpl = "factorized",
                     window: str = "ramlak",
                     precision: Precision | str | None = "fp32"):
    """Beyond-paper (EXPERIMENTS.md §Perf cell C): y-chunked back-projection
    with PER-CHUNK psum_scatter accumulation.

    The plain pipeline back-projects the full (nx/R, ny, nz) slab before the
    row reduction — a 17 GB f32 transient for the 4K problem plus the BP
    intermediates (~69 GB/device peak, 4x over v5e HBM). Here each projection
    batch back-projects one y-chunk at a time and immediately reduce-scatters
    it over the data axis, so the live state is one chunk's intermediates
    plus the 1/C-scattered accumulator (fits in a few GB). The reduction
    moves from one giant end-of-step psum to y_chunks small psum_scatters
    that overlap with the next chunk's compute — the paper's Fig. 4
    streaming idea applied to the *output* side, which the paper itself
    left as future work ("overlapping after the back-projection").

    Output layout: (nx, y_chunks, ny/y_chunks, nz) with x sharded over
    `model` and dim 2 scattered over `data`; reshape(nx, ny, nz) restores
    the canonical volume (globally contiguous, see tests).

    Deprecated-but-stable alias for
    ``ReconstructionPlan(..., schedule="chunked", reduce="scatter")``; the
    plan layer also offers chunked+psum (replicated slab), which this
    wrapper predates.
    """
    warn_deprecated_once(
        "make_chunked_fdk",
        'ReconstructionPlan(..., schedule="chunked", reduce="scatter")'
        '.build()')
    return ReconstructionPlan(
        geometry=g, mesh=mesh, impl=impl, window=window,
        schedule="chunked", n_steps=n_steps, y_chunks=y_chunks,
        reduce="scatter", precision=precision,
    ).build()


def make_pipelined_fdk(mesh: Mesh, g: CBCTGeometry,
                       n_steps: int = 4,
                       impl: BpImpl = "factorized",
                       window: str = "ramlak",
                       reduce: Literal["psum", "scatter",
                                       "scatter_bf16"] = "scatter",
                       precision: Precision | str | None = "fp32",
                       ) -> Callable[[Array], Array]:
    """Pipelined reconstruction; same interface as make_distributed_fdk.

    With a low-precision stream codec the per-step AllGather moves half-
    (bf16/fp16) or quarter-width (fp8_e4m3 + scale sidecar) bytes *and*
    overlaps with the previous batch's f32-accumulate back-projection — the
    two paper speedups compose.

    Deprecated-but-stable alias for
    ``ReconstructionPlan(..., schedule="pipelined").build()``.
    """
    warn_deprecated_once(
        "make_pipelined_fdk",
        'ReconstructionPlan(..., schedule="pipelined").build()')
    return ReconstructionPlan(
        geometry=g, mesh=mesh, impl=impl, window=window,
        schedule="pipelined", n_steps=n_steps, reduce=reduce,
        precision=precision,
    ).build()
