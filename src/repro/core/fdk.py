"""Single-device FDK entry point + shared helpers (scale, GUPS metric).

`reconstruct` is the historical oracle API, now a thin wrapper over the
plan/engine layer (core/plan.py) with `mesh=None, schedule="fused"`; the
distributed builders in core/distributed.py and core/pipeline.py are the
same engine at other plan points.
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, Literal

import jax

from . import backprojection as bp
from .geometry import CBCTGeometry
from .precision import Precision

Array = jax.Array

BpImpl = Literal["reference", "factorized", "kernel"]

# Legacy entry points warn ONCE per process (per entry point) — enough to
# steer callers at the plan layer without spamming per-call loops.
_DEPRECATION_FIRED: set = set()


def warn_deprecated_once(name: str, alternative: str) -> None:
    if name in _DEPRECATION_FIRED:
        return
    _DEPRECATION_FIRED.add(name)
    warnings.warn(
        f"{name} is deprecated; construct a ReconstructionPlan "
        f"(core/plan.py) instead — equivalent: {alternative}",
        DeprecationWarning, stacklevel=3)


def fdk_scale(g: CBCTGeometry) -> float:
    """Global FDK calibration: f = (1/2) d^2 * dbeta * sum_s w_s q_s.

    Alg. 2/4 accumulate with w = 1/z^2; the d^2, the angular step and the
    full-scan 1/2 (every ray is measured twice over a 2*pi orbit) are
    constants applied once at the end (kept out of the inner loop, as any
    production implementation does).
    """
    return float(0.5 * g.d * g.d * g.theta)


def _get_backprojector(impl: BpImpl) -> Callable:
    if impl == "reference":
        return bp.backproject_reference
    if impl == "factorized":
        return bp.backproject_factorized
    if impl == "kernel":
        from repro.kernels.backproject.ops import backproject_pallas
        return backproject_pallas
    raise ValueError(f"unknown back-projection impl: {impl!r}")


def reconstruct(g: CBCTGeometry, projections: Array,
                impl: BpImpl = "factorized",
                window: str = "ramlak",
                precision: Precision | str | None = "fp32") -> Array:
    """Full FDK: (N_p, N_v, N_u) projections -> (N_x, N_y, N_z) volume.

    Deprecated-but-stable alias: a thin wrapper over the plan/engine layer
    (`core/plan.py`) — equivalent to
    ``ReconstructionPlan(geometry=g, impl=impl, window=window,
    precision=precision).build()(projections)``. New code should hold the
    plan (and its built function) directly; built engines are cached per
    plan, so calling this repeatedly does not re-trace.

    `precision` selects the *storage* dtype of the filtered-projection
    stream (core/precision.py): filtering emits it, back-projection gathers
    it and accumulates f32. "fp32" (default) preserves the historical exact
    behaviour; None picks the backend default (bf16 on CPU/TPU).
    """
    warn_deprecated_once(
        "fdk.reconstruct",
        "ReconstructionPlan(geometry=g, ...).build()(projections)")
    from .plan import ReconstructionPlan
    plan = ReconstructionPlan(geometry=g, impl=impl, window=window,
                              precision=precision)
    return plan.build()(projections)


def gups(g: CBCTGeometry, seconds: float) -> float:
    """The paper's metric: giga voxel-updates per second (§2.3)."""
    updates = g.n_x * g.n_y * g.n_z * float(g.n_proj)
    return updates / (seconds * 2**30)


def timed_reconstruct(g: CBCTGeometry, projections: Array,
                      impl: BpImpl = "factorized", iters: int = 3,
                      precision: Precision | str | None = "fp32"):
    """Benchmark helper returning (volume, seconds_per_run, gups)."""
    from .plan import ReconstructionPlan
    fn = ReconstructionPlan(geometry=g, impl=impl,
                            precision=precision).build()
    vol = fn(projections)  # warm-up
    jax.block_until_ready(vol)
    t0 = time.perf_counter()
    for _ in range(iters):
        vol = fn(projections)
        jax.block_until_ready(vol)
    dt = (time.perf_counter() - t0) / iters
    return vol, dt, gups(g, dt)
