"""The service scheduler: queue -> admission -> buckets -> batched engine.

Bucketing rules (DESIGN.md §Serving):

  * Only same-FAMILY scans share a bucket (requests.ScanFamily — identical
    geometry, mesh and plan pins; the batched engine vmaps over scans, so
    every lane must share one trace and one plan).
  * Bucket sizes are powers of two, capped by `max_batch` AND by the
    memory budget: the largest b with b * footprint(plan) <= hbm_bytes
    (planner/feasibility prices one scan's per-rank footprint; the batched
    engine replicates it per lane). Power-of-two buckets bound the number
    of distinct compiled batch engines at log2(max_batch) per family.
  * A partial bucket is padded with zero scans; padding lanes are dropped
    from the output. The batched engine is bit-exact per lane
    (core/plan.py build_batched), so padding cannot perturb real scans.

Scheduling (the cross-family order buckets execute in, `policy=`):

  fifo            round-robin across families in arrival order — each round
                  serves at most one bucket per family, so a chatty family
                  cannot starve a quiet one (the fairness baseline).
  largest_bucket  round-robin rounds ordered by bucket size descending —
                  maximize lane occupancy first while keeping the
                  one-bucket-per-family-per-round fairness bound.
  deadline        earliest-deadline-first across ALL buckets (a bucket's
                  deadline is its most urgent ticket's); deadline-less
                  buckets sort last in arrival order. Urgency deliberately
                  overrides fairness — an SLO is a promise.

Serving modes:

  drain()               synchronous, on the caller's thread (the original
                        PR-7 flow; still the unit of one scheduling pass).
  serve()/shutdown()    the background drain loop: a dedicated thread waits
                        on a condition variable, wakes on submit(), and
                        runs drain passes whenever work is queued — callers
                        never block, they `ticket.wait(timeout=)`. One
                        persistent SourcePrefetcher spans all passes
                        (extend() per pass — no thread churn), and a pass
                        that raises is counted and survived: the loop must
                        keep serving (graceful degradation).

I/O overlap: all admitted scans' projection loads run on a prefetch thread
(double-buffered — scan k+1 loads while scan k computes) and finished
volumes are written behind (AsyncWriteback) while the next bucket runs.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import CBCTGeometry
from repro.io.streams import AsyncWriteback, SourcePrefetcher
from repro.obs.metrics import DEFAULT_TIME_BUCKETS, MetricsRegistry
from repro.obs.trace import get_tracer

from .plan_cache import PlanCache
from .requests import (
    AdmissionError, QueueFullError, ScanFamily, ScanTicket, TicketState,
    _QueuedScan,
)

#: Cross-family bucket execution orders `ReconstructionService(policy=)`
#: accepts — see the module docstring for their semantics.
SCHEDULING_POLICIES = ("fifo", "largest_bucket", "deadline")


def _next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class _Bucket(NamedTuple):
    """One schedulable unit: same-family scans sharing a batched dispatch.
    `seq` is the bucket's earliest admission sequence number — the
    arrival-order key every policy tie-breaks on."""

    family: ScanFamily
    scans: List[_QueuedScan]
    bsz: int
    seq: int

    def deadline(self) -> float:
        """The bucket's most urgent ticket deadline (+inf when no lane
        carries an SLO) — the EDF sort key."""
        ds = [s.ticket.deadline for s in self.scans
              if s.ticket.deadline is not None]
        return min(ds) if ds else math.inf


class ReconstructionService:
    """Multi-scan reconstruction front end over one device fleet (mesh).

    mesh         : the fixed fleet every scan is served on (None = single
                   device). Part of every scan family.
    spec         : plan spec families resolve through ("auto" = planner
                   search, once per family — see PlanCache).
    max_batch    : bucket-size ceiling (power of two recommended).
    max_queue    : admission bound on queued scans (QueueFullError beyond).
    hbm_bytes    : per-device memory budget for admission + bucket sizing.
    policy       : cross-family bucket scheduling order (SCHEDULING_POLICIES).
    """

    def __init__(self, mesh=None, *, spec: str = "auto", max_batch: int = 8,
                 max_queue: int = 64, hbm_bytes: Optional[int] = None,
                 vmem_budget: Optional[int] = None,
                 plan_cache_capacity: int = 32, prefetch_depth: int = 2,
                 writeback_depth: int = 2, policy: str = "fifo"):
        from repro.planner import DEFAULT_HBM_BYTES
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} must be >= 1")
        if policy not in SCHEDULING_POLICIES:
            raise ValueError(
                f"policy={policy!r} is not one of {SCHEDULING_POLICIES}")
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.hbm_bytes = DEFAULT_HBM_BYTES if hbm_bytes is None else hbm_bytes
        self.vmem_budget = vmem_budget
        self.prefetch_depth = prefetch_depth
        self.policy = policy
        self.plan_cache = PlanCache(capacity=plan_cache_capacity, spec=spec)
        self._writeback = AsyncWriteback(max_pending=writeback_depth)
        self._queue: List[_QueuedScan] = []
        self._lock = threading.Lock()
        # Background-loop wakeup: submit()/shutdown() notify, the serve
        # thread waits. Shares self._lock so queue state and wakeup are
        # one atomic picture.
        self._cv = threading.Condition(self._lock)
        self._serve_thread: Optional[threading.Thread] = None
        self._shutdown_requested = False
        self._seq = 0
        # Per-INSTANCE metrics registry (not the process-global default):
        # two services on one process must not pool their counts, and the
        # tests assert per-service stats. `stats()` is a thin view over it.
        self.metrics = MetricsRegistry()
        self._c = {
            k: self.metrics.counter(f"service.scans.{k}")
            for k in ("submitted", "rejected", "served", "failed",
                      "store_failed")
        }
        for k in ("buckets", "padded_lanes", "prefetched_loads",
                  "writebacks"):
            self._c[k] = self.metrics.counter(f"service.{k}")
        self._c["slo_met"] = self.metrics.counter("service.slo.met")
        self._c["slo_missed"] = self.metrics.counter("service.slo.missed")
        self._c["loop_passes"] = self.metrics.counter("service.loop.passes")
        self._c["loop_errors"] = self.metrics.counter("service.loop.errors")
        self._h_queue_wait = self.metrics.histogram(
            "service.queue_wait_seconds", DEFAULT_TIME_BUCKETS)
        self._h_assembly = self.metrics.histogram(
            "service.bucket_assembly_seconds", DEFAULT_TIME_BUCKETS)
        self._h_ttv = self.metrics.histogram(
            "service.time_to_volume_seconds", DEFAULT_TIME_BUCKETS)

    # -- admission -----------------------------------------------------------

    def _admit(self, family: ScanFamily):
        """Resolve the family's plan (cached) and check it serves: the
        schedule must be batchable and one scan's footprint must fit the
        budget — the reject half of admission; the queue bound is the
        backpressure half."""
        plan = self.plan_cache.resolve(family)
        if plan.schedule == "incremental":
            # build_batched would raise at drain time; reject NOW so a
            # bad pin never queues work the engine cannot serve.
            raise AdmissionError(
                "scan rejected: schedule='incremental' is stateful "
                "(projections arrive as deltas) and cannot be served by "
                "the batched engine — use plan.build_incremental() "
                "directly, or pin a batch schedule "
                "(fused/pipelined/chunked)")
        from repro.planner import check_feasible, point_from_plan
        ok, reason = check_feasible(family.geometry, point_from_plan(plan),
                                    self.hbm_bytes, self.vmem_budget)
        if not ok:
            raise AdmissionError(
                f"scan rejected: plan [{plan.describe()}] does not fit the "
                f"budget ({self.hbm_bytes / 2**30:.2f} GiB HBM): {reason}")
        return plan

    def submit(self, projections=None, *, geometry: CBCTGeometry,
               source=None, sink=None, scan_id: Optional[str] = None,
               deadline_s: Optional[float] = None, **pins) -> ScanTicket:
        """Admit one scan. Exactly one of `projections` (in-memory
        (N_p, N_v, N_u) array) / `source` (ProjectionSource, loaded by the
        prefetch thread at drain time) carries the data; `sink`
        (VolumeSink) enables write-behind store of the result. `deadline_s`
        is the scan's time-to-volume SLO target (seconds from now; counted
        in `service.slo.met/missed` at completion, and the `deadline`
        policy schedules against it). `pins` are planner pins
        (precision=..., schedule=...) and widen the scan's family. Returns
        the scan's ticket; raises AdmissionError / QueueFullError instead
        of queueing work that cannot be served. Every rejection path counts
        in the `rejected` stat."""
        try:
            return self._submit(projections, geometry=geometry,
                                source=source, sink=sink, scan_id=scan_id,
                                deadline_s=deadline_s, pins=pins)
        except AdmissionError:     # includes QueueFullError
            self._c["rejected"].inc()
            raise

    def _check_queue_bound(self) -> None:
        if len(self._queue) >= self.max_queue:
            raise QueueFullError(
                f"scan queue is full ({self.max_queue}); drain() or "
                "shed load")

    def _submit(self, projections, *, geometry: CBCTGeometry, source,
                sink, scan_id, deadline_s, pins) -> ScanTicket:
        if (projections is None) == (source is None):
            raise AdmissionError(
                "pass exactly one of projections= (in-memory scan) or "
                "source= (ProjectionSource to prefetch from)")
        if deadline_s is not None and deadline_s < 0:
            raise AdmissionError(
                f"deadline_s={deadline_s} must be >= 0 (seconds from "
                "submission)")
        if projections is not None:
            want = (geometry.n_proj, geometry.n_v, geometry.n_u)
            if tuple(projections.shape) != want:
                raise AdmissionError(
                    f"projections shape {tuple(projections.shape)} does not "
                    f"match the declared geometry {want}")
        # Cheap backpressure check BEFORE the expensive admission step
        # (plan resolve may be a full planner search) — a full queue must
        # not pay for a search it is about to reject.
        with self._lock:
            self._check_queue_bound()
        family = ScanFamily.make(geometry, self.mesh, pins)
        self._admit(family)   # raises AdmissionError on schedule/footprint
        with self._cv:
            self._check_queue_bound()   # re-check: racing submitters
            self._seq += 1
            ticket = ScanTicket(
                scan_id=scan_id or f"scan-{self._seq}", family=family,
                submitted_at=time.perf_counter(), deadline_s=deadline_s)
            self._queue.append(_QueuedScan(ticket=ticket,
                                           projections=projections,
                                           source=source, sink=sink,
                                           seq=self._seq))
            self._c["submitted"].inc()
            self._cv.notify_all()       # wake the background drain loop
        return ticket

    @property
    def queued(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- bucketing -----------------------------------------------------------

    def _bucket_capacity(self, family: ScanFamily, plan) -> int:
        """Largest power-of-two batch the budget admits for this family
        (>= 1: single-scan feasibility was checked at admission)."""
        from repro.planner import plan_footprint, point_from_plan
        fp = plan_footprint(family.geometry, point_from_plan(plan))
        per_scan = max(1, fp.total)
        cap = 1
        while (cap * 2 <= self.max_batch
               and (cap * 2) * per_scan <= self.hbm_bytes):
            cap *= 2
        return cap

    def _make_buckets(self) -> Tuple[List[_Bucket], List[ScanTicket]]:
        """Drain the queue into policy-ordered buckets, preserving
        submission order within each family. Returns (buckets, failed):
        a family whose plan resolve / capacity sizing raises fails ONLY
        its own tickets (state FAILED, error recorded, `failed` counted)
        and the other families still get buckets — before this isolation,
        an exception here unwound drain() with every pending ticket of
        EVERY family already swapped out of the queue and silently stuck
        in QUEUED forever."""
        with self._lock:
            pending, self._queue = self._queue, []
        by_family: Dict[ScanFamily, List[_QueuedScan]] = {}
        order: List[ScanFamily] = []
        for item in pending:
            fam = item.ticket.family
            if fam not in by_family:
                by_family[fam] = []
                order.append(fam)
            by_family[fam].append(item)
        buckets: List[_Bucket] = []
        failed: List[ScanTicket] = []
        for fam in order:
            scans = by_family[fam]
            try:
                plan = self.plan_cache.resolve(fam)
                cap = self._bucket_capacity(fam, plan)
            except BaseException as e:
                for item in scans:
                    item.ticket._set_state(TicketState.FAILED, error=e)
                    self._observe_slo(item.ticket, t_done=None)
                    failed.append(item.ticket)
                self._c["failed"].inc(len(scans))
                continue
            for i in range(0, len(scans), cap):
                chunk = scans[i:i + cap]
                buckets.append(_Bucket(fam, chunk, _next_pow2(len(chunk)),
                                       chunk[0].seq))
        return self._schedule(buckets), failed

    def _schedule(self, buckets: List[_Bucket]) -> List[_Bucket]:
        """Order buckets for execution per `self.policy` (module docstring).
        In-family order is always preserved (buckets chunk the family's
        arrival order); the policy decides the CROSS-family interleave."""
        if self.policy == "deadline":
            # EDF across all buckets; ties (and the deadline-less tail,
            # +inf) fall back to arrival order.
            return sorted(buckets, key=lambda b: (b.deadline(), b.seq))
        per_fam: Dict[ScanFamily, List[_Bucket]] = {}
        for b in buckets:
            per_fam.setdefault(b.family, []).append(b)
        out: List[_Bucket] = []
        while per_fam:
            # One bucket per family per round = the fairness bound: a
            # family with B queued buckets delays any other family by at
            # most one bucket per round, never by all B.
            if self.policy == "largest_bucket":
                round_order = sorted(
                    per_fam, key=lambda f: (-len(per_fam[f][0].scans),
                                            per_fam[f][0].seq))
            else:   # fifo
                round_order = sorted(per_fam,
                                     key=lambda f: per_fam[f][0].seq)
            for fam in round_order:
                q = per_fam[fam]
                out.append(q.pop(0))
                if not q:
                    del per_fam[fam]
        return out

    # -- serving -------------------------------------------------------------

    def _load_jobs(self, buckets: List[_Bucket]):
        """One prefetch job per admitted scan, in processing order: PFS
        sources scatter-read + decode on the worker thread; in-memory scans
        pass through untouched."""
        jobs = []
        for bucket in buckets:
            for item in bucket.scans:
                if item.source is not None:
                    jobs.append(
                        lambda s=item.source: s.load(self.mesh))
                else:
                    jobs.append(lambda p=item.projections: p)
        return jobs

    def _observe_slo(self, ticket: ScanTicket,
                     t_done: Optional[float]) -> None:
        """Count the ticket against its SLO: met iff the volume landed
        (t_done) before the absolute deadline; a FAILED ticket
        (t_done=None) with a deadline is a miss. Counted once, at the
        dispatch-side terminal transition (the same instant the
        time-to-volume histogram observes) — a later write-behind store
        failure flips the state but not the SLO count."""
        deadline = ticket.deadline
        if deadline is None:
            return
        if t_done is not None and t_done <= deadline:
            self._c["slo_met"].inc()
        else:
            self._c["slo_missed"].inc()

    def _serve_bucket(self, bucket: _Bucket, prefetch: SourcePrefetcher,
                      writes: List[Tuple[ScanTicket, object]],
                      tracer) -> List[ScanTicket]:
        """Serve one bucket: consume its prefetched lanes, dispatch the
        batched engine, hand sink-ed volumes to the write-behind pool.
        Never raises — a failure fails exactly this bucket's tickets."""
        from repro.core.distributed import SCATTER_REDUCES, \
            batched_input_sharding
        fam, scans, bsz = bucket.family, bucket.scans, bucket.bsz
        bucket_span = tracer.span("service.bucket", batch=bsz,
                                  scans=len(scans))
        bucket_span.__enter__()
        t_bucket0 = time.perf_counter()
        tickets = [s.ticket for s in scans]
        for t in tickets:
            t._set_state(TicketState.BATCHED)
            if t.submitted_at is not None:
                self._h_queue_wait.observe(t_bucket0 - t.submitted_at)
        # Consume EXACTLY len(scans) prefetch items FIRST, before
        # anything else in the bucket can fail: the prefetch queue
        # is positional (load job k belongs to scan k), so a
        # bucket that bailed early (plan resolve / engine build
        # raising) would leave its loads queued and the NEXT
        # bucket's get() calls would receive them — silent
        # cross-scan data corruption. A failed load fails this
        # bucket only; alignment is preserved either way.
        asm_span = tracer.span("service.bucket.assemble")
        asm_span.__enter__()
        lanes: List[object] = []
        lane_err: Optional[BaseException] = None
        for _ in scans:
            try:
                lanes.append(prefetch.get())
            except BaseException as e:
                lanes.append(None)
                if lane_err is None:
                    lane_err = e
        try:
            if lane_err is not None:
                raise lane_err
            g = fam.geometry
            plan = self.plan_cache.resolve(fam)
            engine = plan.build_batched(bsz)
            lanes = [jnp.asarray(l) for l in lanes]
            n_loads = sum(1 for s in scans if s.source is not None)
            n_pad = bsz - len(lanes)
            if n_pad:
                pad = jnp.zeros((g.n_proj, g.n_v, g.n_u),
                                jnp.float32)
                lanes.extend([pad] * n_pad)
            batch = jnp.stack(lanes)
            if self.mesh is not None:
                batch = jax.device_put(
                    batch, batched_input_sharding(self.mesh))
            asm_span.__exit__(None, None, None)
            asm_span = None
            self._h_assembly.observe(time.perf_counter() - t_bucket0)
            for t in tickets:
                t._set_state(TicketState.SERVING)
            out = engine(batch)
            bucket_span.fence(out)
            layout = None
            if (plan.schedule == "chunked"
                    and plan.reduce in SCATTER_REDUCES):
                layout = {"kind": "y_chunk_major",
                          "y_chunks": plan.y_chunks}
            t_done = time.perf_counter()
            for i, item in enumerate(scans):
                vol = out[i]
                item.ticket._set_state(TicketState.DONE, volume=vol)
                if item.ticket.submitted_at is not None:
                    self._h_ttv.observe(t_done - item.ticket.submitted_at)
                self._observe_slo(item.ticket, t_done)
                if item.sink is not None:
                    writes.append((
                        item.ticket,
                        self._writeback.submit(item.sink, vol,
                                               layout=layout)))
            self._c["buckets"].inc()
            self._c["padded_lanes"].inc(n_pad)
            self._c["prefetched_loads"].inc(n_loads)
            self._c["served"].inc(len(scans))
            self._c["writebacks"].inc(
                sum(1 for s in scans if s.sink is not None))
        except BaseException as e:
            for item in scans:
                item.ticket._set_state(TicketState.FAILED, error=e)
                self._observe_slo(item.ticket, t_done=None)
            self._c["failed"].inc(len(scans))
        finally:
            if asm_span is not None:   # bucket failed mid-assembly
                asm_span.__exit__(None, None, None)
            bucket_span.__exit__(None, None, None)
        return tickets

    def _join_writes(self,
                     writes: List[Tuple[ScanTicket, object]]) -> None:
        """Join write-behind stores; a failed write fails ITS ticket only."""
        for ticket, fut in writes:
            try:
                fut.result()
            except BaseException as e:
                ticket._set_state(TicketState.FAILED, error=e)
                # Counters are monotonic: a store failure retracts the scan
                # from the *served* view via its own counter rather than
                # decrementing (stats() reports served - store_failed).
                self._c["store_failed"].inc()
                self._c["failed"].inc()

    def _drain_pass(self,
                    prefetch: Optional[SourcePrefetcher] = None
                    ) -> List[ScanTicket]:
        """One scheduling pass: snapshot the queue, bucket + order it,
        serve every bucket, join the write-behind stores. `prefetch` is
        the serve loop's persistent prefetcher (extended with this pass's
        jobs); None builds a one-shot one (the synchronous drain() path)."""
        buckets, served = self._make_buckets()
        if not buckets:
            return served
        jobs = self._load_jobs(buckets)
        own_prefetch = prefetch is None
        if own_prefetch:
            prefetch = SourcePrefetcher(jobs,
                                        depth=self.prefetch_depth).start()
        else:
            prefetch.extend(jobs)
        tracer = get_tracer()
        writes: List[Tuple[ScanTicket, object]] = []
        drain_span = tracer.span("service.drain", n_buckets=len(buckets))
        drain_span.__enter__()
        try:
            for bucket in buckets:
                served.extend(self._serve_bucket(bucket, prefetch, writes,
                                                 tracer))
        finally:
            if own_prefetch:
                prefetch.close()
            drain_span.__exit__(None, None, None)
        self._join_writes(writes)
        return served

    def drain(self) -> List[ScanTicket]:
        """Serve every queued scan on the CALLER's thread: bucket by
        family, order buckets by the scheduling policy, reconstruct each
        bucket in one batched dispatch, store sink-ed results write-behind.
        Returns the tickets served this drain in execution order (DONE or
        FAILED — a failed bucket fails only its own tickets). Mutually
        exclusive with the background loop (shutdown() first)."""
        if self.serving:
            raise RuntimeError(
                "drain() is the synchronous serving path, but the "
                "background serve() loop is running — submit() + "
                "ticket.wait() instead, or shutdown() the loop first")
        return self._drain_pass(None)

    # -- the background drain loop -------------------------------------------

    @property
    def serving(self) -> bool:
        """Whether the background drain loop is running."""
        t = self._serve_thread
        return t is not None and t.is_alive()

    def serve(self) -> "ReconstructionService":
        """Start the background drain loop (idempotent): a dedicated
        thread that wakes on submit() and drains whenever scans are
        queued. Callers stop calling drain() and instead
        `ticket.wait(timeout=)` — time-to-volume becomes the service's
        concern (deadline_s SLOs, `service.slo.*` counters), not the
        caller's blocking time."""
        with self._lock:
            if self._serve_thread is not None and self._serve_thread.is_alive():
                return self
            self._shutdown_requested = False
            self._serve_thread = threading.Thread(
                target=self._serve_loop, name="recon-serve", daemon=True)
            self._serve_thread.start()
        return self

    def shutdown(self, timeout: Optional[float] = None) -> None:
        """Stop the background loop GRACEFULLY: scans already queued (and
        any bucket in flight) are served before the thread exits — a
        shutdown never strands admitted work in a non-terminal state.
        Blocks until the loop exits (or `timeout` elapses). Idempotent;
        no-op when the loop never ran."""
        with self._cv:
            self._shutdown_requested = True
            self._cv.notify_all()
        t = self._serve_thread
        if t is not None:
            t.join(timeout)

    def _serve_loop(self) -> None:
        """The background drain loop body. One persistent prefetcher spans
        every pass (extend() feeds it — no per-pass thread spawn/join);
        a pass that raises is counted in `service.loop.errors` and the
        loop keeps serving (its tickets were already failed by the
        per-bucket / per-family isolation — an unexpected error must not
        take the whole service down with scans still arriving)."""
        prefetch = SourcePrefetcher(depth=self.prefetch_depth,
                                    persistent=True).start()
        try:
            while True:
                with self._cv:
                    while not self._queue and not self._shutdown_requested:
                        # The timeout is a lost-wakeup safety net; normal
                        # wakeup is submit()/shutdown() notifying.
                        self._cv.wait(timeout=0.1)
                    if not self._queue and self._shutdown_requested:
                        return
                try:
                    self._drain_pass(prefetch)
                    self._c["loop_passes"].inc()
                except BaseException:
                    self._c["loop_errors"].inc()
        finally:
            prefetch.close()

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        """Counters + cache stats — a thin view over `self.metrics` (the
        per-instance registry), keeping the historical flat keys.
        `plan_cache.searches` staying flat while `submitted` grows is the
        amortization proof (one planner search per scan family);
        `engine_cache` covers the jitted batched engines. `latency` holds
        the queue-wait / bucket-assembly / time-to-volume histogram
        snapshots; `slo` the met/missed counts and attainment fraction
        over deadline-carrying scans; `loop` the background loop's
        pass/error counts and liveness."""
        from repro.core.plan import engine_cache_stats
        v = self.metrics.value
        counters = {
            "submitted": v("service.scans.submitted", 0),
            "rejected": v("service.scans.rejected", 0),
            # store_failed retracts write-behind failures from the served
            # view (monotonic counters cannot decrement).
            "served": (v("service.scans.served", 0)
                       - v("service.scans.store_failed", 0)),
            "failed": v("service.scans.failed", 0),
            "buckets": v("service.buckets", 0),
            "padded_lanes": v("service.padded_lanes", 0),
            "prefetched_loads": v("service.prefetched_loads", 0),
            "writebacks": v("service.writebacks", 0),
        }
        with self._lock:
            counters["queued"] = len(self._queue)
        met = v("service.slo.met", 0)
        missed = v("service.slo.missed", 0)
        counters["slo"] = {
            "met": met,
            "missed": missed,
            "attainment": (met / (met + missed)) if met + missed else None,
        }
        counters["loop"] = {
            "passes": v("service.loop.passes", 0),
            "errors": v("service.loop.errors", 0),
            "serving": self.serving,
        }
        counters["policy"] = self.policy
        counters["latency"] = {
            "queue_wait": self._h_queue_wait.snapshot(),
            "bucket_assembly": self._h_assembly.snapshot(),
            "time_to_volume": self._h_ttv.snapshot(),
        }
        counters["plan_cache"] = self.plan_cache.stats()
        counters["engine_cache"] = engine_cache_stats()
        return counters

    def close(self) -> None:
        """Shut the background loop down (serving queued work first) and
        join the write-behind pool."""
        if self.serving:
            self.shutdown()
        self._writeback.close()
