"""Scan requests, families, and tickets — the service's data model.

A **family** is the bucketing identity: two requests may share one batched
engine dispatch iff their (geometry, mesh, plan pins) triples are equal —
that triple determines the plan the planner would pick, the engine trace,
and every array shape in the pipeline. It is also the plan-cache key
(plan_cache.py), so "same family" and "planner search already paid" are
the same statement.

A **ticket** is the caller's handle on one submitted scan: its lifecycle
(QUEUED -> BATCHED -> SERVING -> DONE | FAILED; REJECTED never enters the
queue), the reconstructed volume once served, and the error if its bucket
failed. With the background drain loop (scheduler.serve()) tickets are
served on another thread, so every state transition goes through
`_set_state` (one lock per ticket, terminal states sticky against
non-terminal writes) and terminal transitions fire a per-ticket
`threading.Event` that `wait(timeout=)` callers block on.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Optional

from repro.core.geometry import CBCTGeometry


class AdmissionError(ValueError):
    """The request was REJECTED at submit time — footprint over the memory
    budget (planner/feasibility said no plan point fits) or malformed. The
    scan never enters the queue; nothing was partially served."""


class QueueFullError(AdmissionError):
    """Backpressure: the scan queue is at max_queue. Callers should retry
    after a drain (or shed load) — queueing unboundedly would just move the
    OOM from device memory to host memory."""


@dataclasses.dataclass(frozen=True)
class ScanFamily:
    """The bucketing identity + plan-cache key: (geometry, mesh, pins).

    `pins` is the canonicalized (sorted key/value tuple) form of the
    caller's planner pins (e.g. precision="bf16") — part of the identity
    because pinned requests must not share a plan (or a bucket) with
    unpinned ones.
    """

    geometry: CBCTGeometry
    mesh: Optional[object]          # jax Mesh (hashable) or None
    pins: tuple = ()

    @staticmethod
    def make(geometry: CBCTGeometry, mesh, pins: dict) -> "ScanFamily":
        return ScanFamily(geometry=geometry, mesh=mesh,
                          pins=tuple(sorted((pins or {}).items())))

    def pins_dict(self) -> dict:
        return dict(self.pins)


class TicketState(enum.Enum):
    QUEUED = "queued"       # admitted, waiting for a drain
    BATCHED = "batched"     # assigned to a bucket this drain pass
    SERVING = "serving"     # its bucket's batched dispatch is in flight
    DONE = "done"           # volume ready (and stored, if a sink was given)
    FAILED = "failed"       # its bucket's dispatch or store raised


#: Terminal states — once reached, only terminal->terminal transitions are
#: allowed (a write-behind store failure flips DONE -> FAILED; nothing can
#: resurrect a finished ticket back into the queue's states).
TERMINAL_STATES = frozenset({TicketState.DONE, TicketState.FAILED})


@dataclasses.dataclass
class ScanTicket:
    """One submitted scan's handle. `volume` is the engine's per-scan
    output (sharded like the single-scan engine's); `error` holds the
    exception when state is FAILED.

    Tickets served by the background loop finish on another thread:
    `wait(timeout=)` blocks until the ticket is terminal (DONE or FAILED —
    the loop fires `_done_event` exactly at that transition), and
    `deadline_s` is the caller's time-to-volume SLO target, measured from
    `submitted_at` (the scheduler counts `service.slo.met/missed` against
    the absolute `deadline` at completion time).
    """

    scan_id: str
    family: ScanFamily
    state: TicketState = TicketState.QUEUED
    volume: Optional[object] = None
    error: Optional[BaseException] = None
    # Monotonic submit timestamp (time.perf_counter()), stamped by the
    # scheduler at admission — the zero point for the queue-wait and
    # time-to-volume latency histograms. None for hand-built tickets.
    submitted_at: Optional[float] = None
    # Time-to-volume SLO target in seconds from submit (None = no SLO).
    deadline_s: Optional[float] = None
    _done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)
    _state_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def deadline(self) -> Optional[float]:
        """Absolute SLO deadline on the `time.perf_counter()` clock, or
        None when the scan has no SLO (or no submit timestamp)."""
        if self.deadline_s is None or self.submitted_at is None:
            return None
        return self.submitted_at + self.deadline_s

    @property
    def done(self) -> bool:
        return self.state is TicketState.DONE

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the ticket is terminal (DONE or FAILED); returns
        True when it is, False on timeout. The call that makes the
        background loop usable: submit -> wait -> result."""
        return self._done_event.wait(timeout)

    def _set_state(self, state: TicketState, *, volume=None,
                   error: Optional[BaseException] = None) -> bool:
        """Thread-safe transition (scheduler-internal). Terminal states are
        sticky: once DONE/FAILED, only another terminal state may overwrite
        (the write-behind store-failure flip DONE -> FAILED). Returns
        whether the transition was applied; fires the done event on
        reaching a terminal state."""
        with self._state_lock:
            if self.state in TERMINAL_STATES and state not in TERMINAL_STATES:
                return False
            if volume is not None:
                self.volume = volume
            if error is not None:
                self.error = error
            self.state = state
        if state in TERMINAL_STATES:
            self._done_event.set()
        return True

    def result(self, timeout: Optional[float] = None):
        """The reconstructed volume; raises the bucket's error for FAILED
        tickets and RuntimeError when the scan has not been served yet.
        `timeout` waits for a terminal state first (background-loop
        callers); the default stays non-blocking for the synchronous
        drain() flow."""
        if timeout is not None:
            self.wait(timeout)
        if self.state is TicketState.FAILED:
            raise RuntimeError(
                f"scan {self.scan_id!r} failed to reconstruct"
            ) from self.error
        if self.state is not TicketState.DONE:
            raise RuntimeError(
                f"scan {self.scan_id!r} is {self.state.value}; call "
                "ReconstructionService.drain() (or serve() the background "
                "loop and ticket.wait()) to serve queued scans")
        return self.volume


@dataclasses.dataclass
class _QueuedScan:
    """Internal queue entry: the ticket plus how to obtain its projections
    (exactly one of `projections` / `source` is set), where to store the
    result (optional sink), and the admission sequence number `seq` — the
    arrival-order key the scheduling policies tie-break on."""

    ticket: ScanTicket
    projections: Optional[object] = None
    source: Optional[object] = None          # io.streams.ProjectionSource
    sink: Optional[object] = None            # io.streams.VolumeSink
    seq: int = 0
