"""Scan requests, families, and tickets — the service's data model.

A **family** is the bucketing identity: two requests may share one batched
engine dispatch iff their (geometry, mesh, plan pins) triples are equal —
that triple determines the plan the planner would pick, the engine trace,
and every array shape in the pipeline. It is also the plan-cache key
(plan_cache.py), so "same family" and "planner search already paid" are
the same statement.

A **ticket** is the caller's handle on one submitted scan: its lifecycle
(QUEUED -> BATCHED -> DONE | FAILED; REJECTED never enters the queue), the
reconstructed volume once served, and the error if its bucket failed.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.geometry import CBCTGeometry


class AdmissionError(ValueError):
    """The request was REJECTED at submit time — footprint over the memory
    budget (planner/feasibility said no plan point fits) or malformed. The
    scan never enters the queue; nothing was partially served."""


class QueueFullError(AdmissionError):
    """Backpressure: the scan queue is at max_queue. Callers should retry
    after a drain (or shed load) — queueing unboundedly would just move the
    OOM from device memory to host memory."""


@dataclasses.dataclass(frozen=True)
class ScanFamily:
    """The bucketing identity + plan-cache key: (geometry, mesh, pins).

    `pins` is the canonicalized (sorted key/value tuple) form of the
    caller's planner pins (e.g. precision="bf16") — part of the identity
    because pinned requests must not share a plan (or a bucket) with
    unpinned ones.
    """

    geometry: CBCTGeometry
    mesh: Optional[object]          # jax Mesh (hashable) or None
    pins: tuple = ()

    @staticmethod
    def make(geometry: CBCTGeometry, mesh, pins: dict) -> "ScanFamily":
        return ScanFamily(geometry=geometry, mesh=mesh,
                          pins=tuple(sorted((pins or {}).items())))

    def pins_dict(self) -> dict:
        return dict(self.pins)


class TicketState(enum.Enum):
    QUEUED = "queued"       # admitted, waiting for a drain
    BATCHED = "batched"     # assigned to a bucket this drain
    DONE = "done"           # volume ready (and stored, if a sink was given)
    FAILED = "failed"       # its bucket's dispatch or store raised


@dataclasses.dataclass
class ScanTicket:
    """One submitted scan's handle. `volume` is the engine's per-scan
    output (sharded like the single-scan engine's); `error` holds the
    exception when state is FAILED."""

    scan_id: str
    family: ScanFamily
    state: TicketState = TicketState.QUEUED
    volume: Optional[object] = None
    error: Optional[BaseException] = None
    # Monotonic submit timestamp (time.perf_counter()), stamped by the
    # scheduler at admission — the zero point for the queue-wait and
    # time-to-volume latency histograms. None for hand-built tickets.
    submitted_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.state is TicketState.DONE

    def result(self):
        """The reconstructed volume; raises the bucket's error for FAILED
        tickets and RuntimeError when the scan has not been served yet."""
        if self.state is TicketState.FAILED:
            raise RuntimeError(
                f"scan {self.scan_id!r} failed to reconstruct"
            ) from self.error
        if self.state is not TicketState.DONE:
            raise RuntimeError(
                f"scan {self.scan_id!r} is {self.state.value}; call "
                "ReconstructionService.drain() to serve queued scans")
        return self.volume


@dataclasses.dataclass
class _QueuedScan:
    """Internal queue entry: the ticket plus how to obtain its projections
    (exactly one of `projections` / `source` is set) and where to store the
    result (optional sink)."""

    ticket: ScanTicket
    projections: Optional[object] = None
    source: Optional[object] = None          # io.streams.ProjectionSource
    sink: Optional[object] = None            # io.streams.VolumeSink
