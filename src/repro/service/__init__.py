"""Reconstruction-as-a-service (DESIGN.md §Serving).

The paper solves ONE scan fast; production CT is a *stream* of scans
hitting a fixed fleet. This package is the request layer that turns the
staged engine (core/plan.py) into a throughput machine:

  * scan queue + admission control — requests are rejected up front when
    their footprint cannot fit the memory budget (planner/feasibility) or
    the queue is full (backpressure), never half-served;
  * geometry-bucketed batching — same-family scans (identical geometry,
    mesh, plan pins) are padded to power-of-two buckets and reconstructed
    by ONE vmapped dispatch (`ReconstructionPlan.build_batched`), bit-exact
    per scan vs the single-scan engine;
  * plan cache — planner search (`plan_from_spec(g, "auto")`) runs once
    per scan family, not per request; hit/miss/search counters are the
    service's proof of amortization;
  * async I/O overlap — PFS reads prefetch ahead (SourcePrefetcher) and
    volume stores write behind (AsyncWriteback), so scan k+1's loads and
    scan k-1's writes overlap scan k's compute.

    svc = ReconstructionService(mesh)
    t1 = svc.submit(projections=p1, geometry=g)
    t2 = svc.submit(source=src2, geometry=g, sink=sink2)
    svc.drain()                      # bucket, batch, reconstruct, store
    volume = t1.volume
    svc.stats()["plan_cache"]        # {"searches": 1, "hits": 1, ...}

Continuous serving (the hardened mode): `serve()` starts a background
drain loop — submit() wakes it through a condition variable, callers
`ticket.wait(timeout=)` instead of draining, per-scan `deadline_s`
time-to-volume SLOs are counted in `service.slo.met/missed`, and a
pluggable `policy=` ("fifo" | "largest_bucket" | "deadline") orders
buckets across families with per-family fairness:

    svc = ReconstructionService(mesh, policy="deadline").serve()
    t = svc.submit(projections=p, geometry=g, deadline_s=30.0)
    t.wait(timeout=60); volume = t.result()
    svc.shutdown()                   # graceful: queued work serves first

Throughput figure of merit: scans/hour at fixed fleet
(benchmarks/bench_serving.py, persisted as BENCH_serving.json — the
serve-loop rows carry SLO attainment).
"""
from .requests import (  # noqa: F401
    AdmissionError, QueueFullError, ScanFamily, ScanTicket, TicketState,
)
from .plan_cache import PlanCache  # noqa: F401
from .scheduler import (  # noqa: F401
    ReconstructionService, SCHEDULING_POLICIES,
)

__all__ = [
    "AdmissionError", "QueueFullError", "ScanFamily", "ScanTicket",
    "TicketState", "PlanCache", "ReconstructionService",
    "SCHEDULING_POLICIES",
]
