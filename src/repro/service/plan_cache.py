"""Plan cache: planner search once per scan family, not per request.

`plan_from_spec(g, "auto")` is the expensive admission step — a full
enumerate/prune/rank sweep of the plan space (repro/planner). A serving
loop seeing thousands of same-geometry scans must pay it once per FAMILY
(geometry, mesh, pins — see requests.ScanFamily), which is exactly what a
counted LRU keyed by the family gives us. The `searches` counter is the
acceptance proof: after two same-family submits it reads 1 (second request
did zero planner-search work), and the service surfaces it in stats().
"""
from __future__ import annotations

from typing import Optional

from repro.core.cache import CountingLRU

from .requests import ScanFamily


class PlanCache:
    """(geometry, mesh, pins) -> validated ReconstructionPlan, bounded LRU.

    spec  : the plan spec every family resolves through — "auto" (default)
            runs planner search with the family's pins; a concrete spec
            string (e.g. "schedule=pipelined,n_steps=4") skips search and
            just builds + validates the plan (still cached: validate and
            kernel-block resolution are not free either).
    """

    def __init__(self, capacity: int = 32, spec: str = "auto"):
        self._lru = CountingLRU(capacity, name="service.plan_cache")
        self.spec = spec
        self.searches = 0    # planner-search (cold resolve) count
        from repro.obs import metrics as _metrics
        self._searches_total = _metrics.counter("service.plan_cache.searches")

    def resolve(self, family: ScanFamily):
        def build():
            from repro.core.plan import plan_from_spec
            self.searches += 1
            self._searches_total.inc()
            plan = plan_from_spec(family.geometry, self.spec,
                                  mesh=family.mesh, **family.pins_dict())
            plan.validate()
            return plan
        return self._lru.get_or_build(family, build)

    def peek(self, family: ScanFamily) -> Optional[object]:
        """Cached plan without resolving (does count as hit/miss)."""
        return self._lru.get(family)

    def stats(self) -> dict:
        s = self._lru.stats()
        s["searches"] = self.searches
        return s

    def clear(self) -> None:
        self._lru.clear()
        self.searches = 0
