from .config import ModelConfig, MoEConfig, SSMConfig, SubLayer, count_params, count_active_params
