"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) block.

Training uses the chunked SSD algorithm: quadratic attention-like compute
inside a chunk, linear state recurrence across chunks (lax.scan). Decode is
the O(1) recurrent update — no KV cache, a fixed-size (H, P, N) state plus a
(d_conv-1)-deep conv buffer, which is what makes the long_500k cell viable
for SSM/hybrid archs.

Layout: x (B, L, H, P) with heads sharded over the model axis (the state is
head-local, so TP needs no collective inside the recurrence).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import ParamDef, rmsnorm

Array = jax.Array


class SSMCache(NamedTuple):
    conv: Array     # (B, d_conv-1, conv_ch)
    state: Array    # (B, H, P, N)


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.d_state
    return d_in, nheads, conv_ch


def ssm_defs(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nheads, conv_ch = ssm_dims(cfg)
    return {
        "w_z": ParamDef((d, d_in), ("fsdp", "tp")),
        "w_xbc": ParamDef((d, conv_ch), ("fsdp", "tp")),
        "w_dt": ParamDef((d, nheads), ("fsdp", "tp")),
        "conv_w": ParamDef((s.d_conv, conv_ch), (None, "tp")),
        "conv_b": ParamDef((conv_ch,), ("tp",), scale=0.0),
        "a_log": ParamDef((nheads,), ("tp",), scale=0.0),
        "d_skip": ParamDef((nheads,), ("tp",), scale=0.0),
        "dt_bias": ParamDef((nheads,), ("tp",), scale=0.0),
        "norm": ParamDef((d_in,), ("tp",), scale=0.0),
        "w_out": ParamDef((d_in, d), ("tp", "fsdp")),
    }


def _split_xbc(cfg: ModelConfig, xbc: Array):
    s = cfg.ssm
    d_in, nheads, _ = ssm_dims(cfg)
    x = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + s.d_state]
    cmat = xbc[..., d_in + s.d_state:]
    b, l = x.shape[0], x.shape[1]
    x = x.reshape(b, l, nheads, s.head_dim)
    return x, bmat, cmat   # B/C: (B, L, N) (single group, broadcast to heads)


def _causal_conv(cfg: ModelConfig, params, xbc: Array) -> Array:
    """Depthwise causal conv, window d_conv, over (B, L, C)."""
    s = cfg.ssm
    pad = s.d_conv - 1
    xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    w = params["conv_w"].astype(xbc.dtype)                 # (d_conv, C)
    out = sum(
        xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(s.d_conv)
    )
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def _ssd_chunked(cfg: ModelConfig, x: Array, dt: Array, a: Array,
                 bmat: Array, cmat: Array, init_state: Array):
    """Chunked SSD scan.

    x (B,L,H,P); dt (B,L,H) post-softplus; a (H,) negative; B/C (B,L,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    s = cfg.ssm
    bsz, l, h, p = x.shape
    n = bmat.shape[-1]
    q = min(s.chunk, l)
    l_orig = l
    if l % q:
        # Zero-pad the tail: dt=0 there => xbar=0 and decay=exp(0)=1, so the
        # padding is exactly inert for both outputs and states.
        pad = q - l % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        l = l + pad
    nc = l // q

    xb = (x * dt[..., None]).reshape(bsz, nc, q, h, p)      # \bar{x}
    da = (dt * a).reshape(bsz, nc, q, h)                    # log-decays
    bm = bmat.reshape(bsz, nc, q, n)
    cm = cmat.reshape(bsz, nc, q, n)

    cs = jnp.cumsum(da, axis=2)                             # (B,NC,Q,H)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,NC,Qi,Qj,H)
    iq = jnp.arange(q)
    causal = iq[:, None] >= iq[None, :]
    # Mask BEFORE exp: non-causal entries have seg > 0 and can overflow;
    # where(mask, exp(seg), 0) would give inf*0 = NaN in the backward pass.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    lmat = jnp.exp(seg)

    # intra-chunk (the "attention-like" quadratic term)
    att = jnp.einsum("bcin,bcjn->bcij", cm, bm)[..., None] * lmat
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(x.dtype), xb)

    # chunk summary state: sum_j exp(cs_last - cs_j) B_j (x) xb_j
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)           # (B,NC,Q,H)
    chunk_state = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn", bm, decay_to_end.astype(x.dtype), xb
    )
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))              # (B,NC,H)

    def scan_fn(state, xs):
        cstate, cdecay = xs                                 # (B,H,P,N), (B,H)
        new = state * cdecay[..., None, None] + cstate
        return new, state                                   # emit state *before* chunk

    states_seq = jnp.moveaxis(chunk_state, 1, 0)            # (NC,B,H,P,N)
    decays_seq = jnp.moveaxis(chunk_decay, 1, 0)
    final_state, prev_states = lax.scan(
        scan_fn, init_state, (states_seq, decays_seq)
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,NC,H,P,N)

    # inter-chunk: y_i += C_i . (decay_in * state_prev)
    decay_in = jnp.exp(cs).astype(x.dtype)                  # (B,NC,Q,H)
    y_inter = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", cm, prev_states.astype(x.dtype), decay_in
    )
    y = (y_intra + y_inter).reshape(bsz, l, h, p)[:, :l_orig]
    return y, final_state


def ssm_block(params, cfg: ModelConfig, u: Array, rules=None,
              cache: SSMCache | None = None, return_cache: bool = False):
    """Full Mamba-2 mixer. u: (B, L, D). With cache: one-step decode (L=1).

    return_cache=True (prefill): also build the post-sequence cache (final
    SSD state + conv tail) so decoding can continue the stream."""
    s = cfg.ssm
    d_in, nheads, conv_ch = ssm_dims(cfg)
    bsz, l, _ = u.shape
    z = u @ params["w_z"].astype(u.dtype)
    xbc = u @ params["w_xbc"].astype(u.dtype)
    dt_raw = u @ params["w_dt"].astype(u.dtype)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log"].astype(jnp.float32))       # (H,) negative

    if cache is None:
        xbc_raw = xbc
        xbc = _causal_conv(cfg, params, xbc)
        x, bmat, cmat = _split_xbc(cfg, xbc)
        init_state = jnp.zeros(
            (bsz, nheads, s.head_dim, s.d_state), jnp.float32
        )
        y, final_state = _ssd_chunked(
            cfg, x.astype(jnp.float32), dt, a,
            bmat.astype(jnp.float32), cmat.astype(jnp.float32), init_state,
        )
        new_cache = None
        if return_cache:
            tail = xbc_raw[:, -(s.d_conv - 1):, :]
            new_cache = SSMCache(conv=tail, state=final_state)
    else:
        # --- recurrent decode: O(1) state update
        conv_buf = jnp.concatenate([cache.conv, xbc], axis=1)  # (B, d_conv, C)
        w = params["conv_w"].astype(u.dtype)
        conv_out = jnp.einsum("btc,tc->bc", conv_buf, w)[:, None, :]
        xbc = jax.nn.silu(conv_out + params["conv_b"].astype(u.dtype))
        x, bmat, cmat = _split_xbc(cfg, xbc)
        xf = x.astype(jnp.float32)[:, 0]                     # (B,H,P)
        btf = bmat.astype(jnp.float32)[:, 0]                 # (B,N)
        ctf = cmat.astype(jnp.float32)[:, 0]
        dt0 = dt[:, 0]                                       # (B,H)
        da = jnp.exp(dt0 * a)                                # (B,H)
        upd = jnp.einsum("bhp,bn,bh->bhpn", xf, btf, dt0)
        state = cache.state * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ctf)[:, None]  # (B,1,H,P)
        final_state = state
        new_cache = SSMCache(conv=conv_buf[:, 1:], state=final_state)

    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * x.astype(jnp.float32)
    y = y.reshape(bsz, l, d_in).astype(u.dtype)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z), cfg.rms_eps)
    out = y @ params["w_out"].astype(u.dtype)
    if rules is not None:
        out = rules.constrain(out, "dp", "sp", None)
    return out, new_cache


def ssm_cache_defs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in, nheads, conv_ch = ssm_dims(cfg)
    return SSMCache(
        conv=jax.ShapeDtypeStruct(
            (batch, s.d_conv - 1, conv_ch), jnp.dtype(cfg.dtype)
        ),
        state=jax.ShapeDtypeStruct(
            (batch, nheads, s.head_dim, s.d_state), jnp.float32
        ),
    )
