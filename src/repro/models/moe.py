"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

GShard/Switch-style: tokens are scattered into a per-expert capacity buffer
(B, E, C, D) — batch stays on the data axis, experts are sharded over the
model axis (expert parallelism), so the dispatch/combine reshard is the
all-to-all the paper's 2-D decomposition would perform. Over-capacity tokens
are dropped (capacity_factor controls head-room), the standard trade at
scale. Shared experts (qwen2-moe) run densely on every token.

Returns (out, aux_loss) where aux_loss is the load-balancing penalty
(Switch §2.2: E * sum_e fraction_e * prob_e).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import ParamDef

Array = jax.Array


def moe_defs(cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    defs = {
        "router": ParamDef((d, m.num_experts), ("fsdp", None)),
        "w_gate": ParamDef((m.num_experts, d, m.d_ff_expert),
                           ("tp", "fsdp", None), fan_in=d),
        "w_up": ParamDef((m.num_experts, d, m.d_ff_expert),
                         ("tp", "fsdp", None), fan_in=d),
        "w_down": ParamDef((m.num_experts, m.d_ff_expert, d),
                           ("tp", None, "fsdp"), fan_in=m.d_ff_expert),
    }
    if m.num_shared_experts:
        f_sh = m.num_shared_experts * m.d_ff_shared
        defs["shared"] = {
            "w_gate": ParamDef((d, f_sh), ("fsdp", "tp")),
            "w_up": ParamDef((d, f_sh), ("fsdp", "tp")),
            "w_down": ParamDef((f_sh, d), ("tp", "fsdp")),
        }
    return defs


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k / m.num_experts * m.capacity_factor)
    return max(int(c), 1)


def moe(params, cfg: ModelConfig, x: Array, rules=None):
    """x: (B, S, D). GShard-style grouped dispatch.

    Tokens are grouped (B rows x G sequence groups); with a mesh, G = the
    tensor-parallel axis size so the capacity buffers are TOKEN-SHARDED over
    `model` and the dispatch/combine reshard is a true all-to-all (g <-> e),
    not an all-gather of token-replicated buffers — measured 16x less MoE
    wire on qwen2-moe train_4k (EXPERIMENTS.md §Perf cell A iter 2). The
    per-group position cumsum stays shard-local either way."""
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    g = rules.tp_size() if rules is not None else 1
    if g <= 1 or s % g:
        g = 1
    sg = s // g
    c = capacity(cfg, sg)
    xd = x.reshape(b, g, sg, d)
    if rules is not None and g > 1:
        xd = rules.constrain_p(xd, P(rules.axes("dp"), rules.axes("tp"),
                                     None, None))

    logits = (xd.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))           # (B,G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (B,G,Sg,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- position of each (token, choice) inside its expert's buffer
    flat_e = gate_idx.reshape(b, g, sg * k)                      # (B,G,Sg*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=2) * onehot                    # rank+1 where set
    pos_in_e = jnp.sum(pos, axis=-1) - 1                         # (B,G,Sg*k)
    keep = (pos_in_e >= 0) & (pos_in_e < c)
    slot = jnp.clip(pos_in_e, 0, c - 1)

    x_rep = jnp.repeat(xd, k, axis=2).reshape(b, g, sg * k, d)
    if g > 1:
        # --- GShard one-hot EINSUM dispatch (no scatter: SPMD scatters with
        # sharded batch dims lower to full gathers — measured 5.5x WORSE,
        # EXPERIMENTS.md §Perf cell A iter 2). dispatch (B,G,Sk,E,C) is
        # bf16 and token-sharded; both reshards are true all-to-alls.
        onehot_c = jax.nn.one_hot(slot, c, dtype=x.dtype) \
            * keep[..., None].astype(x.dtype)                    # (B,G,Sk,C)
        dispatch = onehot.astype(x.dtype)[..., None] \
            * onehot_c[..., None, :]                             # (B,G,Sk,E,C)
        buf = jnp.einsum("bgtec,bgtd->bgecd", dispatch, x_rep)
        if rules is not None:
            buf = rules.constrain_p(
                buf, P(rules.axes("dp"), None, rules.axes("tp"), None, None)
            )
    else:
        # --- scatter dispatch (single-group path: exact same math)
        contrib = jnp.where(keep[..., None], x_rep, 0).astype(x.dtype)
        buf = jnp.zeros((b, g, e, c, d), x.dtype)
        bidx = jnp.broadcast_to(
            jnp.arange(b, dtype=jnp.int32)[:, None, None], flat_e.shape
        )
        gidx = jnp.zeros_like(flat_e)
        buf = buf.at[bidx, gidx, flat_e, slot].add(contrib)
        if rules is not None:
            buf = rules.constrain_p(
                buf, P(rules.axes("dp"), None, rules.axes("tp"), None, None)
            )

    # --- expert FFN (swiglu), experts sharded over the model axis
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("bgecd,edf->bgecf", buf, wg))
    h = h * jnp.einsum("bgecd,edf->bgecf", buf, wu)
    y = jnp.einsum("bgecf,efd->bgecd", h, wd)                    # (B,G,E,C,D)
    if rules is not None:
        # all-to-all back: expert owners -> token groups
        y = rules.constrain_p(
            y, P(rules.axes("dp"), rules.axes("tp"), None, None, None)
        )

    # --- combine: weighted un-dispatch, sum over the k choices
    wv = gate_vals.reshape(b, g, sg * k).astype(x.dtype)
    if g > 1:
        comb = dispatch * wv[..., None, None]
        y_sum = jnp.einsum("bgtec,bgecd->bgtd", comb, y)
        out = y_sum.reshape(b, g, sg, k, d).sum(axis=3).reshape(b, s, d)
    else:
        y_tok = y[bidx, gidx, flat_e, slot]
        y_tok = jnp.where(keep[..., None], y_tok, 0)
        out = jnp.sum(
            (y_tok * wv[..., None]).reshape(b, g, sg, k, d), axis=3
        ).reshape(b, s, d)
    xd = x  # shared experts run on the raw layout

    if m.num_shared_experts:
        sh = params["shared"]
        hs = jax.nn.silu(xd @ sh["w_gate"].astype(xd.dtype))
        hs = hs * (xd @ sh["w_up"].astype(xd.dtype))
        out = out + hs @ sh["w_down"].astype(xd.dtype)

    # --- Switch load-balancing auxiliary loss
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32),
        axis=(0, 1, 2),
    )
    pmean = jnp.mean(probs, axis=(0, 1, 2))
    aux = m.router_aux_weight * e * jnp.sum(frac * pmean)
    if rules is not None:
        out = rules.constrain(out, "dp", "sp", None)
    return out, aux
