"""Model configuration for the LM substrate.

A model is `num_layers` sub-layers arranged as repeats of a *block pattern*
(tuple of SubLayer descriptors). Homogeneous repeats allow scan-over-layers
(compact HLO, fast compiles) while still expressing heterogeneous stacks:

  dense        pattern = (attn+mlp,)
  moe          pattern = (attn+moe,)
  mamba2 (ssm) pattern = (ssm,)
  jamba hybrid pattern = 8 sub-layers: attention at index 4, Mamba elsewhere,
               MoE on odd indices (1:7 attn:mamba interleave, MoE every other
               layer — arXiv:2403.19887 §2).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Kind = Literal["attn", "ssm"]
Ffn = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class SubLayer:
    kind: Kind = "attn"
    ffn: Ffn = "mlp"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0            # per shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() supplies precomputed embeddings."""
    modality: Literal["vision", "audio"]
    d_frontend: int = 0       # embedding dim delivered by the (stub) encoder
    num_positions: int = 0    # patches (vision) / codebooks (audio)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp_type: Literal["swiglu", "gelu"] = "swiglu"
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    sliding_window: Optional[int] = None      # SWA (mixtral)
    tie_embeddings: bool = False
    pattern: Tuple[SubLayer, ...] = (SubLayer(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[FrontendConfig] = None
    dtype: str = "bfloat16"                   # activation/compute dtype
    param_dtype: str = "float32"
    # source tag for provenance, e.g. "arXiv:2407.10671; hf"
    source: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not a multiple of "
                f"pattern length {len(self.pattern)}"
            )
        needs_moe = any(s.ffn == "moe" for s in self.pattern)
        if needs_moe and self.moe is None:
            raise ValueError(f"{self.name}: pattern has MoE but moe config is None")
        needs_ssm = any(s.kind == "ssm" for s in self.pattern)
        if needs_ssm and self.ssm is None:
            raise ValueError(f"{self.name}: pattern has SSM but ssm config is None")

    @property
    def repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def attention_free(self) -> bool:
        return all(s.kind != "attn" for s in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md §5)."""
        return self.attention_free or self.family == "hybrid" or (
            self.sliding_window is not None
        )

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced config of the same family (smoke tests)."""
        return dataclasses.replace(self, **overrides)


def count_params(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for roofline MODEL_FLOPS)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        # K codebook embedding tables + K output heads
        total = 2 * cfg.frontend.num_positions * cfg.vocab_size * d
    else:
        total = cfg.vocab_size * d  # embed
        if not cfg.tie_embeddings:
            total += cfg.vocab_size * d
    if cfg.frontend is not None and cfg.frontend.modality == "vision":
        df = cfg.frontend.d_frontend
        total += df * d + df + d * d  # projector (w1, norm, w2)
    per_pattern = 0
    for s in cfg.pattern:
        per_pattern += d  # pre-norm
        if s.kind == "attn":
            per_pattern += d * cfg.num_heads * hd            # q
            per_pattern += 2 * d * cfg.num_kv_heads * hd     # k, v
            per_pattern += cfg.num_heads * hd * d            # o
            if cfg.qkv_bias:
                per_pattern += (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
        else:
            ssm = cfg.ssm
            d_in = ssm.expand * d
            nheads = d_in // ssm.head_dim
            conv_ch = d_in + 2 * ssm.d_state
            per_pattern += d * (2 * d_in + 2 * ssm.d_state + nheads)  # in_proj
            per_pattern += conv_ch * ssm.d_conv + conv_ch              # conv w+b
            per_pattern += 2 * nheads + nheads                         # A, D, dt_bias
            per_pattern += d_in                                        # gate norm
            per_pattern += d_in * d                                    # out_proj
        if s.ffn == "mlp":
            per_pattern += d  # norm
            if cfg.mlp_type == "swiglu":
                per_pattern += 3 * d * cfg.d_ff
            else:
                per_pattern += 2 * d * cfg.d_ff
        elif s.ffn == "moe":
            per_pattern += d  # norm
            m = cfg.moe
            per_pattern += d * m.num_experts                       # router
            per_pattern += m.num_experts * 3 * d * m.d_ff_expert   # routed (swiglu)
            per_pattern += m.num_shared_experts * 3 * d * m.d_ff_shared
    total += cfg.repeats * per_pattern
    total += d  # final norm
    return int(total)


def count_moe_expert_params(cfg: ModelConfig) -> int:
    """Routed-expert params only (EP-sharded under the optimized strategy)."""
    if cfg.moe is None:
        return 0
    m = cfg.moe
    n_moe_layers = cfg.repeats * sum(1 for s in cfg.pattern if s.ffn == "moe")
    return int(n_moe_layers * m.num_experts * 3 * cfg.d_model * m.d_ff_expert)


def count_active_params(cfg: ModelConfig) -> int:
    """Per-token active params (MoE: only top_k + shared experts)."""
    if cfg.moe is None:
        return count_params(cfg)
    m = cfg.moe
    d = cfg.d_model
    inactive_per_moe = (m.num_experts - m.top_k) * 3 * d * m.d_ff_expert
    n_moe_layers = cfg.repeats * sum(1 for s in cfg.pattern if s.ffn == "moe")
    return int(count_params(cfg) - n_moe_layers * inactive_per_moe)
