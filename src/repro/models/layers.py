"""Core layers: RMSNorm, RoPE, GQA attention (train/prefill/decode), MLP.

Functional style: params are plain dict pytrees; every layer exposes
  defs()  -> pytree of ParamDef (shape + init scale + logical sharding spec)
  apply() -> forward

Attention uses dense scores for short sequences and a query-chunked exact
attention (lax.scan over query blocks) beyond `CHUNK_THRESHOLD` so 32k+
prefill never materializes an S x S score matrix (the XLA-native
flash-attention pattern; the Pallas kernel in kernels/attention is the
TPU-tiled equivalent for the same math).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import ModelConfig

Array = jax.Array

CHUNK_THRESHOLD = 8192
QUERY_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str], ...]          # logical sharding per dim
    scale: float = 1.0                       # stddev multiplier (0 => zeros)
    dtype: str = "float32"
    fan_in: Optional[int] = None             # contraction size (default dim 0)

    def zeros_like(self):
        return jnp.zeros(self.shape, self.dtype)


def init_param(key, d: ParamDef):
    if d.scale == 0.0:
        return jnp.zeros(d.shape, d.dtype)
    fan_in = d.fan_in or d.shape[0]
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_tree(key, defs):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [init_param(k, d) for k, d in zip(keys, leaves)])


def abstract_tree(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def spec_tree(defs):
    return jax.tree.map(
        lambda d: d.spec, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_defs(d_model: int):
    return {"scale": ParamDef((d_model,), (None,), scale=0.0)}


def rmsnorm(params, x: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA; optional sliding window)
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig):
    d, h, k = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, h, hd), ("fsdp", "tp", None)),
        "wk": ParamDef((d, k, hd), ("fsdp", "tp", None)),
        "wv": ParamDef((d, k, hd), ("fsdp", "tp", None)),
        "wo": ParamDef((h, hd, d), ("tp", None, "fsdp"), fan_in=h * hd),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("tp", None), scale=0.0)
        defs["bk"] = ParamDef((k, hd), ("tp", None), scale=0.0)
        defs["bv"] = ParamDef((k, hd), ("tp", None), scale=0.0)
    return defs


def _qkv(params, cfg: ModelConfig, x: Array, positions: Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: Array, k_pos: Array, window: Optional[int]) -> Array:
    """(..., Sq, Sk) additive mask: causal + optional sliding window."""
    ok = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa(q: Array, k: Array, v: Array, bias: Array, n_groups: int) -> Array:
    """q: (B,Sq,H,hd); k,v: (B,Sk,K,hd); bias (B?,Sq,Sk).

    GQA via broadcast-repeat of the KV heads to the full head count: under
    tensor parallelism the repeat is local to each head shard (replicated KV
    expands into the sharded H dim with no communication), whereas the
    reshape-into-groups formulation loses the head sharding through the
    reshape and makes GSPMD reshard every layer."""
    b, sq, h, hd = q.shape
    if n_groups > 1:
        k = jnp.repeat(k, n_groups, axis=2)
        v = jnp.repeat(v, n_groups, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + bias[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v)


def attention_with_kv(params, cfg: ModelConfig, x: Array, positions: Array,
                      rules=None) -> Tuple[Array, Array, Array]:
    """Causal GQA self-attention; returns (out, k, v) so prefill can cache."""
    b, s, _ = x.shape
    n_groups = cfg.num_heads // cfg.num_kv_heads
    q, k, v = _qkv(params, cfg, x, positions)
    if rules is not None:
        q = rules.constrain(q, "dp", None, "tp", None)
        k = rules.constrain(k, "dp", None, "tp", None)
        v = rules.constrain(v, "dp", None, "tp", None)

    if s <= CHUNK_THRESHOLD:
        bias = _mask_bias(positions, positions, cfg.sliding_window)
        out = _sdpa(q, k, v, bias, n_groups)
    else:
        # Query-chunked exact attention: never materialize (S, S).
        s_pad = -(-s // QUERY_CHUNK) * QUERY_CHUNK
        qp, pp = q, positions
        if s_pad != s:  # e.g. VLM prompts: text + image tokens
            qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
            # pad with the last position (valid bias row); output is sliced
            pp = jnp.concatenate(
                [positions] + [positions[:, -1:]] * (s_pad - s), axis=1
            )
        nq = s_pad // QUERY_CHUNK
        qc = qp.reshape(b, nq, QUERY_CHUNK, cfg.num_kv_heads * n_groups,
                        cfg.resolved_head_dim).transpose(1, 0, 2, 3, 4)
        pc = pp.reshape(b, nq, QUERY_CHUNK).transpose(1, 0, 2)

        def chunk_fn(carry, xs):
            qi, pi = xs
            bias = _mask_bias(pi, positions, cfg.sliding_window)
            oi = _sdpa(qi, k, v, bias, n_groups)
            return carry, oi

        _, out = lax.scan(chunk_fn, None, (qc, pc))
        out = out.transpose(1, 0, 2, 3, 4).reshape(
            b, s_pad, cfg.num_heads, cfg.resolved_head_dim
        )[:, :s]

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    if rules is not None:
        out = rules.constrain(out, "dp", "sp", None)
    return out, k, v


def attention(params, cfg: ModelConfig, x: Array, positions: Array,
              rules=None) -> Array:
    """Training / prefill self-attention (causal, GQA, optional SWA)."""
    out, _, _ = attention_with_kv(params, cfg, x, positions, rules)
    return out


# -- decode path ------------------------------------------------------------

def attention_decode(params, cfg: ModelConfig, x: Array,
                     cache_k: Array, cache_v: Array, cur_len: Array,
                     rules=None):
    """One-token decode. x: (B, 1, d); cache_*: (B, S_alloc, K, hd).

    With sliding-window attention the cache is a RING BUFFER of the window
    size (S_alloc = min(S_max, window)): slot i holds the newest absolute
    position p_i = cur_len - ((cur_len - i) mod S_alloc), which is exactly
    the SWA-visible set — 500k-token decode with a 4096-deep cache.

    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    n_groups = cfg.num_heads // cfg.num_kv_heads
    s_alloc = cache_k.shape[1]
    positions = jnp.full((b, 1), cur_len, dtype=jnp.int32)
    q, k, v = _qkv(params, cfg, x, positions)
    ring = cfg.sliding_window is not None
    slot = (cur_len % s_alloc) if ring else cur_len
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1
    )
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1
    )
    idx = jnp.arange(s_alloc, dtype=jnp.int32)
    if ring:
        k_pos = cur_len - jnp.mod(cur_len - idx, s_alloc)
        valid = (k_pos >= 0) & (k_pos > cur_len - cfg.sliding_window)
    else:
        k_pos = idx
        valid = k_pos <= cur_len
    valid = jnp.broadcast_to(valid, (b, s_alloc))
    bias = jnp.where(valid, 0.0, -jnp.inf).astype(jnp.float32)[:, None, :]
    out = _sdpa(q, cache_k.astype(x.dtype), cache_v.astype(x.dtype),
                bias, n_groups)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("fsdp", "tp")),
            "w_up": ParamDef((d, f), ("fsdp", "tp")),
            "w_down": ParamDef((f, d), ("tp", "fsdp")),
        }
    return {
        "w_up": ParamDef((d, f), ("fsdp", "tp")),
        "w_down": ParamDef((f, d), ("tp", "fsdp")),
    }


def mlp(params, cfg: ModelConfig, x: Array, rules=None) -> Array:
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
        h = h * (x @ params["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype))
    if rules is not None:
        h = rules.constrain(h, "dp", None, "tp")
    out = h @ params["w_down"].astype(x.dtype)
    if rules is not None:
        out = rules.constrain(out, "dp", "sp", None)
    return out
