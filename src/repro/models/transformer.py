"""Full model: embeddings + scanned block stack + heads; train/prefill/decode.

Scan-over-layers: parameters of each pattern-repeat are stacked on a leading
`repeats` axis and the stack is driven by lax.scan — one copy of the layer
HLO regardless of depth (compile time matters: the dry run compiles 40+
cells on one CPU core). Heterogeneous stacks (jamba) scan over homogeneous
*super-blocks* (the pattern), see config.py.

Modality frontends (VLM / audio) are stubs per the assignment: `input_specs`
delivers precomputed patch/frame embeddings; the projector (the only trained
frontend piece) is real.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import ShardingRules, tree_shardings
from . import layers as L
from .config import ModelConfig, SubLayer
from .moe import moe, moe_defs
from .ssm import SSMCache, ssm_block, ssm_cache_defs, ssm_defs, ssm_dims

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _sublayer_defs(cfg: ModelConfig, sub: SubLayer) -> Dict:
    defs: Dict[str, Any] = {"norm_mix": L.rmsnorm_defs(cfg.d_model)}
    if sub.kind == "attn":
        defs["attn"] = L.attention_defs(cfg)
    else:
        defs["ssm"] = ssm_defs(cfg)
    if sub.ffn != "none":
        defs["norm_ffn"] = L.rmsnorm_defs(cfg.d_model)
        if sub.ffn == "mlp":
            defs["mlp"] = L.mlp_defs(cfg)
        else:
            defs["moe"] = moe_defs(cfg)
    return defs


def _stack_defs(defs: PyTree, repeats: int) -> PyTree:
    return jax.tree.map(
        lambda d: L.ParamDef((repeats, *d.shape), (None, *d.spec),
                             scale=d.scale, dtype=d.dtype),
        defs, is_leaf=lambda x: isinstance(x, L.ParamDef),
    )


def model_defs(cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    defs: Dict[str, Any] = {}
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        # K codebook embedding tables, summed at input (MusicGen)
        k = cfg.frontend.num_positions
        defs["embed"] = L.ParamDef((k, cfg.vocab_size, d), (None, "tp", "fsdp"),
                                   fan_in=d)
        defs["head"] = L.ParamDef((k, d, cfg.vocab_size), (None, "fsdp", "tp"),
                                  fan_in=d)
    else:
        defs["embed"] = L.ParamDef((cfg.vocab_size, d), ("tp", "fsdp"),
                                   fan_in=d)
        if not cfg.tie_embeddings:
            defs["head"] = L.ParamDef((d, cfg.vocab_size), ("fsdp", "tp"))
    if cfg.frontend is not None and cfg.frontend.modality == "vision":
        df = cfg.frontend.d_frontend
        defs["projector"] = {
            "w1": L.ParamDef((df, d), ("fsdp", "tp")),
            "norm": L.rmsnorm_defs(df),
            "w2": L.ParamDef((d, d), ("tp", "fsdp")),
        }
    block = {
        f"sub_{i}": _sublayer_defs(cfg, s) for i, s in enumerate(cfg.pattern)
    }
    defs["blocks"] = _stack_defs(block, cfg.repeats)
    defs["final_norm"] = L.rmsnorm_defs(d)
    return defs


def init_params(cfg: ModelConfig, key) -> PyTree:
    return L.init_tree(key, model_defs(cfg))


def abstract_params(cfg: ModelConfig) -> PyTree:
    return L.abstract_tree(model_defs(cfg))


def param_shardings(cfg: ModelConfig, rules: ShardingRules) -> PyTree:
    return tree_shardings(rules, model_defs(cfg))


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _gather_block_params(p_block, cfg: ModelConfig, rules):
    """ZeRO-3 gather-at-use: re-constrain each block weight to its spec with
    the `fsdp` dim replicated. Without this, GSPMD resolves a d-sharded
    contraction by ALL-REDUCING the (much larger) activations over the data
    axis — measured 134 MB f32 per matmul per layer on yi-6b before the fix
    (EXPERIMENTS.md §Perf iteration 1). The constraint makes XLA all-gather
    the bf16 weights instead; gradients reduce-scatter back automatically
    when accumulated into the fsdp-sharded grad buffers."""
    if (rules is None or rules.mesh is None or not rules.fsdp
            or not rules.zero3_gather):
        return p_block
    block_defs = {
        f"sub_{i}": _sublayer_defs(cfg, s) for i, s in enumerate(cfg.pattern)
    }

    def gather(w, d):
        spec = tuple(None if s == "fsdp" else s for s in d.spec)
        return rules.constrain(w, *spec)

    out = {}
    for sub_key, sub_defs in block_defs.items():
        sub_p = p_block[sub_key]
        new_sub = {}
        for name, d_sub in sub_defs.items():
            if name == "moe" and not rules.gather_moe_experts:
                # Expert parallelism: the routed expert weights stay sharded
                # on the model axis; only the router (+ shared expert, which
                # every token uses) is gathered.
                new_sub[name] = dict(sub_p[name])
                for small in ("router", "shared"):
                    if small in sub_p[name]:
                        new_sub[name][small] = jax.tree.map(
                            gather, sub_p[name][small], d_sub[small],
                            is_leaf=lambda x: isinstance(x, L.ParamDef),
                        )
            else:
                new_sub[name] = jax.tree.map(
                    gather, sub_p[name], d_sub,
                    is_leaf=lambda x: isinstance(x, L.ParamDef),
                )
        out[sub_key] = new_sub
    return out


def _gather_head_params(params, cfg: ModelConfig, rules):
    """Same gather-at-use for embed/head: a d-sharded head contraction would
    otherwise all-reduce the full logits tensor over the data axis."""
    if (rules is None or rules.mesh is None or not rules.fsdp
            or not rules.zero3_gather):
        return params
    defs = model_defs(cfg)
    out = dict(params)
    for key in ("embed", "head", "projector"):
        if key in params:
            def gather(w, d):
                spec = tuple(None if s == "fsdp" else s for s in d.spec)
                return rules.constrain(w, *spec)
            out[key] = jax.tree.map(
                gather, params[key], defs[key],
                is_leaf=lambda x: isinstance(x, L.ParamDef),
            )
    return out


def _apply_sublayer(p, cfg: ModelConfig, sub: SubLayer, x: Array,
                    positions: Array, rules) -> Tuple[Array, Array]:
    h = L.rmsnorm(p["norm_mix"], x, cfg.rms_eps)
    if sub.kind == "attn":
        x = x + L.attention(p["attn"], cfg, h, positions, rules)
    else:
        out, _ = ssm_block(p["ssm"], cfg, h, rules)
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    if sub.ffn != "none":
        h = L.rmsnorm(p["norm_ffn"], x, cfg.rms_eps)
        if sub.ffn == "mlp":
            x = x + L.mlp(p["mlp"], cfg, h, rules)
        else:
            out, aux = moe(p["moe"], cfg, h, rules)
            x = x + out
    return x, aux


def _block(p_block, cfg: ModelConfig, x: Array, positions: Array,
           rules) -> Tuple[Array, Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for i, sub in enumerate(cfg.pattern):
        x, aux = _apply_sublayer(p_block[f"sub_{i}"], cfg, sub, x,
                                 positions, rules)
        aux_total = aux_total + aux
    return x, aux_total


def _run_blocks(params, cfg: ModelConfig, x: Array, positions: Array,
                rules, remat: bool) -> Tuple[Array, Array]:
    def block(p, h):
        p = _gather_block_params(p, cfg, rules)
        return _block(p, cfg, h, positions, rules)

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )

    def scan_fn(carry, p_block):
        h, aux = carry
        h, a = block(p_block, h)
        return (h, aux + a), None

    (x, aux), _ = lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    return x, aux


# ---------------------------------------------------------------------------
# Input embedding (incl. modality stubs)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, Array],
                 rules) -> Array:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        # tokens: (B, K, S) over K codebooks -> summed embeddings
        tok = batch["tokens"]
        emb = params["embed"]
        x = sum(
            jnp.take(emb[i], tok[:, i], axis=0)
            for i in range(cfg.frontend.num_positions)
        ).astype(dtype)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dtype)
    if (cfg.frontend is not None and cfg.frontend.modality == "vision"
            and "patch_embeds" in batch):  # prefill/train only; decode is text
        pe = batch["patch_embeds"].astype(dtype)         # (B, S_img, d_front)
        pr = params["projector"]
        h = L.rmsnorm(pr["norm"], pe, cfg.rms_eps)
        h = jax.nn.gelu(h @ pr["w1"].astype(dtype)) @ pr["w2"].astype(dtype)
        x = jnp.concatenate([h, x], axis=1)              # image tokens first
    if rules is not None:
        x = rules.constrain(x, "dp", "sp", None)
    return x


def _logits(params, cfg: ModelConfig, x: Array) -> Array:
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        return jnp.einsum("bsd,kdv->bskv", x, params["head"].astype(x.dtype))
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return x @ head.astype(x.dtype)


# ---------------------------------------------------------------------------
# Training forward (loss)
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array],
            rules: Optional[ShardingRules] = None,
            remat: bool = True) -> Tuple[Array, Dict[str, Array]]:
    """Next-token cross entropy. batch: tokens (B,S) [or (B,K,S) audio],
    labels (same shape), optional patch_embeds. Image positions (VLM) are
    excluded from the loss."""
    params = _gather_head_params(params, cfg, rules)
    x = embed_inputs(params, cfg, batch, rules)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux = _run_blocks(params, cfg, x, positions, rules, remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = _logits(params, cfg, x).astype(jnp.float32)

    labels = batch["labels"]
    if cfg.frontend is not None and cfg.frontend.modality == "vision":
        n_img = s - labels.shape[-1]
        logits = logits[:, n_img:]
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        labels = jnp.moveaxis(labels, 1, 2)              # (B, S, K)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Stacked (repeats, ...) caches per sub-layer; entries may be None."""
    attn_k: Dict
    attn_v: Dict
    ssm: Dict


def cache_alloc_len(cfg: ModelConfig, s_max: int) -> int:
    """SWA archs keep a ring buffer of the window size (see attention_decode)."""
    if cfg.sliding_window is not None:
        return min(s_max, cfg.sliding_window)
    return s_max


def cache_specs(cfg: ModelConfig, batch: int, s_max: int,
                rules: Optional[ShardingRules] = None,
                shard_seq: bool = False):
    """Abstract cache (ShapeDtypeStructs) + shardings for decode.

    shard_seq: shard the KV sequence dim over the data axis — the long_500k
    layout (batch=1 cannot use data parallelism; the cache is what must be
    distributed instead: sequence parallelism over the KV cache)."""
    hd = cfg.resolved_head_dim
    r = cfg.repeats
    s_alloc = cache_alloc_len(cfg, s_max)
    attn_k, attn_v, ssm_c = {}, {}, {}
    for i, sub in enumerate(cfg.pattern):
        key = f"sub_{i}"
        if sub.kind == "attn":
            shape = (r, batch, s_alloc, cfg.num_kv_heads, hd)
            cdt = jnp.dtype(cfg.dtype)
            attn_k[key] = jax.ShapeDtypeStruct(shape, cdt)
            attn_v[key] = jax.ShapeDtypeStruct(shape, cdt)
        else:
            c = ssm_cache_defs(cfg, batch)
            ssm_c[key] = SSMCache(
                conv=jax.ShapeDtypeStruct((r, *c.conv.shape), c.conv.dtype),
                state=jax.ShapeDtypeStruct((r, *c.state.shape), c.state.dtype),
            )
    cache = DecodeCache(attn_k, attn_v, ssm_c)
    if rules is None:
        return cache
    seq_ax = "dp" if shard_seq else None
    shardings = DecodeCache(
        jax.tree.map(lambda x: rules.sharding_for_shape(
            x.shape, None, "dp", seq_ax, "tp", None), attn_k),
        jax.tree.map(lambda x: rules.sharding_for_shape(
            x.shape, None, "dp", seq_ax, "tp", None), attn_v),
        jax.tree.map(
            lambda x: (
                rules.sharding_for_shape(x.shape, None, "dp", None, "tp")
                if len(x.shape) == 4                       # conv (r,B,w,C)
                else rules.sharding_for_shape(
                    x.shape, None, "dp", "tp", None, None)  # state
            ),
            ssm_c,
        ),
    )
    return cache, shardings


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> DecodeCache:
    abs_cache = cache_specs(cfg, batch, s_max)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)


def decode_step(params, cfg: ModelConfig, cache: DecodeCache,
                tokens: Array, cur_len: Array,
                rules: Optional[ShardingRules] = None):
    """One decode step. tokens: (B, 1) [or (B, K, 1) audio].

    Returns (logits, new_cache)."""
    params = _gather_head_params(params, cfg, rules)
    batch = {"tokens": tokens}
    x = embed_inputs(params, cfg, batch, rules)
    b = x.shape[0]
    positions = jnp.full((b, 1), cur_len, dtype=jnp.int32)

    def scan_fn(carry, xs):
        h = carry
        p_block, ck, cv, cs = xs
        p_block = _gather_block_params(p_block, cfg, rules)
        if rules is not None and rules.decode_feature_shard:
            h = rules.constrain(h, "dp", None, "fsdp")
        for i, sub in enumerate(cfg.pattern):
            key = f"sub_{i}"
            p = p_block[key]
            hn = L.rmsnorm(p["norm_mix"], h, cfg.rms_eps)
            if sub.kind == "attn":
                out, ck[key], cv[key] = L.attention_decode(
                    p["attn"], cfg, hn, ck[key], cv[key], cur_len, rules
                )
                h = h + out
            else:
                out, cs[key] = ssm_block(p["ssm"], cfg, hn, rules,
                                         cache=cs[key])
                h = h + out
            if sub.ffn != "none":
                hn = L.rmsnorm(p["norm_ffn"], h, cfg.rms_eps)
                if sub.ffn == "mlp":
                    h = h + L.mlp(p["mlp"], cfg, hn, rules)
                else:
                    out, _ = moe(p["moe"], cfg, hn, rules)
                    h = h + out
        return h, (ck, cv, cs)

    xs = (params["blocks"], cache.attn_k, cache.attn_v, cache.ssm)
    x, caches = lax.scan(scan_fn, x, xs)
    new_cache = DecodeCache(*caches)
    x = L.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = _logits(params, cfg, x)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, batch: Dict[str, Array],
            rules: Optional[ShardingRules] = None):
    """Process a full prompt; returns (last-position logits, cache).

    The cache covers the prompt span (decode then extends its own cache);
    the prefill_32k dry-run cell lowers exactly this function.
    """
    params = _gather_head_params(params, cfg, rules)
    x = embed_inputs(params, cfg, batch, rules)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def scan_fn(h, p_block):
        p_block = _gather_block_params(p_block, cfg, rules)
        ck, cv, cs = {}, {}, {}
        for i, sub in enumerate(cfg.pattern):
            key = f"sub_{i}"
            p = p_block[key]
            hn = L.rmsnorm(p["norm_mix"], h, cfg.rms_eps)
            if sub.kind == "attn":
                out, k, v = L.attention_with_kv(p["attn"], cfg, hn,
                                                positions, rules)
                ck[key] = k.astype(jnp.dtype(cfg.dtype))
                cv[key] = v.astype(jnp.dtype(cfg.dtype))
                h = h + out
            else:
                out, cs[key] = ssm_block(p["ssm"], cfg, hn, rules,
                                         return_cache=True)
                h = h + out
            if sub.ffn != "none":
                hn = L.rmsnorm(p["norm_ffn"], h, cfg.rms_eps)
                if sub.ffn == "mlp":
                    h = h + L.mlp(p["mlp"], cfg, hn, rules)
                else:
                    out, _ = moe(p["moe"], cfg, hn, rules)
                    h = h + out
        return h, (ck, cv, cs)

    x, caches = lax.scan(scan_fn, x, params["blocks"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.rms_eps)
    logits = _logits(params, cfg, x)
    return logits[:, -1], DecodeCache(*caches)
