"""Sharded projection/volume I/O (DESIGN.md §7): the shard-level array
store, the pipeline's ProjectionSource/VolumeSink endpoints, and the
StoreError corruption signal."""
from .shard_store import (  # noqa: F401
    HostShardedArray, StoreError, load_array, open_count, read_manifest,
    read_region, reset_open_count, save_array, snapshot, stored_spec,
)
from .streams import (  # noqa: F401
    AsyncWriteback, PrefetchError, ProjectionSource, SourcePrefetcher,
    VolumeSink,
)
