"""Projection/volume endpoints of the reconstruction pipeline (paper Fig. 3).

The paper's rank does not receive projections from the caller — it *loads*
its N_p/(R*C) slice from the parallel filesystem, and it does not return its
slab — it *stores* it. These two endpoints wrap the shard store
(shard_store.py) in pipeline terms:

  ProjectionSource  a projection shard store feeding the plan engine's
                    filter stage: `load(mesh)` scatter-reads exactly the
                    shards that overlap each rank's `input_sharding(mesh)`
                    slice (Eq. 5 load split) and returns the sharded device
                    array the engine consumes. With `codec=` at write time
                    the store persists the stream codec's WIRE format —
                    quantized shards plus, for scaled codecs (fp8), a
                    per-projection f32 scale sidecar store at
                    `<path>/scales` — and `load` decodes back to f32;
                    `load_encoded` returns the wire-format pair verbatim
                    (bit-exact round-trip, see tests/test_shard_store.py).
  VolumeSink        the paper's PFS store: `write(volume)` streams each
                    rank's slab (each addressable shard of the engine's
                    output — x over `model`, plus y over `data` with a
                    scatter reduce) to its own file.

Both are wired as optional `source=` / `sink=` stages on
`ReconstructionPlan.build()` (core/plan.py), closing the pipeline:

    src = ProjectionSource.write(dir_in, projections, chunks=(n_ranks, 1, 1))
    fdk = plan.build(source=src, sink=VolumeSink(dir_out))
    volume = fdk()          # load -> filter -> gather -> BP -> reduce -> store
"""
from __future__ import annotations

import os
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from functools import lru_cache
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Precision, resolve_precision
from repro.obs import metrics as _metrics
from repro.obs.trace import get_tracer

from . import shard_store

# Sub-store holding the per-projection f32 scale sidecar of an encoded
# projection store (sibling of the data store's `shards/` directory).
SCALES_DIR = "scales"


@lru_cache(maxsize=None)
def _jit_decode(codec_name: str):
    """One jitted decode per codec, cached for the process: `load()` used to
    wrap `codec.decode` in a fresh `jax.jit` per call, retracing on every
    load. jit's own signature cache handles distinct input shapes (deltas of
    different sizes) under the one cached callable."""
    return jax.jit(Precision(codec_name).codec.decode)


class ProjectionSource:
    """Projections stored shard-per-file (raw f32, or a stream codec's wire
    format + scale sidecar), restorable onto any mesh."""

    def __init__(self, path: str):
        self.path = path
        self._consumed: set = set()   # shard files already folded (poll API)

    @classmethod
    def write(cls, path: str, projections,
              chunks: Optional[Sequence[int]] = None,
              codec: "Precision | str | None" = None) -> "ProjectionSource":
        """Lay projections down as a shard store. For a device array the
        files follow its sharding; for a host array pass e.g.
        ``chunks=(n_ranks, 1, 1)`` for the paper's slice-per-rank layout.

        `codec` (a storage-precision name, e.g. "fp8_e4m3") persists the
        stream codec's wire format instead of the input dtype: the data
        store holds the quantized shards (its manifest records the codec),
        and scaled codecs add a `<path>/scales` sidecar store with one f32
        scale per projection — fp8 shrinks the on-disk stream to a quarter
        of f32, the same trade the AllGather makes.
        """
        if codec is None:
            shard_store.save_array(path, projections, chunks=chunks)
            return cls(path)
        prec = resolve_precision(codec)
        data, scales = prec.codec.encode(jnp.asarray(projections))
        shard_store.save_array(path, data, chunks=chunks,
                               extra_manifest={"codec": prec.storage})
        if scales is not None:
            shard_store.save_array(os.path.join(path, SCALES_DIR),
                                   np.asarray(scales),
                                   chunks=None if chunks is None
                                   else chunks[:1])
        return cls(path)

    @property
    def shape(self) -> tuple:
        return tuple(shard_store.read_manifest(self.path)["shape"])

    @property
    def dtype(self) -> np.dtype:
        return shard_store.dtype_from_name(
            shard_store.read_manifest(self.path)["dtype"])

    @property
    def codec_name(self) -> Optional[str]:
        """Storage codec the store was encoded with (None = raw store)."""
        return shard_store.read_manifest(self.path).get("codec")

    def load_encoded(self):
        """The stored wire-format pair (data, scales) as host arrays —
        verbatim bytes, no decode. scales is None for raw/scale-free
        stores. The bit-exact-round-trip accessor."""
        data = shard_store.load_array(self.path)
        spath = os.path.join(self.path, SCALES_DIR)
        scales = (shard_store.load_array(spath)
                  if os.path.exists(os.path.join(spath,
                                                 shard_store.MANIFEST))
                  else None)
        return data, scales

    def load(self, mesh=None) -> jax.Array:
        """Scatter-read the projections for `mesh` (each rank's slice of the
        leading projection axis); the whole array on one device if None.
        Encoded stores are decoded back to f32 (quantized data x scale
        sidecar) after the scatter read — each rank only ever reads and
        dequantizes its own slice of the wire bytes."""
        codec_name = self.codec_name
        if mesh is None:
            if codec_name is None:
                return jax.device_put(shard_store.load_array(self.path))
            data, scales = self.load_encoded()
            return _jit_decode(codec_name)(
                jnp.asarray(data),
                None if scales is None else jnp.asarray(scales))
        from jax.sharding import NamedSharding
        from repro.core.distributed import _proj_spec, input_sharding

        sharding = input_sharding(mesh)
        data = shard_store.load_array(self.path, sharding)
        if codec_name is None:
            return data
        scales = None
        spath = os.path.join(self.path, SCALES_DIR)
        if os.path.exists(os.path.join(spath, shard_store.MANIFEST)):
            # The sidecar is sharded along the projection axis exactly like
            # the data (one scale per projection): each rank scatter-reads
            # only its own slice, not the whole sidecar.
            scales = shard_store.load_array(
                spath, NamedSharding(mesh, _proj_spec(mesh)))
        return _jit_decode(codec_name)(data, scales)

    # -- streaming discovery (the instant-CT source side) -------------------

    def poll(self) -> list:
        """Diff the store's (growing) manifest against what this source has
        already handed out: the contiguous [lo, hi) angle ranges of newly
        COMMITTED shards, sorted by lo. Read-only — ranges are marked
        consumed by `iter_deltas`, so repeated polls keep reporting a range
        until it is actually loaded. A store whose manifest does not exist
        yet (scanner not started) reports no deltas."""
        try:
            m = shard_store.read_manifest(self.path)
        except shard_store.StoreError:
            return []
        dtype = shard_store.dtype_from_name(m["dtype"])
        ready = []
        for entry in m["shards"]:
            if entry["file"] in self._consumed:
                continue
            idx = tuple(tuple(b) for b in entry["index"])
            fpath = os.path.join(self.path, shard_store.SHARD_DIR,
                                 entry["file"])
            # The manifest entry is the writer's commit point
            # (shard_store.append_region); the size check just refuses to
            # hand out a range whose bytes a non-protocol writer truncated.
            expected = dtype.itemsize
            for lo, hi in idx:
                expected *= hi - lo
            if (not os.path.exists(fpath)
                    or os.path.getsize(fpath) != expected):
                continue
            ready.append((idx[0][0], idx[0][1], entry["file"]))
        ready.sort()
        return [(lo, hi) for lo, hi, _ in ready]

    def load_slice(self, lo: int, hi: int, mesh=None) -> jax.Array:
        """Load + decode the angle range [lo, hi) only: the region read
        opens just the shard files (and sidecar shards) intersecting it.
        With a mesh the delta lands sharded with `input_sharding(mesh)` —
        ready for `IncrementalSession.update`."""
        shape = self.shape
        region = ((lo, hi),) + tuple((0, d) for d in shape[1:])
        data = shard_store.read_region(self.path, region)
        codec_name = self.codec_name
        scales = None
        if codec_name is not None:
            spath = os.path.join(self.path, SCALES_DIR)
            if os.path.exists(os.path.join(spath, shard_store.MANIFEST)):
                scales = jnp.asarray(
                    shard_store.read_region(spath, ((lo, hi),)))
        if mesh is not None:
            from repro.core.distributed import input_sharding
            data = jax.device_put(data, input_sharding(mesh))
        else:
            data = jnp.asarray(data)
        if codec_name is None:
            return data
        return _jit_decode(codec_name)(data, scales)

    def iter_deltas(self, mesh=None
                    ) -> Iterator[Tuple[int, int, jax.Array]]:
        """Consume newly committed deltas: yields (lo, hi, projections) for
        each range `poll()` discovers, decoded and (on a mesh) sharded, and
        marks it consumed — the discovery protocol IncrementalSession.poll
        drives. Yields nothing when the scanner has not committed anything
        new."""
        try:
            m = shard_store.read_manifest(self.path)
        except shard_store.StoreError:
            return
        by_range = {
            (tuple(e["index"][0][:2])): e["file"] for e in m["shards"]}
        for lo, hi in self.poll():
            delta = self.load_slice(lo, hi, mesh)
            # Mark consumed BEFORE yielding: the delta is fully loaded by
            # now, and a consumer that breaks (or errors) after receiving
            # it closes this generator — marking after the yield would
            # never run, so the already-folded range would be re-reported
            # by the next poll() and trip the session's overlap rejection.
            # A load_slice failure still leaves the range unconsumed
            # (retryable).
            self._consumed.add(by_range[(lo, hi)])
            yield lo, hi, delta


class StreamingProjectionWriter:
    """The scanner side of the streaming protocol: append projection deltas
    to a growing store that `ProjectionSource.poll()` discovers.

    Commit ordering (PFS-safe, see shard_store.append_region): for scaled
    codecs the scale sidecar lands and commits FIRST, then the data shard —
    whose manifest entry is the overall commit point. A reader that sees a
    committed data range is therefore guaranteed its scales are readable;
    a crash between the two leaves only an orphaned sidecar entry, which no
    reader ever addresses.

        writer = StreamingProjectionWriter(path, (N_p, N_v, N_u),
                                           codec="fp8_e4m3")
        writer.append(frames, lo)            # one scanner burst
        ...
        src = ProjectionSource(path)         # reader, possibly another host
        for lo, hi, delta in src.iter_deltas(mesh): session.update(...)
    """

    def __init__(self, path: str, shape: Sequence[int],
                 codec: "Precision | str | None" = None):
        if len(shape) != 3:
            raise ValueError(f"projection stream shape must be "
                             f"(N_p, N_v, N_u), got {tuple(shape)}")
        self.path = path
        self.shape = tuple(shape)
        self._prec = None if codec is None else resolve_precision(codec)
        extra = ({"codec": self._prec.storage}
                 if self._prec is not None else None)
        dtype = (np.float32 if self._prec is None
                 else self._prec.storage_dtype)
        shard_store.init_store(path, self.shape, dtype, extra_manifest=extra)
        if self._prec is not None and self._prec.codec.has_scales:
            shard_store.init_store(os.path.join(path, SCALES_DIR),
                                   self.shape[:1], np.float32)

    def append(self, projections, lo: int) -> Tuple[int, int]:
        """Commit the contiguous angle range [lo, lo + n) (encoding it
        first when the store carries a codec). Returns (lo, hi)."""
        projections = np.asarray(projections)
        n, n_v, n_u = projections.shape
        hi = lo + n
        if (n_v, n_u) != self.shape[1:] or hi > self.shape[0]:
            raise ValueError(
                f"delta [{lo}, {hi}) x ({n_v}, {n_u}) does not fit the "
                f"declared stream shape {self.shape}")
        region = ((lo, hi), (0, n_v), (0, n_u))
        if self._prec is None:
            shard_store.append_region(self.path, region, projections)
            return lo, hi
        data, scales = self._prec.codec.encode(jnp.asarray(projections))
        if scales is not None:   # sidecar first — see commit ordering above
            shard_store.append_region(os.path.join(self.path, SCALES_DIR),
                                      ((lo, hi),), np.asarray(scales))
        shard_store.append_region(self.path, region, np.asarray(data))
        return lo, hi


# Manifest key recording a non-canonical stored volume layout (VolumeSink).
LAYOUT_KEY = "layout"


class VolumeSink:
    """Slice-per-rank volume store: each shard of the reconstructed volume
    goes straight to its own file — no gather, no root writer."""

    def __init__(self, path: str):
        self.path = path

    def write(self, volume, layout: Optional[dict] = None) -> str:
        """Write the (sharded) volume; returns the store directory.

        `layout` records a NON-canonical engine layout in the manifest so
        `read()` can restore the canonical (N_x, N_y, N_z) volume — the
        chunked+scatter engine streams its internal 4-D
        (N_x, y_chunks, N_y/y_chunks, N_z) accumulator layout, recorded as
        ``{"kind": "y_chunk_major", "y_chunks": int}``. Without the record
        a reader had no way to tell the store was not a plain volume."""
        extra = None if layout is None else {LAYOUT_KEY: layout}
        return shard_store.save_array(self.path, volume,
                                      extra_manifest=extra)

    def layout(self) -> Optional[dict]:
        """The recorded engine layout, or None for a canonical store."""
        return shard_store.read_manifest(self.path).get(LAYOUT_KEY)

    def read(self, sharding=None):
        """Read the stored volume back (host numpy, or scatter-read onto
        `sharding`), restoring the canonical (N_x, N_y, N_z) axis order
        when the manifest records a non-canonical engine layout. Device
        reads (`sharding=`) address the stored layout directly — resharding
        canonicalized data is the caller's concern."""
        arr = shard_store.load_array(self.path, sharding)
        layout = self.layout()
        if layout is None or sharding is not None:
            return arr
        kind = layout.get("kind")
        if kind != "y_chunk_major":
            raise shard_store.StoreError(
                f"volume store {self.path!r} records unknown layout "
                f"{kind!r}; cannot canonicalize")
        # (N_x, y_chunks, yc, N_z) -> (N_x, N_y, N_z): chunk-major y is
        # contiguous, a reshape restores the volume.
        n_x, y_chunks, yc, n_z = arr.shape
        return np.ascontiguousarray(arr).reshape(n_x, y_chunks * yc, n_z)

    def nbytes(self) -> int:
        """Stored payload size (shard files only, not the manifest)."""
        sdir = os.path.join(self.path, shard_store.SHARD_DIR)
        return sum(os.path.getsize(os.path.join(sdir, f))
                   for f in os.listdir(sdir))


# ---------------------------------------------------------------------------
# Inter-scan I/O overlap (repro/service): the paper overlaps filtering with
# back-projection *within* one scan; a serving loop lifts the same idea to
# the scan level — scan k+1's PFS reads and scan k-1's writes run on
# background threads while scan k computes. Device dispatch stays on the
# caller's thread; these helpers only move the host-side I/O off it.
# ---------------------------------------------------------------------------

class PrefetchError(RuntimeError):
    """A background load failed; raised on the consumer thread by
    `SourcePrefetcher.get` with the original exception as __cause__."""


class SourcePrefetcher:
    """Double-buffered background loader for a sequence of projection reads.

    jobs  : sequence of zero-arg callables, each returning one scan's
            projections (typically `lambda: source.load(mesh)` — a PFS
            scatter-read + decode). Jobs run IN ORDER on one worker thread.
    depth : how many loaded scans may sit ready ahead of the consumer
            (default 2 = classic double buffering: scan k+1 loads while
            scan k computes; memory stays bounded at `depth` scans).
    persistent : keep the worker alive after the initial jobs drain so
            `extend(jobs)` can feed it more work — the serve-loop mode
            (ReconstructionService.serve() runs ONE prefetcher across all
            drain passes instead of paying a thread spawn/join per pass).
            A persistent prefetcher only reaches DONE via `finish()` or
            `close()`; a one-shot one (the default) is finished at
            construction, exactly the pre-loop contract.

    State machine (DESIGN.md §Serving):

        IDLE --start()--> FILLING --queue full--> BLOCKED(producer)
        FILLING/BLOCKED --get()--> FILLING        consumer frees a slot
        persistent + jobs drained --> IDLE(worker) --extend()--> FILLING
        last job done after finish()/one-shot ctor --> DRAINING
            --get() x k--> DONE (StopIteration, LATCHED: every later
            get() raises StopIteration again instead of blocking on the
            empty queue forever)
        close() --> DONE (worker unblocked + joined; pending jobs
            abandoned; later get() raises StopIteration)
        job raises --> the error is queued in-order and re-raised by the
                       MATCHING get(); later jobs still run, so one bad
                       load fails only its own scan and the queue stays
                       positionally aligned (job k <-> get() k).

    Also iterable: ``for proj in SourcePrefetcher(jobs): ...``.
    """

    _DONE = object()

    def __init__(self, jobs: Sequence[Callable[[], object]] = (),
                 depth: int = 2, persistent: bool = False):
        if depth < 1:
            raise ValueError(f"prefetch depth={depth} must be >= 1")
        self._pending: "deque[Callable[[], object]]" = deque(jobs)
        self._jobs_cv = threading.Condition()
        self._no_more_jobs = not persistent   # one-shot: finished at ctor
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._started = False
        self._finished = False    # consumer-side latch: DONE was observed
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)

    def extend(self, jobs: Sequence[Callable[[], object]]) -> None:
        """Queue more load jobs on a `persistent` prefetcher (serve-loop
        reuse across drain passes). Raises on a finished/closed one —
        its worker is (or is about to be) gone."""
        with self._jobs_cv:
            if self._no_more_jobs or self._stop.is_set():
                raise RuntimeError(
                    "cannot extend a finished prefetcher (one-shot, "
                    "finish()ed, or closed)")
            self._pending.extend(jobs)
            self._jobs_cv.notify()

    def finish(self) -> None:
        """No more jobs are coming: after the pending ones drain, the
        worker queues DONE and exits (persistent mode's graceful end)."""
        with self._jobs_cv:
            self._no_more_jobs = True
            self._jobs_cv.notify()

    def _next_job(self):
        """Worker-side: the next job, or None when the prefetcher is done
        (stopped, or finished with nothing pending)."""
        with self._jobs_cv:
            while True:
                if self._stop.is_set():
                    return None
                if self._pending:
                    return self._pending.popleft()
                if self._no_more_jobs:
                    return None
                # persistent + idle: wait for extend()/finish()/close().
                # The timeout is a safety net against a lost notify.
                self._jobs_cv.wait(timeout=0.1)

    def _worker(self) -> None:
        # Metrics are re-fetched per job (not cached at start) so a
        # registry reset between drains cannot orphan the instruments.
        tracer = get_tracer()
        while True:
            job = self._next_job()
            if job is None:
                break
            try:
                with tracer.span("io.prefetch.load", timed=True) as sp:
                    item = (True, job())
                _metrics.counter("io.prefetch.loads").inc()
                _metrics.histogram("io.prefetch.load_seconds").observe(
                    sp.duration_s)
            except BaseException as e:  # re-raised on the consumer side
                item = (False, e)
                _metrics.counter("io.prefetch.errors").inc()
            if not self._put(item):
                break
        self._put((True, self._DONE))

    def _put(self, item) -> bool:
        """Blocking put that gives up when the consumer called close()."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                _metrics.gauge("io.prefetch.queue_depth").set(
                    self._q.qsize())
                return True
            except queue.Full:
                continue
        return False

    def start(self) -> "SourcePrefetcher":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def get(self):
        """Next loaded scan, blocking until the worker has it. Raises
        PrefetchError when that scan's load failed, StopIteration when all
        jobs are consumed — idempotently: exhaustion is latched, so calling
        get() again keeps raising StopIteration instead of deadlocking on
        the empty queue (the DONE sentinel is only ever queued once). get()
        after close() likewise raises StopIteration once the (abandoned)
        queue is drained."""
        self.start()
        if self._finished:
            raise StopIteration
        t0 = time.perf_counter()
        while True:
            try:
                ok, item = self._q.get(timeout=0.05)
                break
            except queue.Empty:
                # A closed prefetcher's worker may have died without
                # queueing DONE (close() makes _put give up); don't hang.
                if self._stop.is_set() and not self._thread.is_alive():
                    self._finished = True
                    raise StopIteration from None
        _metrics.gauge("io.prefetch.queue_depth").set(self._q.qsize())
        if item is not self._DONE:   # blocked-on-worker time, real items only
            _metrics.histogram("io.prefetch.wait_seconds").observe(
                time.perf_counter() - t0)
        if not ok:
            raise PrefetchError(
                f"background projection load failed: {item}") from item
        if item is self._DONE:
            self._finished = True
            raise StopIteration
        return item

    def __iter__(self):
        self.start()
        while True:
            try:
                yield self.get()
            except StopIteration:
                return

    def close(self) -> None:
        """Stop loading; pending jobs are abandoned (no partial results are
        handed out — even already-loaded ones still sitting in the queue)
        and later get() calls raise StopIteration."""
        self._stop.set()
        with self._jobs_cv:
            self._jobs_cv.notify()
        if self._started:
            self._thread.join(timeout=5.0)
        self._finished = True


class AsyncWriteback:
    """Write-behind executor for VolumeSink stores.

    `submit(sink, volume)` returns immediately after handing the finished
    (device) volume to a single-worker executor; the device->host transfer
    and the shard-per-file write happen off the compute thread, so scan
    k-1's store overlaps scan k's dispatch. Writes run in submission order
    (one worker). `pending` is bounded: submit blocks once more than
    `max_pending` volumes are in flight, so host memory stays bounded under
    a fast producer. `drain()` joins and re-raises the FIRST failed write
    (a serving loop must not ack scans whose stores failed silently).
    """

    def __init__(self, max_pending: int = 2):
        if max_pending < 1:
            raise ValueError(f"max_pending={max_pending} must be >= 1")
        self._max_pending = max_pending
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="volume-writeback")
        self._futures: List[Future] = []
        self._lock = threading.Lock()

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(not f.done() for f in self._futures)

    def submit(self, sink: VolumeSink, volume,
               layout: Optional[dict] = None) -> Future:
        """Queue `sink.write(volume, layout=)`; blocks only when the
        write-behind queue is full (backpressure, not fire-and-forget)."""
        while self.pending >= self._max_pending:
            # Wait on the oldest unfinished write (ordered single worker).
            with self._lock:
                oldest = next((f for f in self._futures if not f.done()),
                              None)
            if oldest is None:
                break
            try:
                oldest.result()
            except BaseException:
                pass  # surfaced by drain(); keep the queue moving

        def _counted_write():
            # Runs on the writeback worker thread: the span lands on its
            # own tid in the trace, visualizing store/compute overlap.
            t0 = time.perf_counter()
            try:
                with get_tracer().span("io.writeback.write"):
                    out = sink.write(volume, layout=layout)
            except BaseException:
                _metrics.counter("io.writeback.errors").inc()
                raise
            finally:
                _metrics.gauge("io.writeback.pending").set(self.pending)
            _metrics.counter("io.writeback.writes").inc()
            _metrics.histogram("io.writeback.write_seconds").observe(
                time.perf_counter() - t0)
            return out

        fut = self._pool.submit(_counted_write)
        with self._lock:
            # Prune completed-OK writes here, not only in drain(): callers
            # that result() the returned future directly (the service's
            # per-ticket join) would otherwise grow the list forever.
            # Failed futures are kept so drain() can still re-raise them.
            self._futures = [f for f in self._futures
                             if not f.done() or f.exception() is not None]
            self._futures.append(fut)
        _metrics.gauge("io.writeback.pending").set(self.pending)
        return fut

    def drain(self) -> int:
        """Wait for every queued write; returns how many completed OK and
        re-raises the first failure (subsequent writes still ran — the
        single worker never cancels queued work)."""
        with self._lock:
            futures, self._futures = self._futures, []
        first_err = None
        done = 0
        for f in futures:
            try:
                f.result()
                done += 1
            except BaseException as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err
        return done

    def close(self) -> None:
        self._pool.shutdown(wait=True)
