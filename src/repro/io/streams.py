"""Projection/volume endpoints of the reconstruction pipeline (paper Fig. 3).

The paper's rank does not receive projections from the caller — it *loads*
its N_p/(R*C) slice from the parallel filesystem, and it does not return its
slab — it *stores* it. These two endpoints wrap the shard store
(shard_store.py) in pipeline terms:

  ProjectionSource  a projection shard store feeding the plan engine's
                    filter stage: `load(mesh)` scatter-reads exactly the
                    shards that overlap each rank's `input_sharding(mesh)`
                    slice (Eq. 5 load split) and returns the sharded device
                    array the engine consumes. With `codec=` at write time
                    the store persists the stream codec's WIRE format —
                    quantized shards plus, for scaled codecs (fp8), a
                    per-projection f32 scale sidecar store at
                    `<path>/scales` — and `load` decodes back to f32;
                    `load_encoded` returns the wire-format pair verbatim
                    (bit-exact round-trip, see tests/test_shard_store.py).
  VolumeSink        the paper's PFS store: `write(volume)` streams each
                    rank's slab (each addressable shard of the engine's
                    output — x over `model`, plus y over `data` with a
                    scatter reduce) to its own file.

Both are wired as optional `source=` / `sink=` stages on
`ReconstructionPlan.build()` (core/plan.py), closing the pipeline:

    src = ProjectionSource.write(dir_in, projections, chunks=(n_ranks, 1, 1))
    fdk = plan.build(source=src, sink=VolumeSink(dir_out))
    volume = fdk()          # load -> filter -> gather -> BP -> reduce -> store
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Precision, resolve_precision

from . import shard_store

# Sub-store holding the per-projection f32 scale sidecar of an encoded
# projection store (sibling of the data store's `shards/` directory).
SCALES_DIR = "scales"


class ProjectionSource:
    """Projections stored shard-per-file (raw f32, or a stream codec's wire
    format + scale sidecar), restorable onto any mesh."""

    def __init__(self, path: str):
        self.path = path

    @classmethod
    def write(cls, path: str, projections,
              chunks: Optional[Sequence[int]] = None,
              codec: "Precision | str | None" = None) -> "ProjectionSource":
        """Lay projections down as a shard store. For a device array the
        files follow its sharding; for a host array pass e.g.
        ``chunks=(n_ranks, 1, 1)`` for the paper's slice-per-rank layout.

        `codec` (a storage-precision name, e.g. "fp8_e4m3") persists the
        stream codec's wire format instead of the input dtype: the data
        store holds the quantized shards (its manifest records the codec),
        and scaled codecs add a `<path>/scales` sidecar store with one f32
        scale per projection — fp8 shrinks the on-disk stream to a quarter
        of f32, the same trade the AllGather makes.
        """
        if codec is None:
            shard_store.save_array(path, projections, chunks=chunks)
            return cls(path)
        prec = resolve_precision(codec)
        data, scales = prec.codec.encode(jnp.asarray(projections))
        shard_store.save_array(path, data, chunks=chunks,
                               extra_manifest={"codec": prec.storage})
        if scales is not None:
            shard_store.save_array(os.path.join(path, SCALES_DIR),
                                   np.asarray(scales),
                                   chunks=None if chunks is None
                                   else chunks[:1])
        return cls(path)

    @property
    def shape(self) -> tuple:
        return tuple(shard_store.read_manifest(self.path)["shape"])

    @property
    def dtype(self) -> np.dtype:
        return shard_store.dtype_from_name(
            shard_store.read_manifest(self.path)["dtype"])

    @property
    def codec_name(self) -> Optional[str]:
        """Storage codec the store was encoded with (None = raw store)."""
        return shard_store.read_manifest(self.path).get("codec")

    def load_encoded(self):
        """The stored wire-format pair (data, scales) as host arrays —
        verbatim bytes, no decode. scales is None for raw/scale-free
        stores. The bit-exact-round-trip accessor."""
        data = shard_store.load_array(self.path)
        spath = os.path.join(self.path, SCALES_DIR)
        scales = (shard_store.load_array(spath)
                  if os.path.exists(os.path.join(spath,
                                                 shard_store.MANIFEST))
                  else None)
        return data, scales

    def load(self, mesh=None) -> jax.Array:
        """Scatter-read the projections for `mesh` (each rank's slice of the
        leading projection axis); the whole array on one device if None.
        Encoded stores are decoded back to f32 (quantized data x scale
        sidecar) after the scatter read — each rank only ever reads and
        dequantizes its own slice of the wire bytes."""
        codec_name = self.codec_name
        if mesh is None:
            if codec_name is None:
                return jax.device_put(shard_store.load_array(self.path))
            data, scales = self.load_encoded()
            return jax.device_put(
                np.asarray(Precision(codec_name).codec.decode(
                    jnp.asarray(data),
                    None if scales is None else jnp.asarray(scales))))
        from repro.core.distributed import input_sharding

        sharding = input_sharding(mesh)
        data = shard_store.load_array(self.path, sharding)
        if codec_name is None:
            return data
        codec = Precision(codec_name).codec
        scales = None
        spath = os.path.join(self.path, SCALES_DIR)
        if os.path.exists(os.path.join(spath, shard_store.MANIFEST)):
            scales = shard_store.load_array(spath)
        return jax.jit(codec.decode)(
            data, None if scales is None else jnp.asarray(scales))


class VolumeSink:
    """Slice-per-rank volume store: each shard of the reconstructed volume
    goes straight to its own file — no gather, no root writer."""

    def __init__(self, path: str):
        self.path = path

    def write(self, volume) -> str:
        """Write the (sharded) volume; returns the store directory."""
        return shard_store.save_array(self.path, volume)

    def read(self, sharding=None):
        """Read the stored volume back (host numpy, or scatter-read onto
        `sharding`)."""
        return shard_store.load_array(self.path, sharding)

    def nbytes(self) -> int:
        """Stored payload size (shard files only, not the manifest)."""
        sdir = os.path.join(self.path, shard_store.SHARD_DIR)
        return sum(os.path.getsize(os.path.join(sdir, f))
                   for f in os.listdir(sdir))
