"""Projection/volume endpoints of the reconstruction pipeline (paper Fig. 3).

The paper's rank does not receive projections from the caller — it *loads*
its N_p/(R*C) slice from the parallel filesystem, and it does not return its
slab — it *stores* it. These two endpoints wrap the shard store
(shard_store.py) in pipeline terms:

  ProjectionSource  a raw-projection shard store feeding the plan engine's
                    filter stage: `load(mesh)` scatter-reads exactly the
                    shards that overlap each rank's `input_sharding(mesh)`
                    slice (Eq. 5 load split) and returns the sharded device
                    array the engine consumes.
  VolumeSink        the paper's PFS store: `write(volume)` streams each
                    rank's slab (each addressable shard of the engine's
                    output — x over `model`, plus y over `data` with
                    reduce="scatter") to its own file.

Both are wired as optional `source=` / `sink=` stages on
`ReconstructionPlan.build()` (core/plan.py), closing the pipeline:

    src = ProjectionSource.write(dir_in, projections, chunks=(n_ranks, 1, 1))
    fdk = plan.build(source=src, sink=VolumeSink(dir_out))
    volume = fdk()          # load -> filter -> gather -> BP -> reduce -> store
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
import numpy as np

from . import shard_store


class ProjectionSource:
    """Raw projections stored shard-per-file, restorable onto any mesh."""

    def __init__(self, path: str):
        self.path = path

    @classmethod
    def write(cls, path: str, projections,
              chunks: Optional[Sequence[int]] = None) -> "ProjectionSource":
        """Lay projections down as a shard store. For a device array the
        files follow its sharding; for a host array pass e.g.
        ``chunks=(n_ranks, 1, 1)`` for the paper's slice-per-rank layout."""
        shard_store.save_array(path, projections, chunks=chunks)
        return cls(path)

    @property
    def shape(self) -> tuple:
        return tuple(shard_store.read_manifest(self.path)["shape"])

    @property
    def dtype(self) -> np.dtype:
        return shard_store.dtype_from_name(
            shard_store.read_manifest(self.path)["dtype"])

    def load(self, mesh=None) -> jax.Array:
        """Scatter-read the projections for `mesh` (each rank's slice of the
        leading projection axis); the whole array on one device if None."""
        if mesh is None:
            return jax.device_put(shard_store.load_array(self.path))
        from repro.core.distributed import input_sharding

        return shard_store.load_array(self.path, input_sharding(mesh))


class VolumeSink:
    """Slice-per-rank volume store: each shard of the reconstructed volume
    goes straight to its own file — no gather, no root writer."""

    def __init__(self, path: str):
        self.path = path

    def write(self, volume) -> str:
        """Write the (sharded) volume; returns the store directory."""
        return shard_store.save_array(self.path, volume)

    def read(self, sharding=None):
        """Read the stored volume back (host numpy, or scatter-read onto
        `sharding`)."""
        return shard_store.load_array(self.path, sharding)

    def nbytes(self) -> int:
        """Stored payload size (shard files only, not the manifest)."""
        sdir = os.path.join(self.path, shard_store.SHARD_DIR)
        return sum(os.path.getsize(os.path.join(sdir, f))
                   for f in os.listdir(sdir))
