"""Shard-level array store: one file per addressable shard + a manifest.

The paper's end-to-end numbers ("4K within 30 s *including I/O*") rest on a
slice-per-rank parallel-filesystem store: every rank streams its own slab to
its own file, so aggregate bandwidth scales with the rank count instead of
funnelling through one writer. This module is that store for arbitrary JAX
arrays (DESIGN.md §7):

  <dir>/
    MANIFEST.json            {shape, dtype, spec, shards: [...]}
    shards/shard_00000.bin   raw little-endian C-order bytes, one file per
    shards/shard_00001.bin   distinct device shard (replicas deduplicated)
    ...

Write side — `save_array`: each host writes only the shards it owns
(`array.addressable_shards`, `replica_id == 0` copies), never materializing
the global array; shard file names are derived from the *global* index map
so every host agrees on the layout without coordination, and process 0
writes the manifest.

Read side — `load_array(path, sharding=...)`: a scatter read. For every
distinct region the target sharding places on this host's devices, only the
shard files that intersect that region are opened (memory-mapped, so a
region that needs one row of a shard reads ~one row, not the file); the
pieces are assembled per device and joined with
`jax.make_array_from_single_device_arrays`. Restoring onto a different mesh
shape than the writer's (the elastic 8 -> 4 path) is the same code path —
the store is indexed by global coordinates, not by writer rank.

Shard files are raw bytes (not .npy) for two reasons: numpy's format cannot
represent the ml_dtypes storage types (bfloat16 projections), and a raw
file's expected size is exactly `prod(extent) * itemsize` — truncation by a
crashed or out-of-quota writer is detected by a size check before any data
is trusted. All corruption paths raise `StoreError` with the offending
path; `open_count()` exposes file-open accounting so tests (and the `io`
benchmark suite) can assert scatter reads touch only what they need.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np

Index = Tuple[Tuple[int, int], ...]     # ((lo, hi), ...) per dimension

MANIFEST = "MANIFEST.json"
SHARD_DIR = "shards"


class StoreError(RuntimeError):
    """A shard store (or checkpoint built on it) is unreadable: truncated
    shard file, missing manifest / manifest entry, or an uncommitted step."""


# ---------------------------------------------------------------------------
# file-open accounting (scatter-read tests, io benchmark suite)

_OPEN_COUNT = 0


def reset_open_count() -> None:
    global _OPEN_COUNT
    _OPEN_COUNT = 0


def open_count() -> int:
    """Shard files opened since `reset_open_count()` (reads only)."""
    return _OPEN_COUNT


# ---------------------------------------------------------------------------
# dtypes / indices

def dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # bfloat16/float8 storage dtypes (jax dependency)

        try:
            return np.dtype(getattr(ml_dtypes, name))
        except AttributeError:
            raise StoreError(f"manifest names unknown dtype {name!r}")


def _normalize_index(index: Sequence[slice], shape: Sequence[int]) -> Index:
    """Tuple-of-slices (as produced by shard.index / devices_indices_map,
    possibly with None bounds) -> ((lo, hi), ...) in global coordinates."""
    out = []
    for sl, dim in zip(index, shape):
        lo, hi, step = sl.indices(dim)
        if step != 1:
            raise StoreError(f"non-unit-stride shard index {sl} unsupported")
        out.append((lo, hi))
    return tuple(out)


def _extent(index: Index) -> Tuple[int, ...]:
    return tuple(hi - lo for lo, hi in index)


def _size(index: Index) -> int:
    n = 1
    for lo, hi in index:
        n *= hi - lo
    return n


def _intersect(a: Index, b: Index) -> Optional[Index]:
    out = []
    for (alo, ahi), (blo, bhi) in zip(a, b):
        lo, hi = max(alo, blo), min(ahi, bhi)
        if lo >= hi:
            return None
        out.append((lo, hi))
    return tuple(out)


def _rel_slices(outer: Index, inner: Index) -> Tuple[slice, ...]:
    """`inner` (global coords) as slices into an array spanning `outer`."""
    return tuple(slice(ilo - olo, ihi - olo)
                 for (olo, _), (ilo, ihi) in zip(outer, inner))


# ---------------------------------------------------------------------------
# host-side snapshot (async checkpointing keeps shard structure, not a
# gathered global array)

@dataclasses.dataclass
class HostShardedArray:
    """A device array snapshotted to host memory shard-by-shard: what the
    CheckpointManager's background writer consumes. Keeps the global shape,
    the logical PartitionSpec (JSON form, None = no spec recorded), the
    GLOBAL shard index table (so a multi-host writer numbers its files
    consistently with every other host and the manifest lists shards this
    host does not own), and one (index, data) pair per owned shard — never
    the assembled array."""

    shape: Tuple[int, ...]
    dtype: Any
    spec: Optional[list]
    shards: list            # [(Index, np.ndarray)] — owned by this host
    table: Optional[list] = None  # [Index] global, sorted; None = shards


def leaf_spec_json(arr) -> Optional[list]:
    """The logical PartitionSpec of `arr` in JSON form, or None when the
    array records no spec (host numpy, single-device default placement).
    None-vs-list is load-bearing: an empty list is a *real* (fully
    replicated) PartitionSpec, not the absence of one."""
    from jax.sharding import NamedSharding

    sharding = getattr(arr, "sharding", None)
    if not isinstance(sharding, NamedSharding):
        return None
    out: list = []
    for e in sharding.spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def snapshot(leaf) -> Any:
    """Device array -> HostShardedArray (per-shard device_get, no global
    gather); host values pass through as numpy arrays."""
    if not isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    shape = tuple(leaf.shape)
    shards = [
        (_normalize_index(s.index, shape), np.asarray(jax.device_get(s.data)))
        for s in leaf.addressable_shards
        if s.replica_id == 0
    ]
    return HostShardedArray(shape=shape, dtype=leaf.dtype,
                            spec=leaf_spec_json(leaf), shards=shards,
                            table=_global_shard_table(leaf))


# ---------------------------------------------------------------------------
# write side

def _chunk_indices(shape: Tuple[int, ...],
                   chunks: Sequence[int]) -> list[Index]:
    """Regular grid of `chunks[d]` pieces along each dim (host-array writes:
    a preprocessing job laying out slice-per-rank files without a mesh)."""
    if len(chunks) != len(shape):
        raise ValueError(f"chunks {tuple(chunks)} must have one entry per "
                         f"dimension of shape {shape}")
    per_dim = []
    for dim, n in zip(shape, chunks):
        if n < 1 or dim % n:
            raise ValueError(
                f"chunks {tuple(chunks)} must positively divide {shape}")
        step = dim // n
        per_dim.append([(i * step, (i + 1) * step) for i in range(n)])
    out: list[Index] = [()]
    for bounds in per_dim:
        out = [idx + (b,) for idx in out for b in bounds]
    return out


def _global_shard_table(arr: jax.Array) -> list[Index]:
    """Sorted distinct global shard indices — identical on every host, so
    shard file names need no coordination."""
    imap = arr.sharding.devices_indices_map(tuple(arr.shape))
    distinct = {_normalize_index(idx, arr.shape) for idx in imap.values()}
    return sorted(distinct)


def save_array(path: str, arr, *, chunks: Optional[Sequence[int]] = None,
               extra_manifest: Optional[dict] = None,
               _process_index: Optional[int] = None) -> str:
    """Write `arr` as a shard store at `path` (clearing any stale store).

    jax.Array        one file per distinct device shard; this host writes
                     only the shards it owns (replica 0 copies).
    HostShardedArray the snapshot path (async checkpoint writer).
    host array       one file, or a `chunks=(c0, c1, ...)` regular grid.

    `extra_manifest` merges additional keys into MANIFEST.json (reserved
    keys shape/dtype/spec/shards win) — e.g. the stream layer records the
    codec an encoded projection store was quantized with, so readers know
    to load the scale sidecar next to the data (repro/io/streams.py).
    """
    pidx = jax.process_index() if _process_index is None else _process_index
    if pidx == 0 and os.path.exists(path):
        # Only one process clears a stale store: a per-host rmtree would
        # race the other hosts' concurrent shard writes on a shared PFS.
        # (Best-effort without a barrier — stale shard files left by other
        # layouts are inert, reads go through the fresh manifest.)
        shutil.rmtree(path)
    shard_dir = os.path.join(path, SHARD_DIR)
    os.makedirs(shard_dir, exist_ok=True)

    if isinstance(arr, HostShardedArray):
        shape, dtype, spec = arr.shape, np.dtype(arr.dtype), arr.spec
        table = (sorted(tuple(tuple(b) for b in i) for i in arr.table)
                 if arr.table is not None
                 else sorted(idx for idx, _ in arr.shards))
        owned = dict(arr.shards)
    elif isinstance(arr, jax.Array) and chunks is None:
        shape, dtype = tuple(arr.shape), np.dtype(arr.dtype)
        spec = leaf_spec_json(arr)
        table = _global_shard_table(arr)
        owned = {
            _normalize_index(s.index, shape):
                np.asarray(jax.device_get(s.data))
            for s in arr.addressable_shards if s.replica_id == 0
        }
    else:
        data = np.asarray(jax.device_get(arr))
        shape, dtype, spec = tuple(data.shape), data.dtype, None
        table = (_chunk_indices(shape, chunks) if chunks is not None
                 else [tuple((0, d) for d in shape)])
        owned = {idx: data[tuple(slice(lo, hi) for lo, hi in idx)]
                 for idx in table}

    entries = []
    for i, idx in enumerate(table):
        fname = f"shard_{i:05d}.bin"
        entries.append({"file": fname, "index": [list(b) for b in idx]})
        if idx in owned:
            piece = np.ascontiguousarray(owned[idx])
            with open(os.path.join(shard_dir, fname), "wb") as f:
                f.write(piece.tobytes())
    if pidx == 0:
        manifest = dict(extra_manifest or {})
        manifest.update({
            "shape": list(shape),
            "dtype": str(dtype),
            "spec": spec,
            "shards": entries,
        })
        with open(os.path.join(path, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
    return path


# ---------------------------------------------------------------------------
# streaming append (growing store: the scanner writes while readers poll)

def _write_manifest(path: str, manifest: dict) -> None:
    """Atomic manifest replace: readers polling a growing store either see
    the old manifest or the new one, never a torn write."""
    mpath = os.path.join(path, MANIFEST)
    tmp = mpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, mpath)


def init_store(path: str, shape: Sequence[int], dtype,
               extra_manifest: Optional[dict] = None) -> str:
    """Create an EMPTY shard store of a known final shape — the head of a
    streaming write (`append_region`): the manifest declares the full array
    up front with no shards, and grows one entry per committed append.
    Readers (`read_region` / a poller diffing `manifest["shards"]`) see only
    committed data."""
    os.makedirs(os.path.join(path, SHARD_DIR), exist_ok=True)
    manifest = dict(extra_manifest or {})
    manifest.update({
        "shape": list(shape),
        "dtype": str(np.dtype(dtype)),
        "spec": None,
        "shards": [],
    })
    _write_manifest(path, manifest)
    return path


def append_region(path: str, index: Sequence, data) -> dict:
    """Append one region to a growing store and COMMIT it.

    Write protocol (PFS-safe ordering): the shard file lands fully on disk
    first, then the manifest is atomically replaced with the new entry
    appended — the manifest entry is the commit point, so a reader never
    sees an entry whose bytes are not durable, and a crashed writer leaves
    at worst an orphaned (inert) shard file. Returns the new entry."""
    m = read_manifest(path)
    shape = tuple(m["shape"])
    idx = (tuple(tuple(b) for b in index) if not isinstance(index[0], slice)
           else _normalize_index(index, shape))
    dtype = dtype_from_name(m["dtype"])
    piece = np.ascontiguousarray(np.asarray(data, dtype=dtype))
    if piece.shape != _extent(idx):
        raise ValueError(
            f"append data shape {piece.shape} does not span index {idx}")
    for entry in m["shards"]:
        prev = tuple(tuple(b) for b in entry["index"])
        if _intersect(idx, prev) is not None:
            raise StoreError(
                f"append region {idx} overlaps committed shard "
                f"{entry['file']} ({prev}) in {path!r}")
    fname = f"shard_{len(m['shards']):05d}.bin"
    with open(os.path.join(path, SHARD_DIR, fname), "wb") as f:
        f.write(piece.tobytes())
        f.flush()
        os.fsync(f.fileno())
    entry = {"file": fname, "index": [list(b) for b in idx]}
    m["shards"].append(entry)
    _write_manifest(path, m)
    return entry


# ---------------------------------------------------------------------------
# read side

def read_manifest(path: str) -> dict:
    mpath = os.path.join(path, MANIFEST)
    if not os.path.exists(mpath):
        raise StoreError(f"no shard store at {path!r} (missing {MANIFEST})")
    try:
        with open(mpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise StoreError(f"unreadable manifest {mpath!r}: {e}") from e


def _open_shard(path: str, entry: dict, dtype: np.dtype) -> np.ndarray:
    """Memory-map one shard file, verifying its size first (truncation from
    a crashed/out-of-quota writer must fail loudly, not read garbage)."""
    global _OPEN_COUNT
    idx = tuple(tuple(b) for b in entry["index"])
    extent = _extent(idx)
    fpath = os.path.join(path, SHARD_DIR, entry["file"])
    if not os.path.exists(fpath):
        raise StoreError(f"missing shard file {fpath!r}")
    expected = _size(idx) * dtype.itemsize
    actual = os.path.getsize(fpath)
    if actual != expected:
        raise StoreError(
            f"truncated shard file {fpath!r}: {actual} bytes on disk, "
            f"expected {expected} ({extent} x {dtype})")
    _OPEN_COUNT += 1
    if _size(idx) == 0 or extent == ():
        data = np.fromfile(fpath, dtype=dtype)
        return data.reshape(extent)
    return np.memmap(fpath, dtype=dtype, mode="r", shape=extent, order="C")


def read_region(path: str, index: Sequence[slice] | Index,
                manifest: Optional[dict] = None) -> np.ndarray:
    """Assemble one global-coordinate region, opening only the shard files
    that intersect it. Raises StoreError when the manifest's shards do not
    cover the region (a deleted/missing manifest entry)."""
    m = manifest if manifest is not None else read_manifest(path)
    shape = tuple(m["shape"])
    dtype = dtype_from_name(m["dtype"])
    if index and isinstance(index[0], slice):
        region = _normalize_index(index, shape)
    else:
        region = tuple(tuple(b) for b in index)
    out = np.empty(_extent(region), dtype=dtype)
    covered = 0
    for entry in m["shards"]:
        sidx = tuple(tuple(b) for b in entry["index"])
        inter = _intersect(region, sidx)  # () for 0-d: the shard covers it
        if inter is None:
            continue
        data = _open_shard(path, entry, dtype)
        out[_rel_slices(region, inter)] = data[_rel_slices(sidx, inter)]
        covered += _size(inter)
        if covered == _size(region):
            break
    if covered != _size(region):
        raise StoreError(
            f"shard store {path!r} does not cover region {region}: "
            f"{covered}/{_size(region)} elements present — missing or "
            "deleted manifest entries")
    return out


def load_array(path: str, sharding=None) -> Any:
    """Restore a stored array.

    sharding=None         assemble the full array on host (numpy).
    sharding=NamedSharding scatter read: for each distinct region the target
                          sharding places on this host, open only the
                          intersecting shard files and build the global
                          jax.Array — the target mesh need not match the
                          writer's (reshard-on-restore).
    """
    m = read_manifest(path)
    shape = tuple(m["shape"])
    if sharding is None:
        return read_region(path, tuple((0, d) for d in shape), manifest=m)
    imap = sharding.addressable_devices_indices_map(shape)
    cache: dict = {}
    pieces = []
    for dev, idx in imap.items():
        key = _normalize_index(idx, shape) if idx else ()
        if key not in cache:
            cache[key] = np.ascontiguousarray(
                read_region(path, key, manifest=m))
        pieces.append(jax.device_put(cache[key], dev))
    return jax.make_array_from_single_device_arrays(shape, sharding, pieces)


def stored_spec(path: str):
    """The writer's logical PartitionSpec (or None if none was recorded)."""
    from jax.sharding import PartitionSpec

    spec = read_manifest(path).get("spec")
    if spec is None:
        return None
    return PartitionSpec(*[tuple(e) if isinstance(e, list) else e
                           for e in spec])
