"""Span tracer: nested monotonic-clock timing with dispatch/compute fencing.

The paper's whole argument is a time budget — filtering overlapped with
back-projection, "4K in 30 s *including I/O*" — so the runtime must be able
to say where a real reconstruction spent its time. This module is the
measurement half of that: explicitly instrumented SPANS (named, nested,
monotonic-clock intervals) collected by a thread-safe `Tracer` and exported
as Chrome/Perfetto ``trace_event`` JSON (`chrome://tracing`, ui.perfetto.dev
both load it directly).

Async-dispatch semantics (the one JAX-specific subtlety): calling a jitted
engine returns as soon as XLA has *enqueued* the work — the wall time of the
Python call is dispatch, not compute. A span that should attribute device
time must FENCE: ``span.fence(out)`` records the elapsed time at the fence
point as the span's ``dispatch_us`` attribute, then blocks until ``out`` is
ready, so the span's total duration is dispatch + compute and the gap
between the two is the device-side tail. Spans without a fence measure pure
host time (I/O, queue waits, bucket assembly).

Overhead contract: with the tracer DISABLED (the default), ``span()``
returns a preallocated no-op context manager — no clock read, no
allocation, no lock — so instrumented hot paths cost one attribute load and
one branch per span (asserted <1% of the fast e2e test, tests/test_obs.py).
``span(..., timed=True)`` always measures (its duration is readable from
the returned span) but still records an event only when enabled — the
mode `planner/measure.py` times engines through.

Usage::

    from repro import obs
    obs.enable()                      # or Tracer(enabled=True) locally
    with obs.span("engine.fused", schedule="fused") as sp:
        out = fdk(projections)
        sp.fence(out)                 # dispatch recorded, block until ready
    obs.get_tracer().save("trace.json")

Span names are dotted ``subsystem.event`` (e.g. ``stage.backproject``,
``service.bucket``, ``io.source.read``); the engine STAGE names consumed by
`obs/attribution.py` are fixed vocabulary — see attribution.STAGE_FIELDS.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Span", "Tracer", "get_tracer", "set_tracer", "enable", "disable",
    "span",
]

# Cap on buffered events per tracer: a forgotten always-on tracer in a
# long-lived service must not grow without bound. Overflow drops new spans
# (counted in `dropped`) instead of evicting old ones — the trace's
# beginning is usually the interesting part of a runaway.
MAX_EVENTS = 200_000


class _NullSpan:
    """The disabled-path span: every method is a no-op. One shared instance;
    it holds no state, so reuse across threads/nestings is safe."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def fence(self, value: Any) -> Any:
        return value

    def set(self, **attrs: Any) -> None:
        return None

    @property
    def duration_s(self) -> float:
        return 0.0


_NULL_SPAN = _NullSpan()


class Span:
    """One named interval. Created by `Tracer.span` (context manager);
    closed on context exit, after which `duration_s` / `dispatch_s` are
    readable. Not reentrant — each `with` gets a fresh Span."""

    __slots__ = ("name", "args", "_tracer", "_record", "_t0", "_t1",
                 "_fence_ns", "_tid")

    def __init__(self, tracer: "Tracer", name: str, record: bool,
                 args: Optional[Dict[str, Any]]):
        self.name = name
        self.args = args or {}
        self._tracer = tracer
        self._record = record
        self._t0 = 0
        self._t1 = 0
        self._fence_ns: Optional[int] = None
        self._tid = 0

    def __enter__(self) -> "Span":
        self._tid = threading.get_ident()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._t1 = time.perf_counter_ns()
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        if self._record:
            self._tracer._finish(self)

    def set(self, **attrs: Any) -> None:
        """Attach attributes (rendered as Perfetto ``args``)."""
        self.args.update(attrs)

    def fence(self, value: Any) -> Any:
        """Record the dispatch-to-here elapsed time, then block until
        `value` (a jax array / pytree) is ready. The span's remaining time
        is device compute the dispatch did not wait for."""
        self._fence_ns = time.perf_counter_ns() - self._t0
        import jax
        jax.block_until_ready(value)
        return value

    # -- readable after close ------------------------------------------------

    @property
    def duration_s(self) -> float:
        return (self._t1 - self._t0) / 1e9

    @property
    def dispatch_s(self) -> Optional[float]:
        """Elapsed at the fence point (None when the span never fenced)."""
        return None if self._fence_ns is None else self._fence_ns / 1e9


class Tracer:
    """Thread-safe span collector with Perfetto ``trace_event`` export.

    Spans nest per thread by construction — a ``ph: "X"`` (complete) event
    whose [ts, ts+dur) interval contains another on the same tid renders as
    its parent — so no explicit parent bookkeeping is needed; the
    monotonic timestamps do the nesting.
    """

    def __init__(self, enabled: bool = False, max_events: int = MAX_EVENTS):
        self.enabled = enabled
        self.max_events = max_events
        self.dropped = 0
        self._events: List[dict] = []
        self._lock = threading.Lock()
        # One epoch per tracer: Perfetto ts values are microseconds relative
        # to it, so traces start near t=0 instead of at machine uptime.
        self._epoch_ns = time.perf_counter_ns()

    # -- recording -----------------------------------------------------------

    def span(self, name: str, timed: bool = False, **attrs: Any):
        """Context manager timing one interval.

        Disabled tracer: returns the shared no-op span (zero cost) unless
        `timed=True`, which measures anyway — the span's `duration_s` is
        readable afterward — but records no event.
        """
        if not self.enabled:
            if not timed:
                return _NULL_SPAN
            return Span(self, name, record=False, args=attrs or None)
        return Span(self, name, record=True, args=attrs or None)

    def _finish(self, sp: Span) -> None:
        ev = {
            "ph": "X",
            "name": sp.name,
            "ts": (sp._t0 - self._epoch_ns) / 1e3,   # µs, tracer-relative
            "dur": (sp._t1 - sp._t0) / 1e3,
            "pid": os.getpid(),
            "tid": sp._tid,
        }
        args = dict(sp.args)
        if sp._fence_ns is not None:
            args["dispatch_us"] = sp._fence_ns / 1e3
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    def instant(self, name: str, **attrs: Any) -> None:
        """Zero-duration marker event (``ph: "i"``)."""
        if not self.enabled:
            return
        ev = {
            "ph": "i", "name": name, "s": "t",
            "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
            "pid": os.getpid(), "tid": threading.get_ident(),
        }
        if attrs:
            ev["args"] = attrs
        with self._lock:
            if len(self._events) >= self.max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    # -- consumption ---------------------------------------------------------

    def events(self) -> List[dict]:
        """Copy of the buffered trace events (Perfetto dict form)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def spans(self, prefix: str = "") -> List[dict]:
        """Finished complete-spans (``ph == "X"``), optionally filtered by
        name prefix — the programmatic view `obs/attribution.py` consumes.
        Durations are in MICROseconds (`dur`), like the wire format."""
        with self._lock:
            return [dict(e) for e in self._events
                    if e["ph"] == "X" and e["name"].startswith(prefix)]

    def stage_totals(self, prefix: str = "stage.") -> Dict[str, float]:
        """Summed SECONDS per span name under `prefix` — the per-stage
        aggregate the bench trajectory files and attribution report read."""
        totals: Dict[str, float] = {}
        for e in self.spans(prefix):
            totals[e["name"]] = totals.get(e["name"], 0.0) + e["dur"] / 1e6
        return totals

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._epoch_ns = time.perf_counter_ns()

    # -- export --------------------------------------------------------------

    def export(self) -> dict:
        """The Chrome/Perfetto ``trace_event`` JSON object."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped": self.dropped},
        }

    def save(self, path: str) -> str:
        """Write the trace JSON; returns `path`."""
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# Process-default tracer: instrumented library code traces through this so
# one `obs.enable()` (or `run.py --trace`) lights every subsystem up at
# once. Disabled by default — the no-op span path is the production cost.
# ---------------------------------------------------------------------------

_DEFAULT = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _DEFAULT


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-default tracer (tests install a fresh one);
    returns the previous tracer."""
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, tracer
    return prev


def enable() -> Tracer:
    _DEFAULT.enabled = True
    return _DEFAULT


def disable() -> Tracer:
    _DEFAULT.enabled = False
    return _DEFAULT


def span(name: str, timed: bool = False, **attrs: Any):
    """`get_tracer().span(...)` — the one-liner instrumentation points use."""
    return _DEFAULT.span(name, timed=timed, **attrs)
