"""Predicted-vs-measured attribution: planner cost model against the tracer.

The planner reproduces the paper's Eq. 8-19 cost model (`planner/cost.py`
-> `PerfBreakdown`), and the traced engine (`ReconstructionPlan.
build_traced`) measures the SAME pipeline stage by stage. This module
joins the two: every engine-stage span maps onto the `PerfBreakdown` field
the model predicts for it, and `compare()` emits one row per stage with
the per-stage model error — the validation loop that turns a cost model
from a heuristic into a tool (cf. Treibig et al., PAPERS.md).

Attribution mapping (DESIGN.md §Observability carries the same table):

    span name           PerfBreakdown      engine stage
    ----------------    ---------------    ---------------------------------
    stage.read          t_read  (Eq. 8)    ProjectionSource scatter-read
    stage.filter        t_flt   (Eq. 9)    ramp filter + codec encode
    stage.allgather     t_allgather (10)   column AllGather (wire bytes)
    stage.backproject   t_bp    (Eq. 12)   slab back-projection
    stage.reduce        t_reduce (Eq. 15)  row-reduce epilogue + FDK scale
    stage.write         t_write (Eq. 16)   VolumeSink slice-per-rank store

`t_h2d`/`t_d2h` (Eqs. 11/14) have no standalone measured counterpart on an
HBM-resident backend — the model folds t_h2d into t_bp (Eq. 12) and the
engine never stages through a host bus — so they are attributed inside the
backproject row, matching `PerfBreakdown.t_bp`'s own definition.

Measured time for a stage is the SUM of its span durations in the trace
(a pipelined engine emits one span per micro-batch; attribution compares
totals, which is what the model predicts too).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Union

from .trace import Tracer

__all__ = ["STAGE_FIELDS", "AttributionRow", "aggregate_error", "compare",
           "render_report", "stage_totals"]

# Engine-stage span name -> PerfBreakdown field. Fixed vocabulary: the
# traced engine emits exactly these names (core/plan.py build_traced), and
# tests assert the two sides stay joined.
STAGE_FIELDS: Dict[str, str] = {
    "stage.read": "t_read",
    "stage.filter": "t_flt",
    "stage.allgather": "t_allgather",
    "stage.backproject": "t_bp",
    "stage.reduce": "t_reduce",
    "stage.write": "t_write",
}


@dataclasses.dataclass(frozen=True)
class AttributionRow:
    """One stage's predicted-vs-measured join.

    error is measured/predicted - 1 (positive: slower than modeled), None
    when the model predicts zero for the stage (nothing to attribute
    against — e.g. t_reduce on a C == 1 grid).
    """

    stage: str            # span name, e.g. "stage.backproject"
    field: str            # PerfBreakdown field, e.g. "t_bp"
    predicted_s: float
    measured_s: float
    n_spans: int

    @property
    def error(self) -> Optional[float]:
        if self.predicted_s <= 0.0:
            return None
        return self.measured_s / self.predicted_s - 1.0


def stage_totals(trace: Union[Tracer, dict, Iterable[dict]]
                 ) -> Dict[str, Dict[str, float]]:
    """{span name: {"seconds": total, "n": count}} for every ``stage.*``
    span in `trace` — a Tracer, an exported ``{"traceEvents": [...]}``
    object (e.g. json.load of a saved trace), or a bare event list."""
    if isinstance(trace, Tracer):
        events = trace.spans("stage.")
    else:
        events = trace.get("traceEvents", []) if isinstance(trace, dict) \
            else list(trace)
        events = [e for e in events
                  if e.get("ph") == "X"
                  and str(e.get("name", "")).startswith("stage.")]
    out: Dict[str, Dict[str, float]] = {}
    for e in events:
        t = out.setdefault(e["name"], {"seconds": 0.0, "n": 0})
        t["seconds"] += e["dur"] / 1e6         # trace durs are µs
        t["n"] += 1
    return out


def compare(plan, trace, system=None,
            calibration=None) -> List[AttributionRow]:
    """Join the plan's modeled `PerfBreakdown` with a measured trace.

    plan   : the ReconstructionPlan the traced run executed.
    trace  : Tracer / exported trace dict / event list containing the
             ``stage.*`` spans of a `plan.build_traced()` run.
    system : MachineSpec the prediction is priced on (default ABCI).
    calibration : optional planner.calibrate.MachineCalibration overlay —
             the calibrated prediction's attribution (drift checks compare
             stock rows against calibrated rows of the same trace).

    Returns one `AttributionRow` per mapped stage, in pipeline order —
    including rows the model predicts as zero (error None) and rows the
    trace never measured (measured 0.0, n_spans 0; a plan run without a
    source/sink legitimately has no read/write spans). Every NONZERO
    predicted stage of the breakdown therefore gets a row; whether it got
    a measured counterpart is `n_spans > 0` (asserted in tests for a
    traced source->engine->sink run).
    """
    from repro.planner.cost import predict_plan
    if system is None:
        bd = predict_plan(plan, calibration=calibration)
    else:
        bd = predict_plan(plan, system, calibration=calibration)
    measured = stage_totals(trace)
    rows = []
    for stage, field in STAGE_FIELDS.items():
        m = measured.get(stage, {"seconds": 0.0, "n": 0})
        rows.append(AttributionRow(
            stage=stage, field=field,
            predicted_s=float(getattr(bd, field)),
            measured_s=m["seconds"], n_spans=m["n"]))
    return rows


def aggregate_error(rows: Iterable[AttributionRow]) -> Optional[float]:
    """Time-weighted aggregate model error over an attribution report:

        sum(measured * |error|) / sum(measured)

    over the rows that can be attributed (predicted > 0 AND measured, i.e.
    n_spans > 0) — each stage's relative error weighted by the wall time it
    actually consumed, so a 50%-off 2 s back-projection dominates a
    50%-off 1 ms reduce. This is the drift-alarm metric: CI's fast-tier
    trace step compares it against a committed baseline
    (benchmarks/export_trace.py --check-drift) and fails on regression.
    None when no row qualifies (nothing measured, or all-zero model)."""
    num = den = 0.0
    for r in rows:
        if r.error is None or r.n_spans <= 0 or r.measured_s <= 0:
            continue
        num += r.measured_s * abs(r.error)
        den += r.measured_s
    return None if den <= 0 else num / den


def render_report(rows: List[AttributionRow]) -> str:
    """Fixed-width predicted-vs-measured table (CLIs, bench footers)."""
    lines = [f"{'stage':<18} {'field':<12} {'predicted':>12} "
             f"{'measured':>12} {'spans':>6} {'error':>9}"]
    for r in rows:
        err = "-" if r.error is None else f"{r.error:+8.1%}"
        lines.append(
            f"{r.stage:<18} {r.field:<12} {r.predicted_s:>12.6f} "
            f"{r.measured_s:>12.6f} {r.n_spans:>6d} {err:>9}")
    return "\n".join(lines)
