"""Unified metrics registry: named counters, gauges, fixed-bucket histograms.

Before this module every subsystem grew its own ad-hoc counting — the
engine cache's `CountingLRU` attributes, the service scheduler's
`_counters` dict, the plan cache's `searches` int — each with its own
`stats()` shape and no way to see the whole process at once. The registry
is the one place instruments live:

    from repro.obs import metrics
    reg = metrics.default_registry()          # process-global
    reg.counter("service.scans.served").inc()
    reg.gauge("io.prefetch.queue_depth").set(2)
    reg.histogram("service.time_to_volume_seconds").observe(0.41)
    reg.snapshot()                            # nested plain-dict view
    print(reg.render())                       # human-readable dump

Naming convention (DESIGN.md §Observability): dotted
``subsystem.object.metric``, lower_snake leaf names, ``_seconds`` /
``_bytes`` unit suffixes on histograms. Instruments are get-or-create —
asking for an existing name returns the same object (asking with a
different TYPE raises, catching collisions early).

Scope: `default_registry()` serves process-global instruments (caches,
module-level I/O helpers). Per-instance components that must not share
counts across instances (a `ReconstructionService` per test, say) own a
private `MetricsRegistry` and expose it; their legacy `stats()` dicts are
thin views over it.

Everything is thread-safe (one lock per instrument, one per registry map)
and dependency-free — `snapshot()` is plain data for tests and CLIs.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "counter", "gauge", "histogram",
    "DEFAULT_TIME_BUCKETS",
]

# Default histogram edges for *_seconds observations: 100 µs .. ~3.4 min in
# x4 steps — wide enough for queue waits and whole-scan latencies without
# per-site tuning. Finite edges only; the +inf overflow bucket is implicit.
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = tuple(
    1e-4 * 4 ** i for i in range(11))


class Counter:
    """Monotonic count. `inc()` only goes up; `value` is the running total."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease (n={n})")
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Gauge:
    """Point-in-time value: set/inc/dec (queue depths, in-flight counts).
    Also records the high-water mark (`max_value`) since creation — depth
    gauges are mostly read *after* the fact, in tests and stats dumps."""

    __slots__ = ("name", "_v", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)
            if self._v > self._max:
                self._max = self._v

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n
            if self._v > self._max:
                self._max = self._v

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._v

    @property
    def max_value(self) -> float:
        with self._lock:
            return self._max


class Histogram:
    """Fixed-bucket histogram: cumulative-style bucket counts over the
    configured upper EDGES plus an implicit +inf overflow bucket, with
    count/sum/min/max for mean and range. Edges are per-instrument and
    immutable — a fixed memory footprint per metric, no quantile sketches.
    """

    __slots__ = ("name", "edges", "_counts", "_n", "_sum", "_min", "_max",
                 "_lock")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        edges = tuple(float(e) for e in buckets)
        if not edges:
            raise ValueError(
                f"histogram {name!r} needs at least one bucket edge")
        if any(not math.isfinite(e) for e in edges):
            raise ValueError(
                f"histogram {name!r} bucket edges must be finite "
                "(+inf overflow is implicit)")
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError(
                f"histogram {name!r} bucket edges must be strictly "
                f"increasing, got {edges}")
        self.name = name
        self.edges = edges
        self._counts = [0] * (len(edges) + 1)   # last = +inf overflow
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        # first edge >= v (counts are per-bucket; snapshot cumulates)
        i = 0
        for e in self.edges:
            if v <= e:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._n += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> dict:
        with self._lock:
            n, s = self._n, self._sum
            counts = list(self._counts)
            mn = self._min if n else None
            mx = self._max if n else None
        return {
            "count": n,
            "sum": s,
            "mean": (s / n) if n else None,
            "min": mn,
            "max": mx,
            "buckets": {
                **{f"le_{e:g}": c for e, c in zip(self.edges, counts)},
                "le_inf": counts[-1],
            },
        }


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors and plain-data
    export. One process-global default (`default_registry()`); components
    with per-instance counts own private registries."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        h = self._get_or_create(name, Histogram,
                                lambda: Histogram(name, buckets))
        if tuple(float(b) for b in buckets) != h.edges:
            raise ValueError(
                f"histogram {name!r} already registered with edges "
                f"{h.edges}; re-registration must agree")
        return h

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str):
        """The instrument registered under `name`, or None."""
        with self._lock:
            return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Counter/gauge value by name (`default` when unregistered) — the
        thin-view accessor legacy `stats()` dicts read through."""
        m = self.get(name)
        if m is None:
            return default
        if isinstance(m, Histogram):
            return m.snapshot()
        return m.value

    def snapshot(self) -> dict:
        """Plain-dict state of every instrument: counters/gauges to their
        value, histograms to their summary dict."""
        with self._lock:
            items = list(self._metrics.items())
        out = {}
        for name, m in sorted(items):
            if isinstance(m, Histogram):
                out[name] = m.snapshot()
            elif isinstance(m, Gauge):
                out[name] = {"value": m.value, "max": m.max_value}
            else:
                out[name] = m.value
        return out

    def render(self) -> str:
        """Human-readable one-line-per-metric dump (CLIs, bench footers)."""
        lines = []
        for name, v in self.snapshot().items():
            if isinstance(v, dict) and "buckets" in v:
                mean = v["mean"]
                lines.append(
                    f"{name}: count={v['count']} sum={v['sum']:.6g}"
                    + (f" mean={mean:.6g} min={v['min']:.6g}"
                       f" max={v['max']:.6g}" if v["count"] else ""))
            elif isinstance(v, dict):
                lines.append(f"{name}: {v['value']:g} (max {v['max']:g})")
            else:
                lines.append(f"{name}: {v}")
        return "\n".join(lines)

    def reset(self) -> None:
        """Drop every instrument (tests). Existing instrument OBJECTS held
        by call sites keep counting into the void — call sites that cache
        instruments across resets should re-fetch them."""
        with self._lock:
            self._metrics.clear()


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT_REGISTRY


def counter(name: str) -> Counter:
    return _DEFAULT_REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return _DEFAULT_REGISTRY.gauge(name)


def histogram(name: str,
              buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
    return _DEFAULT_REGISTRY.histogram(name, buckets)
