"""Observability: span tracing, the unified metrics registry, and planner
predicted-vs-measured attribution.

Three layers (DESIGN.md §Observability):

  trace.py        nested monotonic-clock spans with explicit
                  `block_until_ready` fencing (dispatch-vs-compute
                  attribution under async XLA), thread-safe, near-zero
                  overhead when disabled, exported as Chrome/Perfetto
                  ``trace_event`` JSON.
  metrics.py      named counters / gauges / fixed-bucket histograms behind
                  a process-global default registry; every subsystem's
                  ad-hoc counters (engine cache, plan cache, scheduler,
                  prefetcher, write-behind) report through it.
  attribution.py  joins measured engine-stage spans onto the planner's
                  `PerfBreakdown` prediction — per-stage model error.

Quick start::

    from repro import obs
    obs.enable()                              # light up every subsystem
    fdk = plan.build_traced(source=src, sink=sink)
    volume = fdk()
    obs.get_tracer().save("trace.json")       # load in ui.perfetto.dev
    print(obs.attribution.render_report(
        obs.attribution.compare(plan, obs.get_tracer())))
"""
from . import attribution, metrics, trace
from .metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, counter, default_registry,
    gauge, histogram,
)
from .trace import (
    Span, Tracer, disable, enable, get_tracer, set_tracer, span,
)

__all__ = [
    "attribution", "metrics", "trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "counter",
    "default_registry", "gauge", "histogram",
    "Span", "Tracer", "disable", "enable", "get_tracer", "set_tracer",
    "span",
]
