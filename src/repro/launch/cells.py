"""Dry-run cell construction: (arch x input-shape) -> lowerable function.

`input_specs()` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no device allocation); `build_cell()` pairs them with the
jit-able step function and its in_shardings. The same specs drive the smoke
tests (reduced sizes) via data.pipeline.batch_specs — one source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import batch_specs
from repro.models.config import ModelConfig
from repro.models.transformer import cache_specs, decode_step, prefill
from repro.parallel.sharding import ShardingRules
from repro.training.train_step import (
    make_abstract_state, make_train_step, state_shardings,
)

# The assigned input-shape sets (LM transformer shapes).
SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}

# Microbatch count for train cells (grad accumulation): sized so a
# per-device microbatch holds ~2 rows on the single-pod mesh.
TRAIN_MICROBATCHES = 8


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    skip_reason: Optional[str] = None


def cell_is_applicable(cfg: ModelConfig, shape: str) -> Optional[str]:
    """Returns a skip reason or None (see DESIGN.md §5)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: no sub-quadratic path at 500k "
                "(skip per assignment; see DESIGN.md §Arch-applicability)")
    return None


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the cell's model inputs."""
    info = SHAPES[shape]
    if info["kind"] == "train" or info["kind"] == "prefill":
        return batch_specs(cfg, info["batch"], info["seq"])
    # decode: one new token against a seq_len-deep cache
    b = info["batch"]
    if cfg.frontend is not None and cfg.frontend.modality == "audio":
        tok = jax.ShapeDtypeStruct((b, cfg.frontend.num_positions, 1), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return {"tokens": tok}


def inference_fsdp(cfg: ModelConfig, tp: int = 16,
                   hbm_budget: float = 8e9) -> bool:
    """Serving replicates params over data ranks when the TP shard fits HBM
    (cheap reads); models too big for a TP shard keep FSDP sharding and pay
    the gather (jamba-398b)."""
    from repro.models.config import count_params
    return count_params(cfg) * 2.0 / tp > hbm_budget


def make_rules(cfg: ModelConfig, shape: str, mesh,
               strategy: str = "baseline",
               fsdp: Optional[bool] = None) -> ShardingRules:
    """Sharding strategy for a cell.

    baseline  — the paper-faithful-ish first cut: ZeRO-3 gather-at-use for
                everything (incl. MoE experts), TP over `model`, FSDP over
                (pod, data).
    optimized — the beyond-paper §Perf configuration:
                * MoE experts stay EP-sharded (tokens move, not weights);
                * decode skips the ZeRO-3 gather (partial-sum ARs of tiny
                  activations beat streaming gathered weights at batch<=128);
                * small/mid dense models fold `model` into the FSDP axes
                  (pure FSDP beats TP at this scale on ICI).
    """
    info = SHAPES[shape]
    if fsdp is None:
        fsdp = True if info["kind"] == "train" else inference_fsdp(cfg)
    if strategy == "baseline":
        return ShardingRules(mesh=mesh, fsdp=fsdp, zero3_gather=True,
                             gather_moe_experts=True)
    if info["kind"] == "decode":
        return ShardingRules(mesh=mesh, fsdp=fsdp, zero3_gather=False,
                             gather_moe_experts=False,
                             decode_feature_shard=fsdp)
    from repro.models.config import count_params
    small_dense = cfg.moe is None and count_params(cfg) < 40e9
    fsdp_axes = (("pod", "data", "model") if small_dense
                 else ("pod", "data"))
    return ShardingRules(mesh=mesh, fsdp=fsdp, zero3_gather=True,
                         gather_moe_experts=False, fsdp_axes=fsdp_axes)


def strategy_microbatches(cfg: ModelConfig, strategy: str) -> int:
    """Grad-accumulation depth per strategy (§Perf A4 + dense-FSDP note):
    weight-gather wire scales with microbatch count, so the optimized
    strategy accumulates as little as activation memory allows — dense
    full-DP models take the whole batch in one microbatch (1 row/device),
    MoE models take 4 (16.2 GB/device at 2 was the HBM edge)."""
    if strategy == "baseline":
        return TRAIN_MICROBATCHES
    from repro.models.config import count_params
    if cfg.moe is None and count_params(cfg) < 40e9:
        return 1
    return 4


def build_cell(arch: str, shape: str, mesh, fsdp: Optional[bool] = None,
               microbatches: Optional[int] = None,
               strategy: str = "baseline") -> Cell:
    cfg = get_config(arch)
    skip = cell_is_applicable(cfg, shape)
    if skip:
        return Cell(arch, shape, cfg, None, (), (), skip_reason=skip)
    info = SHAPES[shape]
    if microbatches is None:
        microbatches = strategy_microbatches(cfg, strategy)
    rules = make_rules(cfg, shape, mesh, strategy, fsdp)
    specs = input_specs(cfg, shape)
    batch_sh = {
        k: rules.sharding_for_shape(v.shape, "dp", *(None,) * (len(v.shape) - 1))
        for k, v in specs.items()
    }

    if info["kind"] == "train":
        step = make_train_step(cfg, rules=rules, microbatches=microbatches)
        state = make_abstract_state(cfg)
        st_sh = state_shardings(cfg, rules)
        return Cell(arch, shape, cfg, step, (state, specs), (st_sh, batch_sh))

    from repro.models.transformer import abstract_params, param_shardings
    params = abstract_params(cfg)
    if strategy == "optimized":
        # Serve from bf16 weights (§Perf cell B iter 3): halves both the HBM
        # stream and any remaining weight-shard gathers; f32 masters are a
        # training-only artifact.
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.dtype("float32")
                else s.dtype
            ),
            params,
        )
    p_sh = param_shardings(cfg, rules)

    if info["kind"] == "prefill":
        def prefill_fn(p, b):
            return prefill(p, cfg, b, rules)
        return Cell(arch, shape, cfg, prefill_fn, (params, specs),
                    (p_sh, batch_sh))

    # decode
    long = bool(info.get("long"))
    cache, cache_sh = cache_specs(cfg, info["batch"], info["seq"],
                                  rules, shard_seq=long)
    cur = jax.ShapeDtypeStruct((), jnp.int32)
    cur_sh = rules.sharding()

    def decode_fn(p, c, tok, cur_len):
        return decode_step(p, cfg, c, tok["tokens"], cur_len, rules)

    return Cell(arch, shape, cfg, decode_fn,
                (params, cache, specs, cur),
                (p_sh, cache_sh, batch_sh, cur_sh))
