import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import: jax locks the device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --fdk          # paper's cells

For every cell this prints compiled.memory_analysis() (proves it fits) and
cost_analysis() (FLOPs/bytes for EXPERIMENTS.md §Roofline), plus the parsed
collective wire bytes. Results are appended as JSON lines for the roofline
table generator (benchmarks/roofline_table.py).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.launch.cells import SHAPES, build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline, collective_stats, model_flops_for,
)
from repro.configs import list_archs, get_config
from repro.models.config import count_active_params


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return None
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception:
        return None


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return dict(ca) if ca else None
    except Exception:
        return None


def run_cell(arch: str, shape: str, multi_pod: bool, out_file=None,
             verbose: bool = True, strategy: str = "baseline") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, strategy=strategy)
    if cell.skip_reason:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": cell.skip_reason}
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: SKIP "
                  f"({cell.skip_reason})")
        if out_file:
            out_file.write(json.dumps(rec) + "\n")
            out_file.flush()
        return rec

    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(
            *cell.args
        )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    colls = collective_stats(hlo)

    info = SHAPES[shape]
    cfg = get_config(arch)
    from repro.models.config import count_params
    from repro.launch.cells import make_rules, strategy_microbatches
    from repro.launch.roofline import analytic_costs
    mflops = model_flops_for(cfg, info, count_active_params(cfg))
    rules = make_rules(cfg, shape, mesh, strategy)
    ac = analytic_costs(cfg, info, chips, count_params(cfg),
                        microbatches=strategy_microbatches(cfg, strategy),
                        fsdp=rules.fsdp,
                        zero3_gather=rules.zero3_gather,
                        moe_ep=not rules.gather_moe_experts)
    rl = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=ac.flops_per_dev,
        hlo_bytes=ac.hbm_bytes_per_dev,
        wire_bytes=colls.wire_bytes,
        model_flops=mflops,
        peak_mem_bytes=(mem or {}).get("temp_bytes"),
    )
    rec = {
        "status": "ok",
        "strategy": strategy,
        **rl.row(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "collectives": {"counts": colls.op_count, "bytes": colls.op_bytes},
        "hlo_reported_flops": float(cost.get("flops", 0.0)) if cost else None,
        "hlo_reported_bytes": (float(cost.get("bytes accessed", 0.0))
                               if cost else None),
        "hlo_bytes_len": len(hlo),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {mem}")
        if cost:
            print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {colls.op_count} wire={colls.wire_bytes:.3e}B")
        print(f"  roofline: compute={rl.t_compute:.4f}s memory={rl.t_memory:.4f}s "
              f"collective={rl.t_collective:.4f}s dominant={rl.dominant} "
              f"useful_ratio={rl.useful_ratio and round(rl.useful_ratio, 3)}")
    if out_file:
        out_file.write(json.dumps(rec) + "\n")
        out_file.flush()
    return rec


def run_fdk(multi_pod: bool, problem: str = "4k", out_file=None,
            fdk_impl: str = "pipelined", n_steps: int = 8,
            y_chunks: int = 16, impl: str = "factorized") -> dict:
    """The paper's own cells: 2048^2 x 4096 -> {2k,4k,8k}^3 reconstruction."""
    import jax.numpy as jnp
    from repro.core.geometry import CBCTGeometry
    from repro.core.plan import ReconstructionPlan

    n = {"2k": 2048, "4k": 4096, "8k": 8192}[problem]
    g = CBCTGeometry(
        n_proj=4096, n_u=2048, n_v=2048, d_u=2 * 2.4 / 2048,
        d_v=2 * 2.4 / 2048, d=4.0, dsd=8.0,
        n_x=n, n_y=n, n_z=n, d_x=2.0 / n, d_y=2.0 / n, d_z=2.0 / n,
    )
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if fdk_impl == "chunked":
        plan = ReconstructionPlan(geometry=g, mesh=mesh, impl=impl,
                                  schedule="chunked", n_steps=n_steps,
                                  y_chunks=y_chunks, reduce="scatter")
    elif fdk_impl == "pipelined":
        plan = ReconstructionPlan(geometry=g, mesh=mesh, impl=impl,
                                  schedule="pipelined", n_steps=n_steps,
                                  reduce="scatter")
    else:
        plan = ReconstructionPlan(geometry=g, mesh=mesh, impl=impl,
                                  schedule="fused", reduce="scatter")
    fn = plan.build()
    proj = jax.ShapeDtypeStruct((g.n_proj, g.n_v, g.n_u), jnp.float32)
    lowered = fn.lower(proj) if hasattr(fn, "lower") else jax.jit(
        fn
    ).lower(proj)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    colls = collective_stats(compiled.as_text())
    # Useful work: N_x*N_y*N_z*N_p voxel updates, ~18 flops each (see
    # benchmarks/bench_backprojection.py) + filtering FFTs.
    updates = g.n_x * g.n_y * g.n_z * float(g.n_proj)
    rl = Roofline(
        arch=f"ifdk-{problem}", shape="reconstruct", mesh=mesh_name,
        chips=mesh.devices.size,
        hlo_flops=float(cost.get("flops", 0.0)) if cost else 0.0,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        wire_bytes=colls.wire_bytes,
        model_flops=18.0 * updates,
        peak_mem_bytes=(mem or {}).get("temp_bytes"),
    )
    rec = {"status": "ok", **rl.row(),
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
           "memory_analysis": mem,
           "collectives": {"counts": colls.op_count, "bytes": colls.op_bytes},
           "fdk_impl": fdk_impl, "n_steps": n_steps, "impl": impl}
    print(f"[dryrun] iFDK {problem} x {mesh_name}: OK "
          f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
    print(f"  memory_analysis: {mem}")
    print(f"  collectives: {colls.op_count} wire={colls.wire_bytes:.3e}B")
    print(f"  roofline: compute={rl.t_compute:.4f}s memory={rl.t_memory:.4f}s "
          f"collective={rl.t_collective:.4f}s dominant={rl.dominant}")
    if out_file:
        out_file.write(json.dumps(rec) + "\n")
        out_file.flush()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fdk", action="store_true")
    ap.add_argument("--fdk-problem", default="4k", choices=["2k", "4k", "8k"])
    ap.add_argument("--fdk-impl", default="pipelined",
                    choices=["plain", "pipelined", "chunked"])
    ap.add_argument("--fdk-steps", type=int, default=8)
    ap.add_argument("--fdk-chunks", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--strategy", default="baseline",
                    choices=["baseline", "optimized"])
    args = ap.parse_args()

    out_file = open(args.out, "a") if args.out else None
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    try:
        if args.fdk:
            for mp in meshes:
                run_fdk(mp, args.fdk_problem, out_file,
                        fdk_impl=args.fdk_impl, n_steps=args.fdk_steps,
                        y_chunks=args.fdk_chunks)
            return
        if args.all:
            for arch in list_archs():
                for shape in SHAPES:
                    for mp in meshes:
                        try:
                            run_cell(arch, shape, mp, out_file,
                                     strategy=args.strategy)
                        except Exception as e:
                            failures.append((arch, shape, mp, repr(e)))
                            traceback.print_exc()
            if failures:
                print(f"[dryrun] {len(failures)} FAILURES:")
                for f in failures:
                    print("  ", f)
                raise SystemExit(1)
            print("[dryrun] all cells compiled OK")
            return
        run_cell(args.arch, args.shape, args.multi_pod, out_file,
                 strategy=args.strategy)
    finally:
        if out_file:
            out_file.close()


if __name__ == "__main__":
    main()
